"""Table 12 / App. C: asynchronous off-policy baselines — Truncated-IS
(IMPALA), CISPO, TOPR (± KL) vs GEPO under delay."""
from __future__ import annotations

from benchmarks.common import csv_row, run_method

KEYS = ("eval_best", "eval_last", "gap", "iw_var_mean", "kl_mean")


def run() -> list:
    rows = ["table12_async,method," + ",".join(KEYS)]
    settings = [
        ("tis+kl", dict(loss_type="tis", beta_kl=0.005)),
        ("topr_wo_kl", dict(loss_type="topr", beta_kl=0.0)),
        ("topr+kl", dict(loss_type="topr", beta_kl=0.005)),
        ("cispo_wo_kl", dict(loss_type="cispo", beta_kl=0.0)),
        ("cispo+kl", dict(loss_type="cispo", beta_kl=0.005)),
        ("gepo", dict(loss_type="gepo", beta_kl=0.005)),
    ]
    for name, kw in settings:
        lt = kw.pop("loss_type")
        rec = run_method(lt, mode="hetero", max_delay=64,
                         delay_median_s=900.0, **kw)
        rows.append(csv_row(f"table12_async,{name}", rec, list(KEYS)))
    return rows
