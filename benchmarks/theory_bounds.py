"""Theorems 1 & 2 numeric validation over random discrete distributions
(the App. A math, checked exactly)."""
from __future__ import annotations

import numpy as np

from repro.core import theory


def run() -> list:
    rng = np.random.default_rng(0)
    t1_viol = t2_viol = 0
    margins = []
    n_trials = 2000
    for _ in range(n_trials):
        n = int(rng.integers(2, 64))
        p = rng.dirichlet(np.ones(n) * rng.uniform(0.2, 3.0))
        q = rng.dirichlet(np.ones(n) * rng.uniform(0.2, 3.0))
        delta, exp_kl, c = theory.theorem1_terms(p, q)
        if delta < exp_kl - c - 1e-9:
            t1_viol += 1
        margins.append(delta - (exp_kl - c))
        a = rng.normal(size=n)
        if theory.bias_gepo(p, q, a) > theory.bias_bound(p, q):
            t2_viol += 1
    rows = ["theory,check,violations,trials,min_margin"]
    rows.append(f"theory,theorem1,{t1_viol},{n_trials},{min(margins):.4g}")
    rows.append(f"theory,theorem2_bias,{t2_viol},{n_trials},-")
    assert t1_viol == 0 and t2_viol == 0
    return rows
