"""Shared benchmark harness.

All RL benchmarks train the same tiny LM (SFT warm-started once, cached)
on the synthetic verifiable-math task and differ only in loss type /
latency setting — mirroring the paper's experimental matrix at CPU scale.
Set BENCH_STEPS / BENCH_FULL=1 to change budgets.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.config import (HeteroConfig, ModelConfig, RLConfig, TrainConfig,
                          ATTN, MLP)
from repro.core.diagnostics import MetricsHistory, best_last_gap
from repro.data import ArithmeticTask, Tokenizer
from repro.hetero import HeteroRuntime, run_online
from repro.launch.train import make_eval_fn, sft_warmstart
from repro.models import init_params
from repro.training import TrainState, init_state

FULL = os.environ.get("BENCH_FULL", "0") == "1"
STEPS = int(os.environ.get("BENCH_STEPS", "60" if FULL else "30"))
SFT_STEPS = int(os.environ.get("BENCH_SFT_STEPS", "400" if FULL else "250"))

TINY = ModelConfig(name="bench-lm", family="dense", num_layers=2,
                   d_model=96, num_heads=4, num_kv_heads=2, d_ff=192,
                   vocab_size=32, block_pattern=(ATTN,),
                   ffn_pattern=(MLP,), dtype="float32", attn_impl="naive",
                   remat=False, rope_theta=1e4)


def task_and_tok(seed=0):
    return (ArithmeticTask(max_operand=20, ops="+", prompt_width=6,
                           seed=seed), Tokenizer())


@functools.lru_cache(maxsize=2)
def warm_start(seed: int = 0):
    """Shared SFT warm start (paper RL-tunes a pretrained model)."""
    task, tok = task_and_tok(seed)
    tc = TrainConfig(learning_rate=1e-2, total_steps=SFT_STEPS)
    state = init_state(TINY, tc, init_params(TINY, jax.random.PRNGKey(seed)))
    state, loss = sft_warmstart(TINY, tc, task, tok, state,
                                steps=SFT_STEPS, batch=64, seed=seed)
    return state, float(loss)


def run_method(loss_type: str, *, mode: str = "online",
               max_delay: int = 64, delay_median_s: float = 600.0,
               delay_dist: str = "lognormal", beta_kl: Optional[float] = None,
               group_size: int = 8, temperature: float = 1.0,
               top_k: int = 0, top_p: float = 1.0, adv_normalize: bool = True,
               gepo_smooth: float = 0.0, steps: Optional[int] = None,
               seed: int = 0, num_samplers: int = 2,
               prompts_per_batch: int = 8, lr: float = 1e-3,
               bandwidth_mbps: float = float("inf")) -> Dict:
    """One training run; returns the paper's summary stats + history."""
    steps = steps or STEPS
    jax.clear_caches()                  # bound executable memory on 1 core
    state0, _ = warm_start(seed)
    # the lru-cached warm start is reused across run_method calls; the
    # learner's donated train step never touches it because LearnerNode
    # takes a plan-placed copy of whatever state it is given
    state = TrainState(params=state0.params, opt=state0.opt,
                       step=jnp.zeros((), jnp.int32))
    beta = beta_kl if beta_kl is not None else (
        0.0 if mode == "online" else 0.005)            # paper §4.1
    rl = RLConfig(loss_type=loss_type, group_size=group_size, beta_kl=beta,
                  max_new_tokens=6, temperature=temperature, top_k=top_k,
                  top_p=top_p, adv_normalize=adv_normalize,
                  gepo_smooth=gepo_smooth)
    tc = TrainConfig(learning_rate=lr, total_steps=steps)
    task, tok = task_and_tok(seed)
    eval_fn = make_eval_fn(TINY, rl, task, tok, n_prompts=24)
    eval_every = max(steps // 6, 2)

    if mode == "online":
        hist, evals, learner = run_online(
            TINY, rl, tc, task, tok, state, num_steps=steps,
            prompts_per_batch=prompts_per_batch, seed=seed,
            eval_fn=eval_fn, eval_every=eval_every)
    else:
        hcfg = HeteroConfig(num_samplers=num_samplers,
                            max_delay_steps=max_delay,
                            delay_distribution=delay_dist,
                            delay_median_s=delay_median_s, seed=seed,
                            bandwidth_mbps=bandwidth_mbps)
        rt = HeteroRuntime(TINY, rl, tc, hcfg, task, tok, state,
                           prompts_per_batch=prompts_per_batch,
                           eval_fn=eval_fn, eval_every=eval_every)
        hist = rt.run(steps)
        evals = rt.eval_scores
        learner = rt.learner

    best, last, gap = best_last_gap(evals)
    sync_telemetry = rt.sync_telemetry() if mode != "online" else []
    sampler_rows = [t for t in sync_telemetry if t["sampler"] >= 0]
    return {
        "sync_bytes_on_wire": sum(t["bytes_on_wire"] for t in sampler_rows),
        "sync_seconds": sum(t["sync_seconds"] for t in sampler_rows),
        "sync_dedup_ratio": (float(np.mean([t["dedup_ratio"]
                                            for t in sampler_rows]))
                             if sampler_rows else 0.0),
        "learner_bytes_streamed": (learner.bytes_streamed
                                   if mode != "online" else 0),
        "loss_type": loss_type, "mode": mode,
        "eval_best": best, "eval_last": last, "gap": gap,
        "reward_last10": float(np.mean(hist.get("reward_mean")[-10:])),
        "iw_var_mean": float(np.nanmean(hist.get("iw_var"))),
        "iw_var_max": float(np.nanmax(hist.get("iw_var"))),
        "kl_mean": float(np.nanmean(hist.get("kl"))),
        "grad_norm_std": float(np.nanstd(hist.get("grad_norm"))),
        "est_error_mean": float(np.nanmean(hist.get("est_error"))),
        "staleness_mean": float(np.nanmean(hist.get("staleness"))),
        "history": hist,
    }


STABILITY_KEYS = ("eval_best", "eval_last", "gap", "iw_var_mean",
                  "iw_var_max", "kl_mean", "grad_norm_std",
                  "staleness_mean")


def publish_method_metrics(rec: Dict, *, condition: str = "table2") -> None:
    """Mirror a ``run_method`` summary into the unified obs registry as
    ``bench_<key>{method=...,condition=...}`` gauges — the paper's
    stability quantities (best-to-last gap, IW variance, KL, grad-norm
    std, staleness) become scrapeable next to the live runtime gauges
    instead of living only in CSV rows. No-op while the registry is
    disabled."""
    if not obs.metrics.enabled:
        return
    for k in STABILITY_KEYS:
        obs.metrics.gauge(
            f"bench_{k}",
            "per-method stability summary (Table 2 / Fig. 4)",
            method=rec["loss_type"], condition=condition).set(rec[k])


def csv_row(name: str, rec: Dict, keys: List[str]) -> str:
    return ",".join([name] + [f"{rec[k]:.4f}" if isinstance(rec[k], float)
                              else str(rec[k]) for k in keys])
