"""Observability overhead + trace-export smoke: what the obs spine costs.

Two questions, answered with numbers:

1. **Disabled overhead** — the registry/tracer are constructed into every
   hot path (engine step, sampler generate, learner step) but default
   off; the zero-cost contract says a disabled run must be
   indistinguishable from a build without them. Measured by driving the
   ``serve_latency`` poisson scenario with obs off vs on and comparing
   wall time (min over reps; open-loop arrivals are identical).
2. **Trace well-formedness** — the enabled runs must export
   Perfetto-loadable Chrome traces: one wall-clock serve trace carrying
   engine prefill/decode spans, one EventSim hetero trace carrying
   learner/sampler spans on the *virtual* clock — same format, different
   clock, as promised by the pluggable-clock design.

  PYTHONPATH=src python -m benchmarks.obs_bench [--smoke]

Output: CSV rows ``obs,<metric>,...`` plus a ``BENCH_obs.json`` artifact
(path: $BENCH_OBS_JSON) recording both overheads and trace inventories.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.serve_latency import (_cfg, _drive, _make_prompts,
                                      _poisson_schedule)
from repro import obs
from repro.config import HeteroConfig, RLConfig, ServeConfig, TrainConfig
from repro.models import init_params
from repro.obs import validate_chrome_trace, write_chrome_trace
from repro.sampling import build_engine
from repro.serving.api import Request, SamplingParams

SMOKE_ENV = os.environ.get("BENCH_SMOKE", "0") == "1"
JSON_PATH = os.environ.get("BENCH_OBS_JSON", "BENCH_obs.json")


def _serve_drive_once(smoke: bool, seed: int = 0) -> float:
    """One poisson serve_latency drive; returns wall seconds. The obs
    state (enabled/disabled) is whatever the caller configured — that is
    the variable under test."""
    rng = np.random.default_rng(seed)
    cfg = _cfg(smoke)
    prefix_len, tail_len = (16, 4) if smoke else (48, 8)
    max_new = 8 if smoke else 16
    n = 12 if smoke else 48
    rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0,
                  max_new_tokens=max_new, engine="continuous")
    sp = SamplingParams.from_rl(rl)
    serve = ServeConfig(num_slots=2 if smoke else 4,
                        page_size=4 if smoke else 16,
                        sync_every=4 if smoke else 8,
                        max_total_tokens=prefix_len + tail_len + max_new,
                        max_queue=64, seed=seed)
    prompts = _make_prompts(n, prefix_len, tail_len, rng)
    arrivals = _poisson_schedule(n, 0.02 if smoke else 0.01, rng)
    key = jax.random.PRNGKey(seed)
    engine = build_engine(cfg, init_params(cfg, key), serve, rl=rl,
                          vocab_limit=cfg.vocab_size, key=key)
    # warm executables outside the timed region
    engine.generate([Request(rid=10_000,
                             prompt=prompts[0][:prefix_len + tail_len],
                             params=sp)])
    engine.prefix_cache.clear()
    t0 = time.perf_counter()
    _drive(engine, serve, arrivals, prompts, sp)
    return time.perf_counter() - t0


def _hetero_trace(smoke: bool, path: str, seed: int = 0) -> Dict:
    """A tiny EventSim hetero run with obs on: the virtual clock drives
    the tracer, so learner step windows and sampler generate windows land
    at *simulated* timestamps (hours of WAN delay render in one page)."""
    from benchmarks.common import TINY, task_and_tok
    from repro.hetero import HeteroRuntime
    from repro.training import init_state

    obs.configure(True, clear=True)
    task, tok = task_and_tok(seed)
    rl = RLConfig(loss_type="gepo", group_size=4, max_new_tokens=4,
                  beta_kl=0.005)
    tc = TrainConfig(learning_rate=1e-3, total_steps=4)
    hcfg = HeteroConfig(num_samplers=2, max_delay_steps=64,
                        delay_median_s=600.0, seed=seed)
    state = init_state(TINY, tc, init_params(TINY, jax.random.PRNGKey(seed)))
    rt = HeteroRuntime(TINY, rl, tc, hcfg, task, tok, state,
                       prompts_per_batch=4)
    rt.run(4)
    n = write_chrome_trace(obs.trace, path)
    validate_chrome_trace(path)
    names = {e["name"] for e in obs.trace.events()}
    for want in ("learner_step", "sampler_generate", "step_window",
                 "gen_window"):
        assert want in names, f"hetero trace missing {want!r}: {names}"
    return {"path": path, "events": n, "span_names": sorted(names)}


def run(smoke: bool = None) -> List[str]:
    smoke = SMOKE_ENV if smoke is None else smoke
    reps = 2 if smoke else 3
    rows: List[str] = []

    # -- disabled vs enabled serve drives -----------------------------
    obs.configure(False, clear=True)
    t_off = min(_serve_drive_once(smoke, seed=r) for r in range(reps))
    obs.configure(True, clear=True)
    t_on = min(_serve_drive_once(smoke, seed=r) for r in range(reps))
    overhead_pct = 100.0 * (t_on - t_off) / max(t_off, 1e-9)

    # -- wall-clock serve trace (from the enabled drives) -------------
    serve_path = os.environ.get("BENCH_OBS_SERVE_TRACE",
                                "TRACE_serve.json")
    n_serve = write_chrome_trace(obs.trace, serve_path)
    validate_chrome_trace(serve_path)
    serve_names = {e["name"] for e in obs.trace.events()}
    for want in ("prefill", "decode"):
        assert want in serve_names, \
            f"serve trace missing {want!r}: {serve_names}"

    # -- EventSim hetero trace (virtual clock, same format) -----------
    hetero_path = os.environ.get("BENCH_OBS_HETERO_TRACE",
                                 "TRACE_hetero.json")
    hetero = _hetero_trace(smoke, hetero_path)

    obs.configure(False, clear=True)      # leave no residue for later
    rows.append(f"obs,overhead,disabled_s={t_off:.3f},"
                f"enabled_s={t_on:.3f},overhead_pct={overhead_pct:.1f}")
    rows.append(f"obs,serve_trace,events={n_serve},path={serve_path}")
    rows.append(f"obs,hetero_trace,events={hetero['events']},"
                f"path={hetero_path}")
    artifact = {
        "meta": {"smoke": smoke, "reps": reps},
        "overhead": {"disabled_s": t_off, "enabled_s": t_on,
                     "overhead_pct": overhead_pct},
        "serve_trace": {"path": serve_path, "events": n_serve,
                        "span_names": sorted(serve_names)},
        "hetero_trace": hetero,
    }
    try:
        with open(JSON_PATH, "w") as f:
            json.dump(artifact, f, indent=1)
        rows.append(f"# wrote {JSON_PATH}")
    except OSError:
        rows.append(f"# could not write {JSON_PATH}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for row in run(smoke=args.smoke or SMOKE_ENV):
        print(row, flush=True)


if __name__ == "__main__":
    main()
