"""Benchmark harness entrypoint: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig2,table1,...] [--smoke]

Budget knobs: BENCH_STEPS (default 30), BENCH_FULL=1 for paper-scale runs.
``--smoke`` runs a tiny fast subset (<60 s CPU) so CI can exercise the
benchmark entrypoints without burning minutes.
Output: CSV rows `table,setting,metrics...` on stdout.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time
import traceback

MODULES = [
    ("fig2", "benchmarks.fig2_variance"),
    ("theory", "benchmarks.theory_bounds"),
    ("table14", "benchmarks.table14_localized"),
    ("roofline", "benchmarks.roofline_table"),
    ("table1", "benchmarks.table1_online"),
    ("table2", "benchmarks.table2_hetero"),
    ("fig5", "benchmarks.fig5_latency"),
    ("table12", "benchmarks.table12_async"),
    ("table13", "benchmarks.table13_ablation"),
    ("hyperparams", "benchmarks.hyperparams"),
    ("serve", "benchmarks.serve_throughput"),
    ("serve_lat", "benchmarks.serve_latency"),
    ("logprob", "benchmarks.logprob_bench"),
    ("decode", "benchmarks.decode_bench"),
    ("scaling", "benchmarks.scaling_bench"),
    ("sync", "benchmarks.sync_bench"),
    ("sentinel", "benchmarks.recompile_bench"),
    ("obs", "benchmarks.obs_bench"),
    ("spec", "benchmarks.spec_bench"),
]

# modules cheap enough for the CI smoke job ("serve" stays out: CI
# exercises benchmarks.serve_throughput --smoke as its own step;
# "logprob" rides here so the CI benchmark-smoke covers the hot path;
# "scaling" proves the sharded train step runs at data-axis sizes >1 —
# its workers are subprocesses, so the forced device count never leaks;
# "sync" asserts the chunked weight transport beats whole-blob sync and
# stays byte-identical — its mesh part subprocesses when devices < 4;
# "decode" A/Bs the paged-attention hot loops — decode steps and
# chunked-prefill chunks, plus the fused multi-layer launch —
# (gather-legacy vs in-place kernel/ref) on the temp-bytes proxy and
# emits BENCH_decode.json);
# "serve_lat" drives the admission-controlled front door under Poisson/
# bursty/overload open-loop load and emits BENCH_serve.json;
# "sentinel" asserts the engine's pow2-bucketed executable bound under
# the recompile sentinel (cold run <= bound, steady run compiles zero);
# "obs" measures tracing overhead (disabled vs enabled serve drive) and
# validates the exported Chrome traces parse (emits BENCH_obs.json);
# "spec" A/Bs speculative decoding (prompt-lookup drafts + k-token paged
# verification) against sequential decode and asserts the templated k=4
# speedup/accept-rate bars (emits BENCH_spec.json)
SMOKE_MODULES = ("fig2", "theory", "logprob", "decode", "scaling", "sync",
                 "serve_lat", "sentinel", "obs", "spec")


# One headline metric per legacy BENCH_*.json artifact (newer artifacts
# carry an explicit "headline" block instead and need no entry here).
_HEADLINE_PICKERS = {
    "BENCH_decode.json": lambda d: {
        "metric": "gather_over_ref_temp_max_ctx",
        "value": d["gather_over_ref_temp"][
            max(d["gather_over_ref_temp"], key=int)]},
    "BENCH_serve.json": lambda d: {
        "metric": "poisson_slo_tokens_per_s",
        "value": d["poisson"]["slo"]["tokens_per_s"]},
    "BENCH_obs.json": lambda d: {
        "metric": "trace_overhead_pct",
        "value": d["overhead"]["overhead_pct"]},
}


def write_summary(smoke: bool, path: str = "BENCH_summary.json") -> int:
    """Aggregate one headline metric from every BENCH_*.json in cwd into
    ``BENCH_summary.json`` — the single artifact a dashboard (or a human
    diffing two CI runs) reads instead of N per-bench files. Artifacts
    either carry their own ``headline`` block (the convention for new
    benches) or get a picker above; files matching neither are listed
    without a metric rather than dropped."""
    headlines = {}
    for fp in sorted(glob.glob("BENCH_*.json")):
        if fp == path:
            continue
        try:
            with open(fp) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            headlines[fp] = {"error": str(e)}
            continue
        if isinstance(data.get("headline"), dict) and data["headline"]:
            headlines[fp] = data["headline"]
        elif fp in _HEADLINE_PICKERS:
            try:
                headlines[fp] = _HEADLINE_PICKERS[fp](data)
            except (KeyError, ValueError) as e:
                headlines[fp] = {"error": f"picker failed: {e}"}
        else:
            headlines[fp] = {"metric": None,
                             "note": "no headline block or picker"}
    with open(path, "w") as f:
        json.dump({"bench": "summary", "smoke": smoke,
                   "headlines": headlines}, f, indent=1)
    return len(headlines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast subset for CI (<60 s CPU)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.smoke:
        # must be set before the modules import benchmarks.common
        os.environ["BENCH_SMOKE"] = "1"
        os.environ.setdefault("BENCH_STEPS", "4")
        os.environ.setdefault("BENCH_SFT_STEPS", "20")
        if only is None:
            only = set(SMOKE_MODULES)

    t0 = time.time()
    failures = []
    for name, mod_name in MODULES:
        if only and name not in only:
            continue
        t1 = time.time()
        try:
            import importlib

            import jax
            jax.clear_caches()          # executables from prior modules
            mod = importlib.import_module(mod_name)
            rows = mod.run()
            for r in rows:
                print(r, flush=True)
            print(f"# {name} done in {time.time()-t1:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    n = write_summary(bool(args.smoke))
    print(f"# BENCH_summary.json aggregates {n} artifact headline(s)",
          flush=True)
    print(f"# total {time.time()-t0:.1f}s; failures: {failures or 'none'}",
          flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
