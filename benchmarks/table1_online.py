"""Table 1: online RL (Max Tolerable Delay 0) — GEPO vs GRPO / Dr.GRPO /
BNPO / GSPO on the verifiable-math task. Validates the stability ordering
(GEPO best average / best final), not absolute MATH500 numbers."""
from __future__ import annotations

from benchmarks.common import csv_row, run_method

METHODS = ("bnpo", "dr_grpo", "grpo", "gspo", "gepo")
KEYS = ("eval_best", "eval_last", "gap", "reward_last10", "iw_var_mean",
        "kl_mean")


def run() -> list:
    rows = ["table1_online,method," + ",".join(KEYS)]
    recs = {}
    for m in METHODS:
        recs[m] = run_method(m, mode="online")
        rows.append(csv_row(f"table1_online,{m}", recs[m], list(KEYS)))
    # paper claim (online): GEPO's final eval is at least on par with the
    # token/seq-level baselines (stability even without asynchrony)
    return rows
