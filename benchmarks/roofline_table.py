"""Deliverable (g): aggregate the dry-run JSON records into the roofline
table (per arch × shape × mesh: three terms, bottleneck, useful-FLOPs
fraction, HBM fit)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def run() -> list:
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    rows = ["roofline,arch,shape,mesh,compute_ms,memory_ms,collective_ms,"
            "bottleneck,useful_flops_frac,args_GiB,temp_GiB,fit16G"]
    if not files:
        rows.append("roofline,NO_RESULTS,run `python -m repro.launch."
                    "dryrun` first,,,,,,,,,")
        return rows
    for fn in files:
        with open(fn) as f:
            r = json.load(f)
        t = r["roofline"]
        ma = r.get("memory_analysis", {})
        rows.append(
            f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
            f"{t['compute_s']*1e3:.2f},{t['memory_s']*1e3:.2f},"
            f"{t['collective_s']*1e3:.2f},{t['bottleneck']},"
            f"{(r.get('useful_flops_frac') or 0):.3f},"
            f"{r.get('entry_arg_bytes_per_dev', 0)/2**30:.2f},"
            f"{ma.get('temp_size_in_bytes', 0)/2**30:.2f},"
            f"{r.get('hbm_fit_16g')}")
    return rows
