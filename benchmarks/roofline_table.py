"""Deliverable (g): aggregate the dry-run JSON records into the roofline
table (per arch × shape × mesh: three terms, bottleneck, useful-FLOPs
fraction, HBM fit), plus the analytic paged-decode bytes-per-token rows
(gather-legacy O(pool) vs in-place kernel O(len) KV traffic)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def paged_decode_rows() -> list:
    """Bytes-per-token model of the serving decode hot loop: the legacy
    gather reads (and re-materializes) every slot's full page allotment
    each step, the in-place kernel reads only the live pages — see
    ``benchmarks/decode_bench.py`` for the measured twin of this table."""
    from repro.config import DECODE_32K
    from repro.configs import get_config

    cfg = get_config("qwen2-7b")
    page_size = 16
    pool_len = DECODE_32K.seq_len                 # pages_per_slot * page
    kv_bytes = 2 * cfg.num_kv_heads * cfg.head_dim * 2      # k+v, bf16
    rows = ["roofline,paged-decode,arch,ctx,pool,kv_GiB_per_tok_gather,"
            "kv_GiB_per_tok_kernel,ratio"]
    for ctx in (2048, 8192, pool_len):
        gather = cfg.num_layers * pool_len * kv_bytes        # O(pool)
        live = -(-ctx // page_size) * page_size
        kernel = cfg.num_layers * live * kv_bytes            # O(len)
        rows.append(
            f"roofline,paged-decode,{cfg.name},{ctx},{pool_len},"
            f"{gather/2**30:.3f},{kernel/2**30:.3f},"
            f"{gather/kernel:.1f}x")
    return rows


def paged_prefill_rows() -> list:
    """Bytes-per-chunk model of chunked-prefill admission: the gather
    path materializes the narrowed table's dense view — the pow2 width
    bucket for ``pages_for(c0 + C)`` — per layer per chunk, while the
    in-place kernel streams exactly the reachable pages. The gap is the
    pow2 rounding (≤2x) *plus* the materialization itself: gather pays
    its bytes twice (read pool, write view, read view), the kernel
    once. Measured twin: the prefill sweep in
    ``benchmarks/decode_bench.py``."""
    from repro.config import DECODE_32K
    from repro.configs import get_config

    cfg = get_config("qwen2-7b")
    page_size = 16
    chunk = 512
    pool_len = DECODE_32K.seq_len
    pool_pages = pool_len // page_size
    kv_bytes = 2 * cfg.num_kv_heads * cfg.head_dim * 2      # k+v, bf16
    rows = ["roofline,paged-prefill,arch,c0,chunk,pool,"
            "kv_GiB_per_chunk_gather,kv_GiB_per_chunk_kernel,ratio"]
    for c0 in (2048, 8192, pool_len - chunk):
        live_pages = -(-(c0 + chunk) // page_size)
        width = 1
        while width < live_pages:
            width *= 2
        width = min(width, pool_pages)
        # dense view: read the pages + write/read the materialized copy
        gather = cfg.num_layers * 2 * width * page_size * kv_bytes
        kernel = cfg.num_layers * live_pages * page_size * kv_bytes
        rows.append(
            f"roofline,paged-prefill,{cfg.name},{c0},{chunk},{pool_len},"
            f"{gather/2**30:.3f},{kernel/2**30:.3f},"
            f"{gather/kernel:.1f}x")
    return rows


def run() -> list:
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    rows = ["roofline,arch,shape,mesh,compute_ms,memory_ms,collective_ms,"
            "bottleneck,useful_flops_frac,args_GiB,temp_GiB,fit16G"]
    if not files:
        rows.append("roofline,NO_RESULTS,run `python -m repro.launch."
                    "dryrun` first,,,,,,,,,")
        return rows + paged_decode_rows() + paged_prefill_rows()
    for fn in files:
        with open(fn) as f:
            r = json.load(f)
        t = r["roofline"]
        ma = r.get("memory_analysis", {})
        rows.append(
            f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
            f"{t['compute_s']*1e3:.2f},{t['memory_s']*1e3:.2f},"
            f"{t['collective_s']*1e3:.2f},{t['bottleneck']},"
            f"{(r.get('useful_flops_frac') or 0):.3f},"
            f"{r.get('entry_arg_bytes_per_dev', 0)/2**30:.2f},"
            f"{ma.get('temp_size_in_bytes', 0)/2**30:.2f},"
            f"{r.get('hbm_fit_16g')}")
    return rows + paged_decode_rows() + paged_prefill_rows()
