"""Tables 5–10: hyperparameter sensitivity (reduced sweeps).

Hetero-RL axes: group size, β_KL, delay distribution.
Online-RL axes: temperature, top-p, top-k.
"""
from __future__ import annotations

from benchmarks.common import csv_row, run_method

KEYS = ("eval_best", "eval_last", "gap", "iw_var_mean")


def run() -> list:
    rows = ["hyperparams,setting," + ",".join(KEYS)]

    # Table 5: group size (hetero)
    for g in (2, 4, 8):
        rec = run_method("gepo", mode="hetero", group_size=g,
                         delay_median_s=900.0)
        rows.append(csv_row(f"table5_group_size,g={g}", rec, list(KEYS)))

    # Table 6: beta_KL (hetero)
    for beta in (0.001, 0.005, 0.01):
        rec = run_method("gepo", mode="hetero", beta_kl=beta,
                         delay_median_s=900.0)
        rows.append(csv_row(f"table6_beta_kl,beta={beta}", rec, list(KEYS)))

    # Table 7: delay distribution (hetero)
    for dist in ("lognormal", "weibull", "exponential"):
        rec = run_method("gepo", mode="hetero", delay_dist=dist,
                         delay_median_s=900.0)
        rows.append(csv_row(f"table7_delay_dist,{dist}", rec, list(KEYS)))

    # Table 9: temperature (online)
    for t in (0.4, 1.0):
        rec = run_method("gepo", mode="online", temperature=t)
        rows.append(csv_row(f"table9_temperature,T={t}", rec, list(KEYS)))

    # Table 8: top-p (online)
    for p in (0.9, 1.0):
        rec = run_method("gepo", mode="online", top_p=p)
        rows.append(csv_row(f"table8_top_p,p={p}", rec, list(KEYS)))

    # Table 10: top-k (online)
    for k in (10, 0):
        rec = run_method("gepo", mode="online", top_k=k)
        rows.append(csv_row(f"table10_top_k,k={k or 'off'}", rec,
                            list(KEYS)))
    return rows
