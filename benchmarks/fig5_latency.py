"""Fig. 5/6/7: the latency → KL → IW-variance → estimation-error causal
chain. Staleness is swept via the model-sync delay; per-step correlations
(Fig. 7) computed over the training trace."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_method
from repro.core.diagnostics import pearson


def run() -> list:
    rows = ["fig5,delay_median_s,staleness_mean,kl_mean,iw_var_mean,"
            "est_error_mean"]
    traces = {}
    for med in (60.0, 600.0, 1800.0):
        rec = run_method("gepo", mode="hetero", max_delay=64,
                         delay_median_s=med)
        traces[med] = rec
        rows.append(f"fig5,{med:.0f},{rec['staleness_mean']:.3f},"
                    f"{rec['kl_mean']:.4g},{rec['iw_var_mean']:.4g},"
                    f"{rec['est_error_mean']:.4g}")
    # monotone chain across delay settings (paper Fig. 5)
    stal = [traces[m]["staleness_mean"] for m in (60.0, 600.0, 1800.0)]
    kl = [traces[m]["kl_mean"] for m in (60.0, 600.0, 1800.0)]
    rows.append(f"fig5,monotone_staleness,{stal[0]:.2f}<{stal[2]:.2f},"
                f"kl {kl[0]:.4g}->{kl[2]:.4g},-,-")

    # Fig. 7: per-step correlations on the highest-latency trace
    h = traces[1800.0]["history"]
    pairs = [("staleness", "kl"), ("kl", "iw_var"), ("iw_var", "est_error"),
             ("staleness", "iw_var")]
    rows.append("fig7,pair,pearson_r,-,-,-")
    for a, b in pairs:
        r = pearson(h.get(a), h.get(b))
        rows.append(f"fig7,{a}~{b},{r:.3f},-,-,-")
    return rows
