"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from the dry-run
JSON records (baseline + __opt)."""
from __future__ import annotations

import glob
import json
import os
import sys


def load(results="results/dryrun"):
    recs = {}
    for fn in glob.glob(os.path.join(results, "*.json")):
        key = os.path.basename(fn)[:-5]
        with open(fn) as f:
            recs[key] = json.load(f)
    return recs


def fmt_s(x):
    return f"{x*1e3:9.1f}" if x < 1000 else f"{x*1e3:9.3g}"


def roofline_table(recs, opt=False):
    rows = ["| arch | shape | mesh | compute ms | memory ms | collective ms"
            " | bottleneck | useful | args GiB | temp GiB | fit |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(recs):
        if key.endswith("__opt") != opt:
            continue
        r = recs[key]
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']*1e3:.1f} | {t['memory_s']*1e3:.1f} "
            f"| {t['collective_s']*1e3:.1f} | {t['bottleneck']} "
            f"| {(r.get('useful_flops_frac') or 0):.2f} "
            f"| {r.get('entry_arg_bytes_per_dev', 0)/2**30:.2f} "
            f"| {r['memory_analysis'].get('temp_size_in_bytes', 0)/2**30:.1f} "
            f"| {'✓' if r.get('hbm_fit_16g') else '✗'} |")
    return "\n".join(rows)


def compare_table(recs):
    rows = ["| arch × shape (16x16) | baseline coll GB | optimized coll GB "
            "| × | baseline temp GiB | optimized temp GiB | bottleneck "
            "base→opt |", "|---|---|---|---|---|---|---|"]
    for key in sorted(recs):
        if key.endswith("__opt") or "2x16x16" in key:
            continue
        opt = recs.get(key + "__opt")
        if opt is None:
            continue
        b = recs[key]
        cb = b["collective_bytes_per_dev"] / 1e9
        co = opt["collective_bytes_per_dev"] / 1e9
        tb = b["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30
        to = opt["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30
        rows.append(f"| {b['arch']} × {b['shape']} | {cb:.1f} | {co:.1f} "
                    f"| {cb/max(co,1e-9):.1f}× | {tb:.1f} | {to:.1f} "
                    f"| {b['roofline']['bottleneck']}→"
                    f"{opt['roofline']['bottleneck']} |")
    return "\n".join(rows)


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    print("## baseline\n")
    print(roofline_table(recs, opt=False))
    print("\n## optimized\n")
    print(roofline_table(recs, opt=True))
    print("\n## comparison\n")
    print(compare_table(recs))
