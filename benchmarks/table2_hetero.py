"""Table 2 + Fig. 4: heterogeneous RL at max tolerable delay 64 — the
paper's headline: GEPO keeps the best-to-last gap small while GSPO
collapses; IW variance / gradient-norm stability curves recorded."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (STABILITY_KEYS, csv_row,
                               publish_method_metrics, run_method)

METHODS = ("bnpo", "dr_grpo", "grpo", "gspo", "gepo")
KEYS = STABILITY_KEYS

_cache = {}


def records():
    if not _cache:
        for m in METHODS:
            _cache[m] = run_method(m, mode="hetero", max_delay=64,
                                   delay_median_s=900.0)
            # stability summaries double as registry gauges (method- and
            # condition-labeled) so a scraped /metrics carries the same
            # numbers the CSV table reports
            publish_method_metrics(_cache[m], condition="table2")
    return _cache


def run() -> list:
    rows = ["table2_hetero,method," + ",".join(KEYS)]
    recs = records()
    for m in METHODS:
        rows.append(csv_row(f"table2_hetero,{m}", recs[m], list(KEYS)))
    # Fig. 4: at benign KL (paper Fig. 2's "green region") GEIW variance
    # may exceed sequence-level — the paper's claim is the HIGH-KL regime,
    # so we also run a high-divergence stress condition (5x lr, long
    # delays -> large policy movement between syncs).
    gepo, gspo = recs["gepo"], recs["gspo"]
    rows.append(f"fig4,iw_var_gepo_vs_gspo(mild_kl),"
                f"{gepo['iw_var_mean']:.4g},{gspo['iw_var_mean']:.4g},"
                f"kl={gepo['kl_mean']:.2g}/{gspo['kl_mean']:.2g},-,-,-,-")
    stress = {}
    for m in ("gspo", "gepo"):
        stress[m] = run_method(m, mode="hetero", max_delay=64,
                               delay_median_s=1700.0, lr=8e-3)
        publish_method_metrics(stress[m], condition="high_kl")
    g2, s2 = stress["gepo"], stress["gspo"]
    rows.append(f"fig4,iw_var_gepo_vs_gspo(high_kl),"
                f"{g2['iw_var_mean']:.4g},{s2['iw_var_mean']:.4g},"
                f"kl={g2['kl_mean']:.2g}/{s2['kl_mean']:.2g},"
                f"iw_max={g2['iw_var_max']:.3g}/{s2['iw_var_max']:.3g},"
                f"gap={g2['gap']:.3f}/{s2['gap']:.3f},-,-")
    rows.append(f"fig4,grad_norm_std_gepo_vs_gspo,"
                f"{gepo['grad_norm_std']:.4g},{gspo['grad_norm_std']:.4g},"
                f"-,-,-,-,-")
    # payload-aware link (repro.transport): the same GEPO setting over a
    # finite 200 Mbps WAN — D_M now includes serialization time of the
    # bytes the chunked sync actually moved; the telemetry row records
    # wire bytes, dedup ratio and simulated sync seconds per run.
    bw = run_method("gepo", mode="hetero", max_delay=64,
                    delay_median_s=900.0, bandwidth_mbps=200.0)
    publish_method_metrics(bw, condition="200Mbps")
    rows.append(f"table2_hetero,gepo@200Mbps,"
                + ",".join(f"{bw[k]:.4f}" for k in KEYS))
    rows.append(f"table2_link,gepo@200Mbps,"
                f"wire_bytes={bw['sync_bytes_on_wire']:.0f},"
                f"dedup={bw['sync_dedup_ratio']:.3f},"
                f"sync_s={bw['sync_seconds']:.1f},"
                f"learner_streamed={bw['learner_bytes_streamed']:.0f},"
                f"staleness={bw['staleness_mean']:.2f},-,-,-")
    return rows
