"""Speculative decoding benchmark: drafted+verified vs sequential decode.

Drives the continuous engine over the same request set with speculation
off (the sequential decode-chunk path) and on (prompt-lookup drafts +
k-token paged verification), and reports steady-state decode tokens/s —
compile warmup excluded, the serve_throughput convention. Two workloads,
deliberately at the two ends of the drafter's operating range:

  - templated — greedy decoding. Untrained tiny models fall into short
    repetition cycles, exactly the looping/templated shape (system
    prompts, JSON scaffolding, code boilerplate) prompt-lookup drafting
    exists for: the n-gram drafter locks onto the cycle and acceptance
    climbs. The acceptance bar — the ISSUE target — is >=1.5x tokens/s
    at k=4 with accept-rate >=0.6.
  - random — temperature-1.0 sampling over near-uniform logits:
    incompressible output, accept-rate ~0. This row is the honest floor
    (~1x): verification costs one window forward per round either way,
    and each round still commits >=1 token (the replayed draw), so spec
    decode degrades toward the sequential rate instead of collapsing.

Exactness is not benched here — tests/test_spec.py pins greedy
bit-identity and the target-logp contract; this file only times.

  PYTHONPATH=src python -m benchmarks.spec_bench [--smoke]

Output: CSV rows ``spec,<workload>,k<k>,<tok/s>,accept<rate>,x<speedup>``
plus a ``BENCH_spec.json`` artifact (path: $BENCH_SPEC_JSON) with a
``headline`` block (templated k=4 speedup) for BENCH_summary.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro.config import ATTN, MLP, ModelConfig, RLConfig, ServeConfig
from repro.models import init_params
from repro.sampling import build_engine
from repro.serving.api import Request, SamplingParams

SMOKE_ENV = os.environ.get("BENCH_SMOKE", "0") == "1"
JSON_PATH = os.environ.get("BENCH_SPEC_JSON", "BENCH_spec.json")

# Big enough that the forward dominates per-dispatch overhead — the
# regime speculative decoding exists for (on accelerators decode is
# memory-bound; here model compute plays that role). Short prompts +
# long generations keep prefill out of the decode-rate denominator.
CFG = ModelConfig(name="spec-lm", family="dense", num_layers=4, d_model=256,
                  num_heads=8, num_kv_heads=4, d_ff=512, vocab_size=64,
                  block_pattern=(ATTN,), ffn_pattern=(MLP,),
                  dtype="float32", attn_impl="naive", remat=False,
                  rope_theta=1e4)

GREEDY = dict(temperature=1.0, top_k=1, top_p=1.0)
RANDOM = dict(temperature=1.0, top_k=0, top_p=1.0)


def _prompts(n: int, width: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.integers(4, 30, size=width).astype(np.int32)
            for _ in range(n)]


def _measure(params, profile: Dict, prompts: List[np.ndarray], *,
             max_new: int, spec_k: int, epochs: int,
             spec_rescore: bool = False) -> Dict[str, float]:
    """Steady-state decode rate: one warmup epoch (jit compile + width
    buckets), then ``epochs`` timed epochs over fresh request ids."""
    rl = RLConfig(max_new_tokens=max_new, engine="continuous", **profile)
    serve = ServeConfig(engine="continuous", num_slots=4, page_size=16,
                        sync_every=8, prefix_cache=False,
                        max_total_tokens=len(prompts[0]) + max_new,
                        spec_k=spec_k, spec_rescore=spec_rescore, seed=0)
    eng = build_engine(CFG, params, serve, rl=rl,
                       vocab_limit=CFG.vocab_size,
                       key=jax.random.PRNGKey(0))
    sp = SamplingParams.from_rl(rl)
    rid = 0

    def epoch():
        nonlocal rid
        reqs = [Request(rid=rid + i, prompt=p, params=sp)
                for i, p in enumerate(prompts)]
        rid += len(reqs)
        return eng.generate(reqs)

    epoch()                                          # warmup (compiles)
    base = eng.stats()
    tokens, t0 = 0, time.perf_counter()
    for _ in range(epochs):
        tokens += sum(r.gen_count for r in epoch())
    dt = time.perf_counter() - t0
    st = eng.stats()
    drafted = st["drafted_tokens_total"] - base["drafted_tokens_total"]
    accepted = st["accepted_tokens_total"] - base["accepted_tokens_total"]
    return {"tok_s": tokens / dt, "tokens": tokens, "seconds": dt,
            "accept_rate": accepted / max(drafted, 1),
            "drafted": int(drafted),
            "rescore_max_diff": st["spec_rescore_max_diff"]}


def run_bench(smoke: bool) -> List[str]:
    n, width = 8, 8
    # long generations over short prompts: decode-rate measurement with
    # no prefill dilution, and a live context long enough that the
    # per-step K/V gather (the memory-bound share) is in play
    max_new = 128
    epochs = 2 if smoke else 3
    params = init_params(CFG, jax.random.PRNGKey(0))
    ks = (4,) if smoke else (2, 4, 8)

    rows, out_rows = [], []

    def record(workload, k, res, base_tok_s):
        speedup = res["tok_s"] / base_tok_s
        row = {"workload": workload, "spec_k": k, **res,
               "speedup_x": round(speedup, 3)}
        out_rows.append(row)
        rows.append(f"spec,{workload},k{k},{res['tok_s']:.1f} tok/s,"
                    f"accept{res['accept_rate']:.2f},x{speedup:.2f}")
        return speedup

    headline = {}
    for workload, profile in (("templated", GREEDY), ("random", RANDOM)):
        base = _measure(params, profile, _prompts(n, width),
                        max_new=max_new, spec_k=0, epochs=epochs)
        record(workload, 0, base, base["tok_s"])
        for k in ks:
            res = _measure(params, profile, _prompts(n, width),
                           max_new=max_new, spec_k=k, epochs=epochs)
            speedup = record(workload, k, res, base["tok_s"])
            if workload == "templated" and k == 4:
                headline = {"metric": "templated_speedup_x_k4",
                            "value": round(speedup, 3),
                            "accept_rate": round(res["accept_rate"], 3)}
                # acceptance bar: templated k=4 must clear 1.5x with
                # accept-rate >= 0.6 (the ISSUE target)
                assert speedup >= 1.5, \
                    f"templated k=4 speedup {speedup:.2f}x < 1.5x"
                assert res["accept_rate"] >= 0.6, \
                    f"accept rate {res['accept_rate']:.2f} < 0.6"
    # rescore-on rider: what the drift gauge costs (one extra fused
    # launch per round) — and that it stays exactly 0
    res = _measure(params, GREEDY, _prompts(n, width), max_new=max_new,
                   spec_k=4, epochs=epochs, spec_rescore=True)
    assert res["rescore_max_diff"] == 0.0, res["rescore_max_diff"]
    base_tok = next(r["tok_s"] for r in out_rows
                    if r["workload"] == "templated" and r["spec_k"] == 0)
    record("templated+rescore", 4, res, base_tok)

    artifact = {
        "bench": "spec_decode",
        "meta": {"smoke": smoke, "requests": n, "max_new": max_new,
                 "epochs": epochs, "model": CFG.name,
                 "num_layers": CFG.num_layers, "vocab": CFG.vocab_size},
        "rows": out_rows,
        "headline": headline,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(artifact, f, indent=1)
    rows.append(f"# wrote {JSON_PATH}")
    return rows


def run() -> List[str]:
    return run_bench(SMOKE_ENV)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast configuration for CI")
    args = ap.parse_args()
    for r in run_bench(args.smoke or SMOKE_ENV):
        print(r, flush=True)


if __name__ == "__main__":
    main()
