"""Table 14 / App. F: localized reward computation.

Two implementations of group-advantage normalization are lowered on an
8-device fake mesh (subprocess, so the device-count override stays
contained):

  global   — rewards all-gathered, batch statistics computed globally
             (the "before" column of Table 14)
  localized — per-group statistics with groups aligned to shards
             (the paper's optimization: no collective at all)

The measured quantity is collective bytes in the compiled HLO.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.roofline import parse_collectives_loop_aware

    mesh = jax.make_mesh((8,), ("data",))
    B, G = 256, 8
    sh = NamedSharding(mesh, P("data"))

    def localized(rewards):
        r = rewards.reshape(B // G, G)
        a = (r - r.mean(-1, keepdims=True)) / (r.std(-1, keepdims=True)
                                               + 1e-6)
        return a.reshape(B)

    def global_stats(rewards):
        # pre-App.-F implementations normalize with *global* batch stats
        mu = rewards.mean()
        sd = rewards.std()
        r = rewards.reshape(B // G, G)
        a = (r - r.mean(-1, keepdims=True)) / (sd + 1e-6) + 0 * mu
        return a.reshape(B)

    out = {}
    with mesh:
        for name, fn in [("localized", localized),
                         ("global", global_stats)]:
            c = jax.jit(fn, in_shardings=sh, out_shardings=sh).lower(
                jax.ShapeDtypeStruct((B,), jnp.float32)).compile()
            coll = parse_collectives_loop_aware(c.as_text())
            out[name] = int(sum(coll.values()))
    print(json.dumps(out))
""")


def run() -> list:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    rec = json.loads(res.stdout.strip().splitlines()[-1])
    rows = ["table14_localized,variant,collective_bytes_per_step"]
    rows.append(f"table14_localized,global_gather,{rec['global']}")
    rows.append(f"table14_localized,localized(ours),{rec['localized']}")
    assert rec["localized"] <= rec["global"]
    assert rec["localized"] == 0, \
        "localized reward computation must need NO collectives"
    return rows
