"""Paged-decode hot-loop microbenchmark: gather-legacy vs ref vs pallas.

One decode step of the continuous engine runs ``paged_decode`` per layer
— the hottest loop in the serving path. This bench times exactly that op
across context lengths × pool occupancy and reports XLA's
``temp_size_in_bytes`` for the compiled executable as a peak-HBM-traffic
proxy (the ``logprob_bench`` convention):

  - gather   — the legacy path: materialize the whole
               (B, pages_per_slot·page_size, Hkv, D) logical view, then
               dense ``decode_attention`` over it. O(pool) bytes/token
               regardless of context.
  - ref      — ``paged_decode_ref``: per-page online softmax bounded by
               the live high-water mark. O(ceil(len/page)) bytes/token.
  - pallas   — the Mosaic kernel in interpret mode on CPU (compiled on
               a real TPU); benched at a reduced size — interpret mode
               pays a large python constant per grid step, but its
               memory story matches ref.

  PYTHONPATH=src python -m benchmarks.decode_bench [--smoke]

Output: CSV rows ``decode,<impl>,ctx<L>of<pool>,<ms>,<temp MiB>`` plus a
``BENCH_decode.json`` artifact (path: $BENCH_DECODE_JSON) — the first
datapoint of the serving-path perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import paged_decode

SMOKE_ENV = os.environ.get("BENCH_SMOKE", "0") == "1"
JSON_PATH = os.environ.get("BENCH_DECODE_JSON", "BENCH_decode.json")


def _make_case(b, hkv, rep, d, page, pages_per_slot, ctx, seed=0,
               dtype=jnp.float32):
    """Engine-shaped operands: every slot holds ``ctx`` live tokens of a
    pool provisioned for ``pages_per_slot`` pages per slot.

    f32 pools so the temp proxy compares layouts, not dtype lowering:
    XLA:CPU has no native bf16 dot, and the resulting upcast is
    loop-invariant for the page-loop impls — it would charge *only*
    them a pool-sized f32 conversion that a real TPU never pays."""
    hq = hkv * rep
    pool = 1 + b * pages_per_slot
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d), dtype)
    kp = jax.random.normal(ks[1], (pool, page, hkv, d), dtype)
    vp = jax.random.normal(ks[2], (pool, page, hkv, d), dtype)
    host = np.random.default_rng(seed)
    perm = host.permutation(np.arange(1, pool))
    table = perm[:b * pages_per_slot].reshape(b, pages_per_slot)
    lengths = host.integers(max(1, ctx // 2), ctx + 1, size=b)
    return (q, kp, vp, jnp.asarray(table.astype(np.int32)),
            jnp.asarray(lengths.astype(np.int32)))


def _temp_bytes(args, **kw) -> Optional[int]:
    try:
        mem = paged_decode.lower(*args, **kw).compile().memory_analysis()
        return int(mem.temp_size_in_bytes) if mem is not None else None
    except Exception:
        return None


def _bench(impl: str, args, *, reps: int, interpret=None):
    kw: Dict = {"impl": impl}
    if interpret is not None:
        kw["interpret"] = interpret
    tmp = _temp_bytes(args, **kw)
    out = paged_decode(*args, **kw)                  # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = paged_decode(*args, **kw)
    jax.block_until_ready(out)
    ms = (time.perf_counter() - t0) / reps * 1e3
    return ms, tmp


def run_bench(smoke: bool) -> List[str]:
    # decode-shaped: GQA 4:1. The pool is provisioned for the
    # longest request (prompt + max_new); the sweep holds the pool fixed
    # and varies the live context, i.e. pool-over-context ratio — the
    # regime where the legacy gather pays for capacity it never reads.
    if smoke:
        b, hkv, rep, d, page = 4, 2, 4, 64, 8
        pages_per_slot, ctxs, reps = 64, (32, 128, 512), 2
        pallas_ctx = 32
    else:
        b, hkv, rep, d, page = 8, 4, 4, 128, 16
        pages_per_slot, ctxs, reps = 128, (256, 512, 2048), 3
        pallas_ctx = 64
    pool_tokens = pages_per_slot * page

    rows: List[str] = []
    records: List[Dict] = []
    temps: Dict = {}
    for ctx in ctxs:
        args = _make_case(b, hkv, rep, d, page, pages_per_slot, ctx)
        for impl in ("gather", "ref"):
            ms, tmp = _bench(impl, args, reps=reps)
            temps[(impl, ctx)] = tmp
            mib = f"{tmp / 2**20:.1f}" if tmp is not None else "n/a"
            rows.append(f"decode,{impl},ctx{ctx}of{pool_tokens},"
                        f"{ms:.1f},{mib}")
            records.append({"impl": impl, "ctx": ctx,
                            "pool_tokens": pool_tokens,
                            "batch": b, "kv_heads": hkv, "rep": rep,
                            "head_dim": d, "page_size": page,
                            "ms": round(ms, 2), "temp_bytes": tmp})
    # pallas in interpret mode: one small shape, memory story == ref.
    # The table is narrowed to the live high-water mark exactly like the
    # engine does before dispatch — the interpreter walks every grid
    # step in python, so the dead-page DMA skip doesn't save it time.
    q, kp, vp, table, lengths = _make_case(b, hkv, rep, d, page,
                                           pages_per_slot, pallas_ctx)
    args = (q, kp, vp, table[:, :max(1, -(-pallas_ctx // page))], lengths)
    ms, tmp = _bench("pallas", args, reps=1, interpret=True)
    mib = f"{tmp / 2**20:.1f}" if tmp is not None else "n/a"
    rows.append(f"decode,pallas,ctx{pallas_ctx}of{pool_tokens},"
                f"{ms:.1f},{mib} (interpret)")
    records.append({"impl": "pallas-interpret", "ctx": pallas_ctx,
                    "pool_tokens": pool_tokens, "ms": round(ms, 2),
                    "temp_bytes": tmp})

    # the headline: at >=4x pool-over-context, the in-place path must
    # beat the legacy gather on the temp-bytes proxy
    ratios = {}
    for ctx in ctxs:
        tg, tr = temps.get(("gather", ctx)), temps.get(("ref", ctx))
        if tg and tr:
            ratios[str(ctx)] = round(tg / tr, 2)
            rows.append(f"# ctx={ctx} (pool/ctx={pool_tokens/ctx:.0f}x): "
                        f"gather temp = {tg / tr:.2f}x ref temp")
    out = {"bench": "decode", "unit": "ms/step+temp_bytes",
           "workload": {"batch": b, "kv_heads": hkv, "rep": rep,
                        "head_dim": d, "page_size": page,
                        "pages_per_slot": pages_per_slot,
                        "dtype": "float32", "smoke": smoke},
           "rows": records, "gather_over_ref_temp": ratios}
    try:
        with open(JSON_PATH, "w") as f:
            json.dump(out, f, indent=1)
        rows.append(f"# wrote {JSON_PATH}")
    except OSError:
        rows.append(f"# could not write {JSON_PATH}")
    return rows


def run() -> List[str]:
    """benchmarks.run entrypoint."""
    return run_bench(SMOKE_ENV)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload (<30 s CPU)")
    args = ap.parse_args()
    print("table,impl,shape,step_ms,temp_mib")
    for r in run_bench(args.smoke or SMOKE_ENV):
        print(r)


if __name__ == "__main__":
    main()
