"""Paged-attention hot-loop microbenchmark: gather-legacy vs ref vs
pallas, decode steps *and* chunked-prefill chunks.

One decode step of the continuous engine runs ``paged_decode`` per layer
and every admitted prompt runs ``paged_prefill`` per chunk per layer —
the two hottest loops in the serving path. This bench times exactly
those ops across context lengths × pool occupancy and reports XLA's
``temp_size_in_bytes`` for the compiled executable as a peak-HBM-traffic
proxy (the ``logprob_bench`` convention):

  - gather   — the legacy path: materialize the whole
               (B, pages_per_slot·page_size, Hkv, D) logical view, then
               dense attention over it. O(pool) bytes regardless of
               context.
  - ref      — ``paged_decode_ref`` / ``paged_prefill_ref``: per-page
               online softmax bounded by the live high-water mark.
               O(ceil(len/page)) bytes.
  - pallas   — the Mosaic kernels in interpret mode on CPU (compiled on
               a real TPU); benched at a reduced size — interpret mode
               pays a large python constant per grid step, but the
               memory story matches ref.

The prefill sweep varies the chunk's start offset ``c0`` (prompt already
cached) against a fixed-width table: the gather path's dense view pays
for the full table width while ref/pallas touch only
``pages_for(c0 + C)`` pages. A fused-layers section times L per-layer
launches against ONE layer-folded launch (``paged_decode_layers``).

  PYTHONPATH=src python -m benchmarks.decode_bench [--smoke]

Output: CSV rows ``decode,<impl>,ctx<L>of<pool>,<ms>,<temp MiB>`` /
``prefill,<impl>,c0<c0>+<C>of<pool>,...`` plus a ``BENCH_decode.json``
artifact (path: $BENCH_DECODE_JSON) — the serving-path perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import (paged_decode, paged_decode_layers,
                               paged_prefill)

SMOKE_ENV = os.environ.get("BENCH_SMOKE", "0") == "1"
JSON_PATH = os.environ.get("BENCH_DECODE_JSON", "BENCH_decode.json")


def _make_case(b, hkv, rep, d, page, pages_per_slot, ctx, seed=0,
               dtype=jnp.float32):
    """Engine-shaped operands: every slot holds ``ctx`` live tokens of a
    pool provisioned for ``pages_per_slot`` pages per slot.

    f32 pools so the temp proxy compares layouts, not dtype lowering:
    XLA:CPU has no native bf16 dot, and the resulting upcast is
    loop-invariant for the page-loop impls — it would charge *only*
    them a pool-sized f32 conversion that a real TPU never pays."""
    hq = hkv * rep
    pool = 1 + b * pages_per_slot
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d), dtype)
    kp = jax.random.normal(ks[1], (pool, page, hkv, d), dtype)
    vp = jax.random.normal(ks[2], (pool, page, hkv, d), dtype)
    host = np.random.default_rng(seed)
    perm = host.permutation(np.arange(1, pool))
    table = perm[:b * pages_per_slot].reshape(b, pages_per_slot)
    lengths = host.integers(max(1, ctx // 2), ctx + 1, size=b)
    return (q, kp, vp, jnp.asarray(table.astype(np.int32)),
            jnp.asarray(lengths.astype(np.int32)))


def _make_prefill_case(b, hkv, rep, d, page, pages_per_slot, c0, chunk,
                       seed=0, dtype=jnp.float32):
    """A prefill chunk mid-prompt: C queries at offset c0, every slot's
    table at the full provisioned width (the worst pow2 bucket — what a
    long prompt's tail chunks see)."""
    hq = hkv * rep
    pool = 1 + b * pages_per_slot
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, chunk, hq, d), dtype)
    kp = jax.random.normal(ks[1], (pool, page, hkv, d), dtype)
    vp = jax.random.normal(ks[2], (pool, page, hkv, d), dtype)
    host = np.random.default_rng(seed)
    perm = host.permutation(np.arange(1, pool))
    table = perm[:b * pages_per_slot].reshape(b, pages_per_slot)
    positions = c0 + np.arange(chunk, dtype=np.int32)[None]
    positions = np.broadcast_to(positions, (b, chunk))
    return (q, kp, vp, jnp.asarray(table.astype(np.int32)),
            jnp.asarray(positions))


def _temp_bytes(fn, args, **kw) -> Optional[int]:
    try:
        mem = fn.lower(*args, **kw).compile().memory_analysis()
        return int(mem.temp_size_in_bytes) if mem is not None else None
    except Exception:
        return None


def _bench_fn(fn, impl: str, args, *, reps: int, interpret=None):
    kw: Dict = {"impl": impl}
    if interpret is not None:
        kw["interpret"] = interpret
    tmp = _temp_bytes(fn, args, **kw)
    out = fn(*args, **kw)                            # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    ms = (time.perf_counter() - t0) / reps * 1e3
    return ms, tmp


def _bench(impl: str, args, *, reps: int, interpret=None):
    return _bench_fn(paged_decode, impl, args, reps=reps,
                     interpret=interpret)


def run_bench(smoke: bool) -> List[str]:
    # decode-shaped: GQA 4:1. The pool is provisioned for the
    # longest request (prompt + max_new); the sweep holds the pool fixed
    # and varies the live context, i.e. pool-over-context ratio — the
    # regime where the legacy gather pays for capacity it never reads.
    if smoke:
        b, hkv, rep, d, page = 4, 2, 4, 64, 8
        pages_per_slot, ctxs, reps = 64, (32, 128, 512), 2
        pallas_ctx = 32
    else:
        b, hkv, rep, d, page = 8, 4, 4, 128, 16
        pages_per_slot, ctxs, reps = 128, (256, 512, 2048), 3
        pallas_ctx = 64
    pool_tokens = pages_per_slot * page

    rows: List[str] = []
    records: List[Dict] = []
    temps: Dict = {}
    for ctx in ctxs:
        args = _make_case(b, hkv, rep, d, page, pages_per_slot, ctx)
        for impl in ("gather", "ref"):
            ms, tmp = _bench(impl, args, reps=reps)
            temps[(impl, ctx)] = tmp
            mib = f"{tmp / 2**20:.1f}" if tmp is not None else "n/a"
            rows.append(f"decode,{impl},ctx{ctx}of{pool_tokens},"
                        f"{ms:.1f},{mib}")
            records.append({"impl": impl, "ctx": ctx,
                            "pool_tokens": pool_tokens,
                            "batch": b, "kv_heads": hkv, "rep": rep,
                            "head_dim": d, "page_size": page,
                            "ms": round(ms, 2), "temp_bytes": tmp})
    # pallas in interpret mode: one small shape, memory story == ref.
    # The table is narrowed to the live high-water mark exactly like the
    # engine does before dispatch — the interpreter walks every grid
    # step in python, so the dead-page DMA skip doesn't save it time.
    q, kp, vp, table, lengths = _make_case(b, hkv, rep, d, page,
                                           pages_per_slot, pallas_ctx)
    args = (q, kp, vp, table[:, :max(1, -(-pallas_ctx // page))], lengths)
    ms, tmp = _bench("pallas", args, reps=1, interpret=True)
    mib = f"{tmp / 2**20:.1f}" if tmp is not None else "n/a"
    rows.append(f"decode,pallas,ctx{pallas_ctx}of{pool_tokens},"
                f"{ms:.1f},{mib} (interpret)")
    records.append({"impl": "pallas-interpret", "ctx": pallas_ctx,
                    "pool_tokens": pool_tokens, "ms": round(ms, 2),
                    "temp_bytes": tmp})

    # the headline: at >=4x pool-over-context, the in-place path must
    # beat the legacy gather on the temp-bytes proxy
    ratios = {}
    for ctx in ctxs:
        tg, tr = temps.get(("gather", ctx)), temps.get(("ref", ctx))
        if tg and tr:
            ratios[str(ctx)] = round(tg / tr, 2)
            rows.append(f"# ctx={ctx} (pool/ctx={pool_tokens/ctx:.0f}x): "
                        f"gather temp = {tg / tr:.2f}x ref temp")

    # ---- chunked prefill: chunk offset (cached prompt) sweep ----------
    # full-width tables throughout — the regime where the gather path's
    # dense view pays for table width while ref touches pages_for(c0+C)
    chunk = 16 if smoke else 64
    c0s = ((0, 64, pool_tokens - chunk) if smoke
           else (0, 512, pool_tokens - chunk))
    ptemps: Dict = {}
    for c0 in c0s:
        pargs = _make_prefill_case(b, hkv, rep, d, page, pages_per_slot,
                                   c0, chunk)
        for impl in ("gather", "ref"):
            ms, tmp = _bench_fn(paged_prefill, impl, pargs, reps=reps)
            ptemps[(impl, c0)] = tmp
            mib = f"{tmp / 2**20:.1f}" if tmp is not None else "n/a"
            rows.append(f"prefill,{impl},c0{c0}+{chunk}of{pool_tokens},"
                        f"{ms:.1f},{mib}")
            records.append({"phase": "prefill", "impl": impl, "c0": c0,
                            "chunk": chunk, "pool_tokens": pool_tokens,
                            "batch": b, "kv_heads": hkv, "rep": rep,
                            "head_dim": d, "page_size": page,
                            "ms": round(ms, 2), "temp_bytes": tmp})
    # pallas prefill in interpret mode: one small shape, memory == ref
    pc0 = c0s[0]
    pargs = _make_prefill_case(b, hkv, rep, d, page,
                               8 if smoke else 16, pc0, chunk)
    ms, tmp = _bench_fn(paged_prefill, "pallas", pargs, reps=1,
                        interpret=True)
    mib = f"{tmp / 2**20:.1f}" if tmp is not None else "n/a"
    rows.append(f"prefill,pallas,c0{pc0}+{chunk},{ms:.1f},{mib} "
                "(interpret)")
    records.append({"phase": "prefill", "impl": "pallas-interpret",
                    "c0": pc0, "chunk": chunk, "ms": round(ms, 2),
                    "temp_bytes": tmp})

    pratios = {}
    for c0 in c0s:
        tg, tr = ptemps.get(("gather", c0)), ptemps.get(("ref", c0))
        if tg and tr:
            pratios[str(c0)] = round(tg / tr, 2)
            live = c0 + chunk
            rows.append(f"# prefill c0={c0} "
                        f"(pool/live={pool_tokens/live:.0f}x): "
                        f"gather temp = {tg / tr:.2f}x ref temp")

    # ---- fused multi-layer launch: L calls vs one folded call ---------
    lyr = 2 if smoke else 4
    fb, fpps = (2, 16) if smoke else (4, 32)
    base = [_make_case(fb, hkv, rep, d, page, fpps, fpps * page // 2,
                       seed=s) for s in range(lyr)]
    qs = jnp.stack([c[0] for c in base])
    kps = jnp.stack([c[1] for c in base])
    vps = jnp.stack([c[2] for c in base])
    table_f, lengths_f = base[0][3], base[0][4]

    def looped():
        return [paged_decode(qs[l], kps[l], vps[l], table_f, lengths_f,
                             impl="ref") for l in range(lyr)]

    jax.block_until_ready(looped())
    t0 = time.perf_counter()
    for _ in range(reps):
        outs = looped()
    jax.block_until_ready(outs)
    ms_loop = (time.perf_counter() - t0) / reps * 1e3
    fargs = (qs, kps, vps, table_f, lengths_f)
    ms_fused, _ = _bench_fn(paged_decode_layers, "ref", fargs, reps=reps)
    rows.append(f"decode,ref-L{lyr}-looped,b{fb},{ms_loop:.1f},n/a")
    rows.append(f"decode,ref-L{lyr}-fused,b{fb},{ms_fused:.1f},n/a "
                f"(one launch for {lyr} layers)")
    records.append({"phase": "fused", "impl": "ref-looped", "layers": lyr,
                    "batch": fb, "ms": round(ms_loop, 2)})
    records.append({"phase": "fused", "impl": "ref-fused", "layers": lyr,
                    "batch": fb, "ms": round(ms_fused, 2)})

    out = {"bench": "decode", "unit": "ms/step+temp_bytes",
           "workload": {"batch": b, "kv_heads": hkv, "rep": rep,
                        "head_dim": d, "page_size": page,
                        "pages_per_slot": pages_per_slot,
                        "prefill_chunk": chunk,
                        "dtype": "float32", "smoke": smoke},
           "rows": records, "gather_over_ref_temp": ratios,
           "prefill_gather_over_ref_temp": pratios}
    try:
        with open(JSON_PATH, "w") as f:
            json.dump(out, f, indent=1)
        rows.append(f"# wrote {JSON_PATH}")
    except OSError:
        rows.append(f"# could not write {JSON_PATH}")
    return rows


def run() -> List[str]:
    """benchmarks.run entrypoint."""
    return run_bench(SMOKE_ENV)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload (<30 s CPU)")
    args = ap.parse_args()
    print("table,impl,shape,step_ms,temp_mib")
    for r in run_bench(args.smoke or SMOKE_ENV):
        print(r)


if __name__ == "__main__":
    main()
