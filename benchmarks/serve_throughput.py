"""Sampler-node serving throughput: static vs continuous-batching engine.

A mixed-length workload (early-EOS sequences present — the untrained
bench LM emits EOS with prob ≈ 1/vocab per step, giving geometric
completion lengths far below ``max_new``) is served two ways:

- **static**: classic batch server — requests are grouped into rounds of
  ``slots`` and each round scans to the full ``max_new`` even for rows
  that hit EOS on step 1;
- **continuous**: all requests stream through the same ``slots`` decode
  slots; EOS frees a slot (and its KV pages) for the next queued prompt.

Reported: useful tokens/s per engine, the speedup, and the continuous
engine's slot utilization. ``--smoke`` (or BENCH_SMOKE=1) shrinks the
workload to CI scale (<60 s CPU).

  PYTHONPATH=src python -m benchmarks.serve_throughput [--smoke]
"""
from __future__ import annotations

import argparse
import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RLConfig, ATTN, MLP
from repro.data import ArithmeticTask, Tokenizer, encode_prompts
from repro.models import init_params
from repro.sampling import generate, generate_continuous

SMOKE_ENV = os.environ.get("BENCH_SMOKE", "0") == "1"


def _cfg(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(name="serve-bench-smoke", family="dense",
                           num_layers=2, d_model=96, num_heads=4,
                           num_kv_heads=2, d_ff=192, vocab_size=32,
                           block_pattern=(ATTN,), ffn_pattern=(MLP,),
                           dtype="float32", attn_impl="naive", remat=False,
                           rope_theta=1e4)
    return ModelConfig(name="serve-bench", family="dense", num_layers=4,
                       d_model=256, num_heads=8, num_kv_heads=4, d_ff=512,
                       vocab_size=32, block_pattern=(ATTN,),
                       ffn_pattern=(MLP,), dtype="float32",
                       attn_impl="naive", remat=False, rope_theta=1e4)


def _bench(smoke: bool, *, requests: int, slots: int, max_new: int,
           page_size: int, seed: int, sync_every: int = 8) -> List[str]:
    cfg = _cfg(smoke)
    rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=max_new)
    tok = Tokenizer()
    task = ArithmeticTask(max_operand=99, ops="+-", prompt_width=8, seed=seed)
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    prompts = np.asarray(encode_prompts(tok, task.sample_batch(requests)))
    vocab = tok.vocab_size

    # warm both executables out of the timed region
    warm = jnp.asarray(prompts[:slots])
    kw = jax.random.fold_in(key, 999)
    np.asarray(generate(cfg, rl, params, warm, kw, max_new=max_new,
                        vocab_limit=vocab)["comp_mask"])
    np.asarray(generate_continuous(cfg, rl, params, warm, kw,
                                   max_new=max_new, vocab_limit=vocab,
                                   num_slots=slots, page_size=page_size,
                                   sync_every=sync_every)["comp_mask"])

    # static: rounds of `slots`, each scanned to max_new. A ragged last
    # round is padded back to `slots` rows (reusing row 0's prompt) so the
    # timed region never XLA-recompiles for a smaller batch shape; only
    # the real rows' tokens are counted.
    t0 = time.perf_counter()
    static_tok = 0
    for r0 in range(0, requests, slots):
        kr = jax.random.fold_in(key, r0)
        batch = prompts[r0:r0 + slots]
        real = batch.shape[0]
        if real < slots:
            batch = np.concatenate(
                [batch, np.broadcast_to(batch[:1], (slots - real,) +
                                        batch.shape[1:])])
        roll = generate(cfg, rl, params, jnp.asarray(batch),
                        kr, max_new=max_new, vocab_limit=vocab)
        static_tok += int(np.asarray(roll["comp_mask"])[:real].sum())
    t_static = time.perf_counter() - t0

    # continuous: one queue through the same number of slots
    t0 = time.perf_counter()
    roll = generate_continuous(cfg, rl, params, jnp.asarray(prompts), key,
                               max_new=max_new, vocab_limit=vocab,
                               num_slots=slots, page_size=page_size,
                               sync_every=sync_every)
    t_cont = time.perf_counter() - t0
    cont_tok = int(np.asarray(roll["comp_mask"]).sum())
    stats = roll["stats"]

    tps_static = static_tok / t_static
    tps_cont = cont_tok / t_cont
    rows = [
        f"serve_throughput,static,{static_tok},{t_static:.2f},"
        f"{tps_static:.1f},1.00",
        f"serve_throughput,continuous,{cont_tok},{t_cont:.2f},"
        f"{tps_cont:.1f},{stats['slot_utilization']:.2f}",
        f"# speedup {tps_cont / tps_static:.2f}x "
        f"(requests={requests} slots={slots} max_new={max_new} "
        f"decode_steps={stats['decode_steps']} "
        f"vs static {-(-requests // slots) * max_new})",
    ]
    return rows


def run() -> List[str]:
    """benchmarks.run entrypoint. Full scale by default (like every other
    module); smoke scale only under BENCH_SMOKE=1 / --smoke."""
    if SMOKE_ENV:
        return _bench(True, requests=12, slots=4, max_new=24,
                      page_size=8, seed=0, sync_every=4)
    return _bench(False, requests=48, slots=12, max_new=64,
                  page_size=16, seed=0, sync_every=8)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload (<60 s CPU)")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--slots", type=int, default=0)
    ap.add_argument("--max-new", type=int, default=0)
    ap.add_argument("--page-size", type=int, default=0)
    ap.add_argument("--sync-every", type=int, default=0,
                    help="decode steps per scheduler sync")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    smoke = args.smoke or SMOKE_ENV
    defaults = ((12, 4, 24, 8, 4) if smoke else (48, 12, 64, 16, 8))
    rows = _bench(smoke,
                  requests=args.requests or defaults[0],
                  slots=args.slots or defaults[1],
                  max_new=args.max_new or defaults[2],
                  page_size=args.page_size or defaults[3],
                  seed=args.seed,
                  sync_every=args.sync_every or defaults[4])
    print("table,engine,useful_tokens,seconds,tok_s,slot_util")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
