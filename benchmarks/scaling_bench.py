"""Data-parallel scaling of the sharded train step on forced host devices.

    PYTHONPATH=src python -m benchmarks.scaling_bench [--data 1,2,4]

For each data-axis size D a fresh subprocess forces
``--xla_force_host_platform_device_count=D`` (device count locks on first
jax init, so the parent never imports with the override), builds an
``ExecutionPlan`` on a (D, 1) mesh and times ``make_sharded_train_step``
over a fixed global batch. On one physical CPU all fake devices share a
core, so tokens/s is a *plumbing* benchmark (sharded-step dispatch +
collective overhead at D>1), not a speedup claim — the point is that the
same code path runs at every D and the overhead stays bounded. On real
multi-chip hardware the same harness measures true scaling.

CSV: scaling,D=<n>,tokens_per_s,step_ms
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import List

SMOKE = os.environ.get("BENCH_SMOKE", "0") == "1"
FULL = os.environ.get("BENCH_FULL", "0") == "1"


def _worker(n_data: int, steps: int, batch: int, seq: int) -> None:
    """Runs inside the subprocess (XLA_FLAGS already set by the parent)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.config import RLConfig, TrainConfig, ModelConfig, ATTN, MLP
    from repro.models import init_params
    from repro.parallel import ExecutionPlan, make_sharded_train_step
    from repro.training import init_state

    cfg = ModelConfig(name="scaling-lm", family="dense", num_layers=2,
                      d_model=96, num_heads=4, num_kv_heads=2, d_ff=192,
                      vocab_size=64, block_pattern=(ATTN,),
                      ffn_pattern=(MLP,), dtype="float32",
                      attn_impl="naive", remat=False, rope_theta=1e4)
    rl = RLConfig(loss_type="gepo", group_size=4, beta_kl=0.0)
    tc = TrainConfig(learning_rate=1e-3, total_steps=steps + 1)
    mesh = jax.make_mesh((n_data, 1), ("data", "model"))
    plan = ExecutionPlan(mesh=mesh, mode="train")

    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    b = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, 64),
        "mask": jnp.ones((batch, seq - 1)),
        "sampler_lp": -jnp.abs(jax.random.normal(ks[1], (batch, seq - 1))),
        "rewards": (jax.random.uniform(ks[2], (batch,)) > 0.5).astype(
            jnp.float32),
    }
    b = plan.device_put_batch(cfg, b)
    state = init_state(cfg, tc, init_params(cfg, ks[3]), plan=plan)
    step = make_sharded_train_step(cfg, rl, tc, plan)

    state, m = step(state, b)                      # compile + warmup
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(steps):
        state, m = step(state, b)
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    tokens = batch * (seq - 1) * steps
    print(json.dumps({"data": n_data, "tokens_per_s": tokens / dt,
                      "step_ms": 1e3 * dt / steps}))


def run(sizes=None, steps=None, batch=None, seq=None) -> List[str]:
    sizes = sizes or ([1, 2] if SMOKE else [1, 2, 4] + ([8] if FULL else []))
    steps = steps or (3 if SMOKE else 10)
    batch = batch or 16
    seq = seq or 17
    rows = ["table,setting,tokens_per_s,step_ms"]
    for d in sizes:
        env = dict(
            os.environ,
            XLA_FLAGS=f"--xla_force_host_platform_device_count={d}",
            PYTHONPATH=os.pathsep.join(
                [p for p in (os.environ.get("PYTHONPATH"),) if p]
                + [os.path.join(os.path.dirname(os.path.dirname(
                    os.path.abspath(__file__))), "src"),
                   os.path.dirname(os.path.dirname(os.path.abspath(__file__)))]))
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.scaling_bench", "--worker",
             str(d), "--steps", str(steps), "--batch", str(batch),
             "--seq", str(seq)],
            capture_output=True, text=True, env=env, timeout=420)
        if out.returncode != 0:
            raise RuntimeError(f"scaling worker D={d} failed:\n"
                               f"{out.stderr[-2000:]}")
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        rows.append(f"scaling,D={d},{rec['tokens_per_s']:.1f},"
                    f"{rec['step_ms']:.1f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", type=int, default=0,
                    help="internal: run the timed loop at this data size")
    ap.add_argument("--data", default=None,
                    help="comma-separated data-axis sizes (driver mode)")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=17)
    args = ap.parse_args()
    if args.worker:
        _worker(args.worker, args.steps or 10, args.batch, args.seq)
        return
    sizes = ([int(s) for s in args.data.split(",")] if args.data else None)
    for r in run(sizes=sizes, steps=args.steps or None):
        print(r, flush=True)


if __name__ == "__main__":
    main()
