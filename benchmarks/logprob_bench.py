"""Fused-logprob hot-path microbenchmark: naive vs chunked vs pallas.

The RL learner's inner loop is ``value_and_grad`` of a loss built on
per-token log-probs (+ entropy) of a (B·T, V) logits tensor. This bench
times exactly that — one jitted forward+backward through each
implementation at an RL-shaped workload — and reports XLA's
``temp_size_in_bytes`` for the compiled executable as a peak-memory
proxy (the naive path materializes V-sized f32 log-softmax activations
in both passes; the fused paths stream the vocabulary).

Implementations (see ``repro.kernels.ops.fused_token_logprob``):
  - naive    — materializing log-softmax (repro.core.logprob)
  - chunked  — lax.map over token chunks, custom VJP (CPU fallback)
  - pallas   — Pallas kernel pair in interpret mode (CPU container);
               on a real TPU this is the Mosaic-compiled hot path

  PYTHONPATH=src python -m benchmarks.logprob_bench [--smoke]

Output: CSV rows ``logprob,<impl>,<TxV>,<fwd+bwd ms>,<temp MiB>``.
"""
from __future__ import annotations

import argparse
import os
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ops import fused_token_logprob

Row = Tuple[List[str], float, Optional[int]]

SMOKE_ENV = os.environ.get("BENCH_SMOKE", "0") == "1"


def _step_fn(impl: str, chunk: int, block_t: int, block_v: int):
    def loss(logits, targets, w_lp, w_ent):
        lp, ent = fused_token_logprob(logits, targets, impl=impl,
                                      chunk=chunk, block_t=block_t,
                                      block_v=block_v)
        # logp and entropy both live in RL losses (policy term + bonus)
        return (w_lp * lp + w_ent * ent).sum()

    return jax.jit(jax.value_and_grad(loss))


def _temp_bytes(fn, *args) -> Optional[int]:
    try:
        mem = fn.lower(*args).compile().memory_analysis()
        return int(mem.temp_size_in_bytes) if mem is not None else None
    except Exception:
        return None


def _bench_impl(impl: str, t: int, v: int, dtype, *, reps: int,
                chunk: int, block_t: int, block_v: int) -> Row:
    """-> ([csv_row], fwd+bwd ms, XLA temp bytes or None)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    logits = (4 * jax.random.normal(ks[0], (t, v))).astype(dtype)
    targets = jax.random.randint(ks[1], (t,), 0, v)
    w_lp = jax.random.normal(ks[2], (t,))
    w_ent = 0.01 * jax.random.normal(ks[3], (t,))

    fn = _step_fn(impl, chunk, block_t, block_v)
    args = (logits, targets, w_lp, w_ent)
    tmp = _temp_bytes(fn, *args)
    out = fn(*args)                      # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    ms = (time.perf_counter() - t0) / reps * 1e3
    tmp_mib = f"{tmp / 2**20:.1f}" if tmp is not None else "n/a"
    return [f"logprob,{impl},{t}x{v},{ms:.1f},{tmp_mib}"], ms, tmp


def run_bench(smoke: bool) -> List[str]:
    # RL-shaped: T = B·S tokens of a rollout batch; bf16 logits as in
    # mixed-precision training. Interpret-mode pallas pays a large
    # python dispatch constant per tile — bench it at a reduced T so the
    # full run stays in budget (its memory story matches chunked).
    # ``chunk`` is the time↔memory knob: smaller chunks shrink the live
    # f32 set linearly but pay more sequential lax.map iterations
    # (measured at 4096×8192 bf16: chunk=256 → 2.5× less temp memory,
    # ~0.7× naive's speed; chunk=1024 → 1.7× less temp at parity speed).
    if smoke:
        t, v, reps, chunk = 512, 1024, 3, 128
        t_pallas, bt, bv = 128, 64, 256
    else:
        t, v, reps, chunk = 4096, 8192, 5, 1024
        t_pallas, bt, bv = 256, 128, 1024
    dtype = jnp.bfloat16

    rows: List[str] = []
    r, ms_naive, tmp_naive = _bench_impl("naive", t, v, dtype, reps=reps,
                                         chunk=chunk, block_t=bt, block_v=bv)
    rows += r
    r, ms_chunk, tmp_chunk = _bench_impl("chunked", t, v, dtype, reps=reps,
                                         chunk=chunk, block_t=bt, block_v=bv)
    rows += r
    r, _, _ = _bench_impl("pallas", t_pallas, v, dtype, reps=1,
                          chunk=chunk, block_t=bt, block_v=bv)
    rows += [r[0] + " (interpret)"]

    if tmp_naive and tmp_chunk:
        rows.append(f"# chunked vs naive: {ms_naive / ms_chunk:.2f}x step "
                    f"time, {tmp_naive / tmp_chunk:.2f}x temp memory "
                    f"(T={t} V={v} chunk={chunk} dtype=bf16)")
    else:
        rows.append(f"# chunked vs naive: {ms_naive / ms_chunk:.2f}x step "
                    f"time (T={t} V={v} chunk={chunk} dtype=bf16)")
    return rows


def run() -> List[str]:
    """benchmarks.run entrypoint."""
    return run_bench(SMOKE_ENV)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload (<30 s CPU)")
    args = ap.parse_args()
    print("table,impl,shape,fwd_bwd_ms,temp_mib")
    for r in run_bench(args.smoke or SMOKE_ENV):
        print(r)


if __name__ == "__main__":
    main()
