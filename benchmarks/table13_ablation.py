"""Table 13 / App. D: importance-weight granularity ablation
(token → sequence → group) plus advantage-normalization ablation and the
App.-H defensive-denominator variant (beyond-paper)."""
from __future__ import annotations

from benchmarks.common import csv_row, run_method

KEYS = ("eval_best", "eval_last", "gap", "iw_var_mean", "iw_var_max")


def run() -> list:
    rows = ["table13_ablation,variant," + ",".join(KEYS)]
    settings = [
        ("token-lv(grpo-iw)", dict(loss_type="grpo")),
        ("seq-lv(gspo-iw)", dict(loss_type="gspo")),
        ("group-lv(gepo)", dict(loss_type="gepo")),
        ("gepo_wo_adv_norm", dict(loss_type="gepo", adv_normalize=False)),
        ("gepo_smooth_0.2", dict(loss_type="gepo", gepo_smooth=0.2)),
    ]
    recs = {}
    for name, kw in settings:
        lt = kw.pop("loss_type")
        recs[name] = run_method(lt, mode="hetero", max_delay=64,
                                delay_median_s=900.0, **kw)
        rows.append(csv_row(f"table13_ablation,{name}", recs[name],
                            list(KEYS)))
    return rows
