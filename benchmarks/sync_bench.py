"""Weight-transport A/B: whole-blob npz sync vs chunked content-addressed
delta sync (repro.transport), in bytes on the wire and simulated sync
seconds.

    PYTHONPATH=src python -m benchmarks.sync_bench [--smoke]

Part A (any device count) replays a publish/sync series where part of the
model is frozen (embeddings + head — a standard RL-tuning setting): the
whole-blob path re-ships the full npz every sync, the chunked path moves
only the changed chunks, across sampler sync cadences (sync every k-th
publish).

Part B needs a >=4-device mesh (in-process when visible, e.g. under the
CI multidevice job's forced host devices; otherwise a subprocess forces
8) and checks the sharded claims end-to-end with real nodes: a
``SamplerNode`` on a *smaller* plan (1x2 serve) synced from a 2x2 train
learner gets params byte-identical to the legacy whole-blob fetch, its
fetch is a strict subset of the learner's per-shard chunk entries (and a
host-scoped subscriber a strict subset of the distinct chunks), and an
elastic re-fit onto a changed plan lands the same bytes without moving
new chunks.

CSV: sync,setting,metrics...
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import List

SMOKE = os.environ.get("BENCH_SMOKE", "0") == "1"

BANDWIDTH_MBPS = 100.0


def _tiny():
    from repro.config import ModelConfig, ATTN, MLP
    return ModelConfig(name="sync-lm", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=64, block_pattern=(ATTN,),
                       ffn_pattern=(MLP,), dtype="float32",
                       attn_impl="naive", remat=False, rope_theta=1e4)


def _perturbed(params, step: int):
    """Simulated training step that leaves embed/lm_head/final_norm
    frozen (chunked sync should skip them; whole-blob cannot)."""
    import jax
    from repro.checkpoint.store import path_key

    frozen = ("embed", "lm_head", "final_norm")

    def bump(path, leaf):
        if path_key(path).split("/")[-1] in frozen or path_key(path) in frozen:
            return leaf
        return leaf + 1e-3 * (step + 1)

    return jax.tree_util.tree_map_with_path(bump, params)


def _series_rows() -> List[str]:
    import jax
    import numpy as np

    from repro.checkpoint import PolicyStore, load_pytree, save_pytree
    from repro.config import HeteroConfig
    from repro.hetero.latency import sync_delay_s
    from repro.models import init_params
    from repro.parallel import local_plan
    from repro.transport import ChunkSubscriber, SimulatedLink, publish_params

    cfg = _tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    plan = local_plan("train")
    n_publishes = 4 if SMOKE else 8
    # the propagation term of sync_delay_s is identical for both paths, so
    # the seconds columns report the serialization term only — the part
    # the payload size actually controls at BANDWIDTH_MBPS
    hcfg = HeteroConfig(delay_distribution="constant", delay_min_s=0.0,
                        delay_median_s=0.0, bandwidth_mbps=BANDWIDTH_MBPS)
    rng = np.random.default_rng(0)

    rows = []
    for cadence in (1, 2, 4):
        store = PolicyStore()
        link = SimulatedLink(bandwidth_mbps=BANDWIDTH_MBPS)
        sub = ChunkSubscriber(store, link)
        blob_bytes = 0
        blob_seconds = 0.0
        chunk_seconds = 0.0
        p = params
        publish_stats = []
        for v in range(n_publishes):
            # the sampler joins at v0 (cold cache, full fetch) and then
            # syncs every cadence-th publish — deltas against its cache
            p = _perturbed(p, v) if v else p
            publish_stats.append(publish_params(store, v, plan, cfg, p))
            if v and v % cadence != cadence - 1:
                continue
            # chunked-delta sampler sync
            _, tree, ss = sub.sync(p, cfg=cfg, plan=local_plan("serve"))
            chunk_seconds += sync_delay_s(rng, hcfg, ss.bytes_on_wire)
            # legacy whole-blob sampler sync of the same version
            blob = save_pytree(p)
            blob_bytes += len(blob)
            blob_seconds += sync_delay_s(rng, hcfg, len(blob))
            # transport restore must stay byte-identical to the blob
            legacy = load_pytree(blob, p)
            for a, b in zip(jax.tree_util.tree_leaves(legacy),
                            jax.tree_util.tree_leaves(tree)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        chunk_bytes = link.bytes_on_wire
        assert chunk_bytes < blob_bytes, (
            f"chunked-delta sync must move strictly fewer bytes than "
            f"whole-blob on partially-unchanged publishes "
            f"({chunk_bytes} vs {blob_bytes})")
        stream_new = sum(s.bytes_new for s in publish_stats)
        stream_full = sum(s.payload_bytes for s in publish_stats)
        rows.append(
            f"sync,cadence={cadence},{blob_bytes},{chunk_bytes},"
            f"{chunk_bytes / blob_bytes:.3f},{blob_seconds:.2f},"
            f"{chunk_seconds:.2f},{stream_new}/{stream_full}")
    return (["sync,setting,blob_bytes,chunk_bytes,byte_ratio,"
             "blob_ser_s,chunk_ser_s,publish_new/full"] + rows)


def _mesh_rows() -> List[str]:
    """Sharded end-to-end checks on a 2x2 learner / 1x2 sampler; needs
    >= 4 visible devices (run under XLA_FLAGS host-device forcing)."""
    import jax
    import numpy as np

    from repro.checkpoint import PolicyStore, load_pytree, save_pytree
    from repro.config import HeteroConfig, RLConfig, TrainConfig
    from repro.data import ArithmeticTask, PromptPipeline, Tokenizer
    from repro.hetero.nodes import LearnerNode, SamplerNode
    from repro.models import init_params
    from repro.parallel import ExecutionPlan, make_debug_mesh
    from repro.training import init_state
    from repro.transport import ChunkSubscriber, Manifest

    cfg = _tiny()
    rl = RLConfig(loss_type="gepo", group_size=4, max_new_tokens=4,
                  temperature=1.0, top_k=0, top_p=1.0)
    tc = TrainConfig(learning_rate=1e-3, total_steps=8)
    hcfg = HeteroConfig(num_samplers=1, bandwidth_mbps=BANDWIDTH_MBPS)
    task = ArithmeticTask(max_operand=9, ops="+", prompt_width=5, seed=0)
    tok = Tokenizer()

    learner_plan = ExecutionPlan(mesh=make_debug_mesh(2, 2), mode="train")
    sampler_plan = ExecutionPlan(mesh=jax.make_mesh((1, 2),
                                                    ("data", "model")),
                                 mode="serve")
    state = init_state(cfg, tc, init_params(cfg, jax.random.PRNGKey(0)))
    store = PolicyStore()
    learner = LearnerNode(cfg, rl, tc, hcfg, state, store,
                          plan=learner_plan)   # publishes v0 in ctor
    pub = learner.publish_stats
    v, blob = store.fetch()
    manifest = Manifest.from_json(blob)

    # real sampler node on the smaller plan syncs through the transport
    sampler = SamplerNode(0, cfg, rl, PromptPipeline(task, tok, 4, 4),
                          task, tok, learner.state.params, store, hcfg,
                          seed=0, plan=sampler_plan)
    sampler.version = -1                       # force a fetch of v0
    moved = sampler.sync()
    # byte-identity vs the legacy whole-blob path
    host = learner.plan.host_gather(learner.state.params)
    legacy = load_pytree(save_pytree(host), host)
    for a, b in zip(jax.tree_util.tree_leaves(legacy),
                    jax.tree_util.tree_leaves(sampler.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # the sampler's fetch is a strict subset of the learner's per-shard
    # chunk entries (replica entries dedup onto content-addressed chunks)
    fetched = sampler.subscriber.chunks_fetched
    assert fetched <= manifest.num_chunks < manifest.num_entries, (
        fetched, manifest.num_chunks, manifest.num_entries)
    hashes = manifest.hashes()
    assert fetched < manifest.num_entries

    # one *host* of the sampler mesh (device column 0) needs a strict
    # subset of even the distinct chunks: model-sharded leaves contribute
    # only their first column
    scoped = ChunkSubscriber(store)
    need = scoped.needed_refs(manifest, plan=sampler_plan, cfg=cfg,
                              devices=[sampler_plan.mesh.devices[0, 0]])
    scoped_hashes = {r.hash for _, refs in need for r in refs}
    assert scoped_hashes < hashes, (len(scoped_hashes), len(hashes))

    # elastic re-fit: the same version lands on a *changed* plan from the
    # local cache (no new chunk bytes), byte-identical again
    refit_plan = ExecutionPlan(mesh=jax.make_mesh((2, 1),
                                                  ("data", "model")),
                               mode="serve")
    before = sampler.subscriber.chunks_fetched
    sampler.sync(plan=refit_plan)
    for a, b in zip(jax.tree_util.tree_leaves(legacy),
                    jax.tree_util.tree_leaves(sampler.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert sampler.subscriber.chunks_fetched == before, \
        "re-fit must come from the chunk cache, not the wire"
    assert sampler.params["embed"].sharding.mesh == refit_plan.mesh

    blob_bytes = len(save_pytree(host))
    return [
        "sync,setting,chunks,entries,hashes,fetched,scoped_hashes,"
        "payload_bytes,blob_bytes,max_host_egress,sampler_wire_bytes",
        f"sync,mesh_2x2_to_1x2,{manifest.num_chunks},"
        f"{manifest.num_entries},{len(hashes)},{fetched},"
        f"{len(scoped_hashes)},{manifest.payload_bytes},{blob_bytes},"
        f"{pub.max_host_egress},{moved}",
    ]


def run() -> List[str]:
    import jax
    rows = _series_rows()
    if len(jax.devices()) >= 4:
        rows += _mesh_rows()
    else:
        rows += _mesh_rows_subprocess()
    return rows


def _mesh_rows_subprocess() -> List[str]:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        PYTHONPATH=os.pathsep.join(
            [p for p in (os.environ.get("PYTHONPATH"),) if p]
            + [os.path.join(repo, "src"), repo]))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.sync_bench", "--mesh-worker"],
        capture_output=True, text=True, env=env, timeout=420)
    if out.returncode != 0:
        raise RuntimeError(f"sync_bench mesh worker failed:\n"
                           f"{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh-worker", action="store_true",
                    help="internal: emit the mesh rows as JSON")
    args = ap.parse_args()
    if args.smoke:
        os.environ["BENCH_SMOKE"] = "1"
        global SMOKE
        SMOKE = True
    if args.mesh_worker:
        print(json.dumps(_mesh_rows()))
        return
    for r in run():
        print(r, flush=True)


if __name__ == "__main__":
    main()
