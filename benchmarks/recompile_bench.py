"""Recompile sentinel benchmark: the engine's O(log)-executables claim.

Drives the continuous engine through a mixed-length workload twice under
:class:`repro.analysis.sentinel.RecompileSentinel` and *asserts* the
PR-5 claim the static analyzer (RA002) can only approximate: the cold
epoch compiles at most the pow2-bucketed executable set, and a steady
epoch — the shape distribution already seen — compiles exactly nothing.
A failure here means someone re-introduced a per-call shape (the
recompile storm the bucketed block-table narrowing exists to prevent).

  PYTHONPATH=src python -m benchmarks.recompile_bench [--smoke]

Output: CSV rows ``recompile,<epoch>,compiles<n>,bound<b>,<steps>,<s>``.
"""
from __future__ import annotations

import argparse
import os
import time
from typing import List

import jax
import numpy as np

from repro.analysis.sentinel import RecompileSentinel, pow2_bucket_count
from repro.config import ATTN, MLP, ModelConfig, RLConfig
from repro.models import init_params
from repro.sampling import ContinuousEngine
from repro.serving.api import Request, SamplingParams

SMOKE_ENV = os.environ.get("BENCH_SMOKE", "0") == "1"

TINY = ModelConfig(name="bench-lm", family="dense", num_layers=2,
                   d_model=96, num_heads=4, num_kv_heads=2, d_ff=192,
                   vocab_size=32, block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)

NUM_SLOTS = 4
PREFILL_CHUNK = 4


def _workload(rng, n_requests: int, max_total: int, rid0: int,
              rl: RLConfig) -> List[Request]:
    """Mixed prompt lengths and token budgets spanning the page buckets."""
    reqs = []
    for i in range(n_requests):
        mnew = int(rng.integers(2, 9))
        plen = int(rng.integers(2, max_total - mnew))
        prompt = rng.integers(3, 20, size=plen)
        reqs.append(Request(rid=rid0 + i, prompt=prompt,
                            params=SamplingParams.from_rl(rl, max_new=mnew)))
    return reqs


def run(smoke: bool = SMOKE_ENV) -> List[str]:
    n_requests = 12 if smoke else 48
    max_total = 32
    rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=8)
    params = init_params(TINY, jax.random.PRNGKey(0))
    eng = ContinuousEngine(TINY, params, rl=rl, max_total_tokens=max_total,
                           num_slots=NUM_SLOTS, page_size=4, sync_every=2,
                           prefill_chunk=PREFILL_CHUNK, vocab_limit=20,
                           prefix_cache=False, key=jax.random.PRNGKey(1))
    buckets = pow2_bucket_count(eng.pages_per_slot)
    # two jitted chunk families (prefill, decode) x width buckets, plus
    # the eager per-(slot, chunk-offset) last-logits scatter and a few
    # one-off convert/fill executables — see tests/test_recompile.py
    cold_bound = 2 * buckets + NUM_SLOTS * PREFILL_CHUNK + 8

    rows = []
    # the *same* shape mix both epochs: epoch 2 must be all cache hits
    for epoch, (rid0, bound) in enumerate([(0, cold_bound), (1000, 0)]):
        rng = np.random.default_rng(7)       # same draws, fresh rids
        t0 = time.perf_counter()
        with RecompileSentinel(f"epoch{epoch}") as s:
            results = eng.generate(_workload(rng, n_requests, max_total,
                                             rid0, rl),
                                   key=jax.random.PRNGKey(2))
        dt = time.perf_counter() - t0
        assert len(results) == n_requests
        s.assert_bound(bound, f"epoch{epoch} ({'cold' if epoch == 0 else 'steady'})")
        steps = int(eng.stats()["decode_steps"])
        rows.append(f"recompile,epoch{epoch},"
                    f"compiles{s.compiles},bound{bound},"
                    f"decode_steps{steps},{dt:.2f}s")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for r in run(smoke=args.smoke or SMOKE_ENV):
        print(r)


if __name__ == "__main__":
    main()
