"""Fig. 2: Var[p/q] vs Var[p/Ê_q[q]] under Bernoulli and Gaussian
parameter grids — analytic, validates the paper's variance-reduction
geometry (GEIW wins in the high-KL regime; a small region where it
loses is expected and reported)."""
from __future__ import annotations

import numpy as np

from repro.core import theory


def run() -> list:
    rows = ["fig2,setting,frac_gepo_wins,max_gap_highkl,min_gap_lowkl"]
    # Bernoulli grid
    grid = np.linspace(0.05, 0.95, 19)
    wins, gaps_hi, gaps_lo = [], [], []
    for a in grid:
        for b in grid:
            v_std, v_new = theory.bernoulli_vars(a, b)
            kl = theory.kl(np.array([1 - a, a]), np.array([1 - b, b]))
            gap = v_std - v_new
            wins.append(gap > 0)
            (gaps_hi if kl > 1.0 else gaps_lo).append(gap)
    rows.append(f"fig2,bernoulli,{np.mean(wins):.4f},"
                f"{max(gaps_hi):.4g},{min(gaps_lo):.4g}")
    assert all(g > 0 for g in gaps_hi), "GEIW must win in every high-KL cell"

    # Gaussian grid
    wins, gaps_hi, gaps_lo = [], [], []
    for d in np.linspace(0.1, 4.0, 16):
        v_std, v_new, kl = theory.gaussian_vars(0.0, d)
        gap = v_std - v_new
        wins.append(gap > 0)
        (gaps_hi if kl > 1.0 else gaps_lo).append(gap)
    rows.append(f"fig2,gaussian,{np.mean(wins):.4f},"
                f"{max(gaps_hi):.4g},{min(gaps_lo):.4g}")
    return rows
