"""Serving SLO latency under open-loop load: the front-door trajectory.

Drives the admission-controlled continuous engine with open-loop arrival
processes (requests arrive on a wall-clock schedule whether or not the
server keeps up — the serving-literature convention that exposes queueing
delay, unlike closed-loop drivers that self-throttle):

- **poisson** — exponential interarrivals at a rate near the engine's
  service capacity; the steady-state scenario;
- **bursty** — groups of simultaneous arrivals separated by idle gaps;
  the admission-control stress scenario (queue + page-pool pressure);
- **overload** — one burst far beyond the pool's overcommit budget with
  shedding forced tight (``queue_overcommit=1``): the door must reject
  the excess at arrival, and every request it *does* admit must finish.

Both scenarios share a system-prompt prefix across most requests
(``PREFIX_SHARE``), so the shared-prefix KV page reuse path carries the
prefill load: the *effective prefill throughput* ratio reported per
scenario is (prompt tokens admitted) / (prompt tokens actually
prefilled) — ≥ 2x at high prefix share is the acceptance bar.

Reported per scenario: p50/p99 TTFT, p50/p99 end-to-end latency,
tokens/s/slot, slot utilization, prefill-reuse ratio, admission
rejections by reason. Invariants asserted, not just reported: every
admitted request finishes (eos/length — admission reserves the full page
budget, so nothing is ever dropped mid-decode) and the page pool is
balanced after drain (frees match allocations net of cache-held pages).

  PYTHONPATH=src python -m benchmarks.serve_latency [--smoke]

Output: CSV rows ``serve_lat,<scenario>,<metrics...>`` plus a
``BENCH_serve.json`` artifact (path: $BENCH_SERVE_JSON) — the serving
SLO datapoint of the perf trajectory.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.config import ATTN, MLP, ModelConfig, RLConfig, ServeConfig
from repro.models import init_params
from repro.sampling import build_engine
from repro.serving import AdmissionController, ServeTelemetry
from repro.serving.api import Request, SamplingParams

SMOKE_ENV = os.environ.get("BENCH_SMOKE", "0") == "1"
JSON_PATH = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")

PREFIX_SHARE = 0.9          # fraction of requests carrying the system prompt


def _cfg(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(name="serve-lat-smoke", family="dense",
                           num_layers=2, d_model=96, num_heads=4,
                           num_kv_heads=2, d_ff=192, vocab_size=32,
                           block_pattern=(ATTN,), ffn_pattern=(MLP,),
                           dtype="float32", attn_impl="naive", remat=False,
                           rope_theta=1e4)
    return ModelConfig(name="serve-lat", family="dense", num_layers=4,
                       d_model=256, num_heads=8, num_kv_heads=4, d_ff=512,
                       vocab_size=32, block_pattern=(ATTN,),
                       ffn_pattern=(MLP,), dtype="float32",
                       attn_impl="naive", remat=False, rope_theta=1e4)


def _make_prompts(n: int, prefix_len: int, tail_len: int,
                  rng: np.random.Generator) -> List[np.ndarray]:
    """``PREFIX_SHARE`` of the prompts start with one shared system
    prefix; the rest are fully unique. The share is assigned
    deterministically (every k-th prompt is unique) rather than sampled —
    small scenarios would otherwise swing the realized share enough to
    move the headline reuse ratio. Tokens stay in [4, 30) — clear of the
    PAD/BOS/EOS specials."""
    sys_prefix = rng.integers(4, 30, size=prefix_len).astype(np.int32)
    stride = max(2, round(1.0 / (1.0 - PREFIX_SHARE)))
    prompts = []
    for i in range(n):
        tail = rng.integers(4, 30, size=tail_len).astype(np.int32)
        if i % stride == stride - 1:
            prompts.append(rng.integers(4, 30,
                                        size=prefix_len + tail_len
                                        ).astype(np.int32))
        else:
            prompts.append(np.concatenate([sys_prefix, tail]))
    return prompts


def _poisson_schedule(n: int, mean_gap_s: float,
                      rng: np.random.Generator) -> List[float]:
    return list(np.cumsum(rng.exponential(mean_gap_s, size=n)))


def _bursty_schedule(bursts: int, burst_size: int,
                     gap_s: float) -> List[float]:
    return [b * gap_s for b in range(bursts) for _ in range(burst_size)]


def _drive(engine, serve: ServeConfig, arrivals: List[float],
           prompts: List[np.ndarray], sp: SamplingParams
           ) -> Tuple[ServeTelemetry, AdmissionController]:
    """Open-loop driver: submit each request when its arrival time
    passes (admission-checked), step the engine, collect completions.
    All timestamps are relative to the drive start, one clock end to
    end, so TTFT includes queueing delay."""
    admission = AdmissionController(serve, engine)
    telemetry = ServeTelemetry(serve.num_slots)
    schedule = sorted(zip(arrivals, range(len(prompts))))
    live: set = set()
    i = 0
    t0 = time.perf_counter()
    while i < len(schedule) or engine.has_work() or live:
        now = time.perf_counter() - t0
        while i < len(schedule) and schedule[i][0] <= now:
            t_arr, idx = schedule[i]
            i += 1
            req = Request(rid=idx, prompt=prompts[idx], params=sp,
                          arrival_s=t_arr)
            if admission.check(req, now_s=now):
                engine.submit(req)
                live.add(idx)
        if not engine.has_work():
            if i < len(schedule):            # idle until the next arrival
                time.sleep(min(schedule[i][0] - now, 0.002)
                           if schedule[i][0] > now else 0)
            continue
        for ev in engine.step(now):
            if ev.finished:
                res = engine.pop_result(ev.rid)
                telemetry.record(res, done_s=now)
                live.discard(ev.rid)
    return telemetry, admission


def _scenario_row(name: str, snap: Dict[str, float], reuse: float,
                  util: float, rejected: int) -> str:
    return (f"serve_lat,{name},"
            f"ttft_p50_ms={1e3 * snap['ttft_p50_s']:.1f},"
            f"ttft_p99_ms={1e3 * snap['ttft_p99_s']:.1f},"
            f"lat_p99_ms={1e3 * snap['latency_p99_s']:.1f},"
            f"tok_s_slot={snap['tokens_per_s_per_slot']:.1f},"
            f"prefill_reuse={reuse:.2f}x,"
            f"slot_util={util:.2f},"
            f"rejected={rejected}")


def run(smoke: bool = None) -> List[str]:
    smoke = SMOKE_ENV if smoke is None else smoke
    seed = 0
    rng = np.random.default_rng(seed)
    cfg = _cfg(smoke)
    prefix_len, tail_len = (16, 4) if smoke else (48, 8)
    max_new = 8 if smoke else 16
    n_poisson = 12 if smoke else 64
    bursts, burst_size = (3, 5) if smoke else (6, 12)
    mean_gap = 0.02 if smoke else 0.01
    burst_gap = 0.15 if smoke else 0.25

    rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0,
                  max_new_tokens=max_new, engine="continuous")
    sp = SamplingParams.from_rl(rl)
    serve = ServeConfig(
        num_slots=2 if smoke else 4, page_size=4 if smoke else 16,
        sync_every=4 if smoke else 8,
        max_total_tokens=prefix_len + tail_len + max_new,
        max_queue=64, seed=seed)
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)

    rows: List[str] = []
    artifact: Dict[str, Dict] = {
        "meta": {"smoke": smoke, "prefix_share": PREFIX_SHARE,
                 "prefix_len": prefix_len, "tail_len": tail_len,
                 "max_new": max_new, "num_slots": serve.num_slots,
                 "page_size": serve.page_size}}

    # overload: one burst far past the shedding budget, shedding forced
    # tight — the pool holds num_slots turns' worth, the burst asks for
    # several times that
    n_overload = 24 if smoke else 96
    overload = dataclasses.replace(serve, queue_overcommit=1.0,
                                   max_queue=n_overload)
    scenarios = [
        ("poisson", serve, _poisson_schedule(n_poisson, mean_gap, rng),
         _make_prompts(n_poisson, prefix_len, tail_len, rng), False),
        ("bursty", serve, _bursty_schedule(bursts, burst_size, burst_gap),
         _make_prompts(bursts * burst_size, prefix_len, tail_len, rng),
         False),
        ("overload", overload, [0.0] * n_overload,
         _make_prompts(n_overload, prefix_len, tail_len, rng), True),
    ]
    for name, sv, arrivals, prompts, expect_shed in scenarios:
        engine = build_engine(cfg, params, sv, rl=rl,
                              vocab_limit=cfg.vocab_size,
                              key=jax.random.fold_in(key, hash(name) % 997))
        # warm executables outside the timed region (one tiny request)
        engine.generate([Request(rid=10_000,
                                 prompt=prompts[0][:prefix_len + tail_len],
                                 params=sp)])
        engine.prefix_cache.clear()
        telemetry, admission = _drive(engine, sv, arrivals, prompts, sp)
        st = engine.stats()
        # -- invariants, not vibes ------------------------------------
        # 1) every admitted request ran to completion: admission reserves
        #    the full page budget, so bursts can never force a mid-decode
        #    drop (the warmup request is the +1)
        assert st["completed"] == st["admitted"] == \
            telemetry.completed + 1, (st, telemetry.completed)
        # 2) the pool balances after drain: every page either free or
        #    held by the prefix cache
        cache_held = len({pg for ent in engine.prefix_cache._entries.values()
                          for pg in ent.pages})
        assert engine.free_pages + cache_held == engine.num_pages - 1, \
            (engine.free_pages, cache_held, engine.num_pages)
        snap = telemetry.snapshot()
        reuse = ((st["prefill_tokens"] + st["prefix_tokens_reused"])
                 / max(st["prefill_tokens"], 1))
        rows.append(_scenario_row(name, snap, reuse,
                                  st["slot_utilization"],
                                  admission.rejected_total))
        artifact[name] = {"slo": snap, "rejected": dict(admission.rejected),
                          "prefill_reuse": reuse,
                          "engine": {k: st[k] for k in
                                     ("admitted", "completed", "expired",
                                      "prefill_tokens",
                                      "prefix_tokens_reused", "prefix_hits",
                                      "cow_copies", "decode_steps",
                                      "slot_utilization")}}
        if expect_shed:
            # 3) the door shed load at arrival — and *only* at arrival:
            #    nothing admitted was dropped (checked by invariant 1)
            assert admission.rejected["overloaded"] > 0, admission.rejected
        else:
            # 3) the headline: shared prefixes must at least double
            #    effective prefill throughput at this prefix share
            assert reuse >= 2.0, f"{name}: prefill reuse {reuse:.2f}x < 2x"

    # speculative decoding behind the front door: the same Poisson drive
    # with spec_k=4 on a greedy profile (spec needs its own engine — one
    # sampling profile per engine). The serving invariants must hold
    # unchanged under draft/verify/rollback — admission reserves the full
    # page budget, so completed == admitted even when rounds commit a
    # variable number of tokens — and the accept rate must flow through
    # stats() so /metrics exports it.
    rl_spec = RLConfig(temperature=1.0, top_k=1, top_p=1.0,
                       max_new_tokens=max_new, engine="continuous")
    sp_spec = SamplingParams.from_rl(rl_spec)
    sv = dataclasses.replace(serve, spec_k=4)
    engine = build_engine(cfg, params, sv, rl=rl_spec,
                          vocab_limit=cfg.vocab_size,
                          key=jax.random.fold_in(key, 131))
    engine.generate([Request(rid=10_000,
                             prompt=prompts[0][:prefix_len + tail_len],
                             params=sp_spec)])
    engine.prefix_cache.clear()
    spec_prompts = _make_prompts(n_poisson, prefix_len, tail_len, rng)
    telemetry, admission = _drive(
        engine, sv, _poisson_schedule(n_poisson, mean_gap, rng),
        spec_prompts, sp_spec)
    st = engine.stats()
    assert st["completed"] == st["admitted"] == telemetry.completed + 1, \
        (st, telemetry.completed)
    cache_held = len({pg for ent in engine.prefix_cache._entries.values()
                      for pg in ent.pages})
    assert engine.free_pages + cache_held == engine.num_pages - 1, \
        (engine.free_pages, cache_held, engine.num_pages)
    assert st["spec_rounds"] + st["spec_fallback_chunks"] > 0, st
    snap = telemetry.snapshot()
    rows.append(f"serve_lat,poisson_spec,"
                f"ttft_p50_ms={1e3 * snap['ttft_p50_s']:.1f},"
                f"lat_p99_ms={1e3 * snap['latency_p99_s']:.1f},"
                f"tok_s_slot={snap['tokens_per_s_per_slot']:.1f},"
                f"accept_rate={st['accept_rate']:.2f},"
                f"drafted={int(st['drafted_tokens_total'])}")
    artifact["poisson_spec"] = {
        "slo": snap, "rejected": dict(admission.rejected),
        "spec": {k: st[k] for k in
                 ("accept_rate", "draft_hit_rate", "drafted_tokens_total",
                  "accepted_tokens_total", "spec_rounds",
                  "spec_fallback_chunks", "admitted", "completed")}}
    try:
        with open(JSON_PATH, "w") as f:
            json.dump(artifact, f, indent=1)
        rows.append(f"# wrote {JSON_PATH}")
    except OSError:
        rows.append(f"# could not write {JSON_PATH}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for row in run(smoke=args.smoke or SMOKE_ENV):
        print(row, flush=True)


if __name__ == "__main__":
    main()
