"""Optimizers in pure JAX: AdamW (paper's setting) and Adafactor
(factored second moment — the production choice for the largest MoE
configs, where full Adam state does not fit 16 GB/chip HBM; see
EXPERIMENTS.md §Dry-run memory notes)."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any, dtype=jnp.float32) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(z, params),
                      v=jax.tree_util.tree_map(z, params))


def adamw_update(tc: TrainConfig, grads: Any, state: AdamWState, params: Any,
                 lr: jax.Array) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    b1, b2, eps = tc.b1, tc.b2, tc.eps
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if tc.weight_decay:
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p, strict=True)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


# --------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), factored second moment, no first moment.


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any      # row stats (for >=2D leaves) or full v (1D)
    vc: Any      # col stats (zeros placeholder for 1D)


def _factored(p: jax.Array) -> bool:
    return p.ndim >= 2


def adafactor_init(params: Any) -> AdafactorState:
    def r(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                else jnp.zeros(p.shape, jnp.float32))

    def c(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p) else jnp.zeros((1,), jnp.float32))

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree_util.tree_map(r, params),
                          vc=jax.tree_util.tree_map(c, params))


def adafactor_update(tc: TrainConfig, grads: Any, state: AdafactorState,
                     params: Any, lr: jax.Array,
                     decay: float = 0.999) -> Tuple[Any, AdafactorState]:
    step = state.step + 1
    eps = 1e-30

    def upd(g, vr, vc, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if _factored(p):
            vr = decay * vr + (1 - decay) * g2.mean(axis=-1)
            vc = decay * vc + (1 - decay) * g2.mean(axis=-2)
            r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
            denom = jnp.sqrt(r[..., None] * vc[..., None, :])
        else:
            vr = decay * vr + (1 - decay) * g2
            denom = jnp.sqrt(vr)
        delta = g32 / jnp.maximum(denom, eps)
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(delta * delta) + eps)
        delta = delta / jnp.maximum(1.0, rms)
        if tc.weight_decay:
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), vr, vc

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(state.vr)
    flat_c = tdef.flatten_up_to(state.vc)
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, r, c, p) for g, r, c, p
           in zip(flat_g, flat_r, flat_c, flat_p, strict=True)]
    return (tdef.unflatten([o[0] for o in out]),
            AdafactorState(step=step,
                           vr=tdef.unflatten([o[1] for o in out]),
                           vc=tdef.unflatten([o[2] for o in out])))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree_util.tree_map(lambda l: l * scale.astype(l.dtype),
                                  tree), n
