"""Learning-rate schedules. The paper uses 1e-6 with 3% linear warmup."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import TrainConfig


def warmup_schedule(tc: TrainConfig, step) -> jnp.ndarray:
    warm = max(int(tc.warmup_frac * tc.total_steps), 1)
    s = jnp.asarray(step, jnp.float32)
    frac = jnp.minimum((s + 1.0) / warm, 1.0)   # first step has lr > 0
    return jnp.asarray(tc.learning_rate, jnp.float32) * frac


def cosine_schedule(tc: TrainConfig, step, final_frac: float = 0.1
                    ) -> jnp.ndarray:
    warm = max(int(tc.warmup_frac * tc.total_steps), 1)
    s = jnp.asarray(step, jnp.float32)
    lr = jnp.asarray(tc.learning_rate, jnp.float32)
    warm_lr = lr * jnp.minimum(s / warm, 1.0)
    t = jnp.clip((s - warm) / max(tc.total_steps - warm, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(s < warm, warm_lr, lr * cos)
