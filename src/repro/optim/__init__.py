from repro.optim.adamw import (AdafactorState, AdamWState, adafactor_init,
                               adafactor_update, adamw_init, adamw_update,
                               clip_by_global_norm, global_norm)
from repro.optim.schedule import cosine_schedule, warmup_schedule

__all__ = ["AdamWState", "adamw_init", "adamw_update", "AdafactorState",
           "adafactor_init", "adafactor_update", "clip_by_global_norm",
           "global_norm", "warmup_schedule", "cosine_schedule"]
