"""Span tracer: one timeline vocabulary for live and simulated runs.

``tracer.span("prefill", slot=3)`` opens a duration span on the current
*track* (a logical timeline — "learner", "sampler-0", or the OS thread
name by default); spans nest per track through a thread-local stack, and
a span that raises still closes and records its duration plus the
exception type. Events accumulate in a bounded ring buffer and export as
Chrome-trace/Perfetto JSON or a JSONL event log (``repro.obs.export``).

The clock is pluggable: ``time.perf_counter`` for real runs, or any
zero-arg callable — ``use_sim(sim)`` points it at an
:class:`~repro.hetero.events.EventSim`'s virtual ``now``, so a
discrete-event hetero run emits the *same* trace format as a live one
(simulated seconds on the x-axis instead of wall seconds). For scheduled
work whose duration is known to the simulator rather than measured,
``complete(name, start_s, end_s)`` records an explicitly-timed span.

Zero-cost contract: a disabled tracer's ``span()`` returns a shared
no-op singleton — no allocation, no clock read; mutators check
``enabled`` first. The ring buffer (``deque(maxlen=...)``) bounds memory
on long-lived servers; the oldest events fall off.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

DEFAULT_MAX_EVENTS = 200_000


class _NoopSpan:
    """Shared do-nothing span for the disabled fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP_SPAN = _NoopSpan()


class Span:
    """Open duration span; records a complete ("X") event on exit —
    including the exceptional exit, which additionally tags the event
    with the exception type so failed phases are visible in the trace."""

    __slots__ = ("_tracer", "name", "args", "track", "t0")

    def __init__(self, tracer: "Tracer", name: str, track: Optional[str],
                 args: Dict[str, Any]) -> None:
        self._tracer = tracer
        self.name = name
        self.track = track
        self.args = args
        self.t0 = 0.0

    def __enter__(self) -> "Span":
        self.t0 = self._tracer.now()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        tr = self._tracer
        t1 = tr.now()
        args = self.args
        if exc_type is not None:
            args = dict(args)
            args["error"] = exc_type.__name__
        tr._emit({"ph": "X", "name": self.name, "ts": self.t0,
                  "dur": max(t1 - self.t0, 0.0),
                  "track": self.track or tr.current_track(), "args": args})
        return False                      # never swallow the exception


class _TrackCtx:
    __slots__ = ("_tracer", "_name")

    def __init__(self, tracer: "Tracer", name: str) -> None:
        self._tracer = tracer
        self._name = name

    def __enter__(self) -> None:
        self._tracer._track_stack().append(self._name)

    def __exit__(self, *exc) -> bool:
        stack = self._tracer._track_stack()
        if stack:
            stack.pop()
        return False


class Tracer:
    """Bounded event recorder with a pluggable clock; see module doc."""

    def __init__(self, enabled: bool = False,
                 clock: Callable[[], float] = time.perf_counter,
                 max_events: int = DEFAULT_MAX_EVENTS) -> None:
        self.enabled = enabled
        self.clock = clock
        # deque.append is atomic under the GIL — sampler threads and the
        # learner emit concurrently without a lock on the hot path
        self._events: deque = deque(maxlen=max_events)
        self._tls = threading.local()
        self._aid = 0                     # async-flow id source
        self._aid_lock = threading.Lock()

    # -- clock ---------------------------------------------------------
    def now(self) -> float:
        return self.clock()

    def set_clock(self, clock: Callable[[], float]) -> None:
        self.clock = clock

    def use_wall_clock(self) -> None:
        self.clock = time.perf_counter

    def use_sim(self, sim: Any) -> None:
        """Read timestamps from a discrete-event sim's virtual clock
        (anything with a float ``now`` attribute)."""
        self.clock = lambda: sim.now

    # -- track (logical timeline) context ------------------------------
    def _track_stack(self) -> List[str]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = []
            self._tls.stack = stack
        return stack

    def current_track(self) -> str:
        stack = self._track_stack()
        return stack[-1] if stack else threading.current_thread().name

    def track(self, name: str) -> _TrackCtx:
        """Context manager: spans opened inside land on track ``name``."""
        return _TrackCtx(self, name)

    def set_track(self, name: str) -> None:
        """Pin the current thread's default track (worker-loop entry)."""
        self._tls.stack = [name]

    # -- emitters --------------------------------------------------------
    def _emit(self, ev: Dict[str, Any]) -> None:
        self._events.append(ev)

    def span(self, name: str, track: Optional[str] = None, **args):
        """Open a duration span (context manager). No-op when disabled."""
        if not self.enabled:
            return _NOOP_SPAN
        return Span(self, name, track, args)

    def instant(self, name: str, track: Optional[str] = None,
                **args) -> None:
        if not self.enabled:
            return
        self._emit({"ph": "i", "name": name, "ts": self.now(),
                    "track": track or self.current_track(), "args": args})

    def complete(self, name: str, start_s: float, end_s: float,
                 track: Optional[str] = None, **args) -> None:
        """Explicitly-timed span — scheduled work whose duration the
        simulator knows (a learner-step window, a WAN transfer)."""
        if not self.enabled:
            return
        self._emit({"ph": "X", "name": name, "ts": start_s,
                    "dur": max(end_s - start_s, 0.0),
                    "track": track or self.current_track(), "args": args})

    def next_flow_id(self) -> int:
        with self._aid_lock:
            self._aid += 1
            return self._aid

    def async_begin(self, name: str, flow_id: int, cat: str = "flow",
                    ts: Optional[float] = None, track: Optional[str] = None,
                    **args) -> None:
        """Async-flow begin ("b"): overlapping operations (chunk fetches
        in flight) that don't nest on a single track."""
        if not self.enabled:
            return
        self._emit({"ph": "b", "name": name, "id": flow_id, "cat": cat,
                    "ts": self.now() if ts is None else ts,
                    "track": track or self.current_track(), "args": args})

    def async_end(self, name: str, flow_id: int, cat: str = "flow",
                  ts: Optional[float] = None, track: Optional[str] = None,
                  **args) -> None:
        if not self.enabled:
            return
        self._emit({"ph": "e", "name": name, "id": flow_id, "cat": cat,
                    "ts": self.now() if ts is None else ts,
                    "track": track or self.current_track(), "args": args})

    # -- access ----------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        return list(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def clear(self) -> None:
        self._events.clear()
