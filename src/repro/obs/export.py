"""Trace/metrics exporters: Chrome-trace (Perfetto) JSON and JSONL.

The tracer records events with float-second timestamps and logical
*track* names; export maps tracks onto Chrome-trace ``tid`` integers
(first-seen order) with ``thread_name`` metadata so Perfetto labels each
timeline "learner", "sampler-0", "engine", … Timestamps convert to the
format's microseconds.

``validate_chrome_trace`` is the smoke-test half: it re-parses an
exported file and checks the structural contract Perfetto needs
(``traceEvents`` list; every event carries ``name``/``ph``/``ts``;
duration events carry ``dur``; async events carry ``id``), returning the
event count so callers can assert non-emptiness.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from repro.obs.trace import Tracer

_DUR_PH = {"X"}
_ASYNC_PH = {"b", "n", "e"}


def chrome_trace(tracer: Tracer, process_name: str = "repro"
                 ) -> Dict[str, Any]:
    """The tracer's events as a Chrome-trace JSON object."""
    tids: Dict[str, int] = {}
    out: List[Dict[str, Any]] = []
    for ev in tracer.events():
        track = str(ev.get("track", "main"))
        tid = tids.get(track)
        if tid is None:
            tid = tids[track] = len(tids) + 1
        ce: Dict[str, Any] = {"name": ev["name"], "ph": ev["ph"],
                              "ts": round(ev["ts"] * 1e6, 3),
                              "pid": 1, "tid": tid}
        if "dur" in ev:
            ce["dur"] = round(ev["dur"] * 1e6, 3)
        if "id" in ev:
            ce["id"] = ev["id"]
        if "cat" in ev:
            ce["cat"] = ev["cat"]
        if ev["ph"] == "i":
            ce["s"] = "t"                # instant scope: thread
        if ev.get("args"):
            ce["args"] = {k: v for k, v in ev["args"].items()}
        out.append(ce)
    meta: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": process_name}}]
    for track, tid in tids.items():
        meta.append({"name": "thread_name", "ph": "M", "pid": 1,
                     "tid": tid, "args": {"name": track}})
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def write_chrome_trace(tracer: Tracer, path: str,
                       process_name: str = "repro") -> int:
    """Write the Perfetto-loadable trace file; returns the event count
    (excluding metadata)."""
    obj = chrome_trace(tracer, process_name)
    with open(path, "w") as f:
        json.dump(obj, f)
    return sum(1 for e in obj["traceEvents"] if e["ph"] != "M")


def write_jsonl(tracer: Tracer, path: str) -> int:
    """One JSON object per line, raw tracer vocabulary (float seconds,
    track names) — the grep/pandas-friendly event log."""
    events = tracer.events()
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev) + "\n")
    return len(events)


def validate_chrome_trace(path: str) -> int:
    """Parse ``path`` and check the Chrome-trace structural contract;
    returns the non-metadata event count. Raises ``ValueError`` on any
    malformation (the CI smoke gate for exported traces)."""
    with open(path) as f:
        obj = json.load(f)
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError(f"{path}: not a Chrome-trace object "
                         "(missing traceEvents)")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError(f"{path}: traceEvents is not a list")
    n = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"{path}: event {i} is not an object")
        for field in ("name", "ph"):
            if field not in ev:
                raise ValueError(f"{path}: event {i} missing {field!r}")
        ph = ev["ph"]
        if ph == "M":
            continue
        n += 1
        if "ts" not in ev:
            raise ValueError(f"{path}: event {i} ({ev['name']}) missing ts")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"{path}: event {i} ts not numeric")
        if ph in _DUR_PH and not isinstance(ev.get("dur"), (int, float)):
            raise ValueError(f"{path}: duration event {i} ({ev['name']}) "
                             "missing numeric dur")
        if ph in _ASYNC_PH and "id" not in ev:
            raise ValueError(f"{path}: async event {i} ({ev['name']}) "
                             "missing id")
    return n
