"""repro.obs — the unified tracing + metrics spine.

One registry, one tracer, one trace format across the learner, sampler
nodes, the continuous engine, the weight transport, and the serving
front door. Everything is **disabled by default** and contractually
zero-cost until :func:`configure` turns it on:

    from repro import obs
    obs.configure()                      # wall clock (serving, threads)
    obs.configure(sim=runtime.sim)       # EventSim virtual clock (hetero)
    ...
    obs.export_chrome_trace("trace.json")    # load in ui.perfetto.dev
    print(obs.metrics.prometheus_text())     # or scrape GET /metrics

``obs.metrics`` is the module-level :class:`MetricsRegistry` (counters /
gauges / bounded histograms; Prometheus text exposition); ``obs.trace``
is the module-level :class:`Tracer` (``with obs.trace.span("prefill",
slot=3): ...``). Instrumented call sites bind handles once and hold
them forever; enabling/disabling flips live behavior in place.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.obs.export import (chrome_trace, validate_chrome_trace,
                              write_chrome_trace, write_jsonl)
from repro.obs.registry import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                                MetricsRegistry, Reservoir)
from repro.obs.trace import Span, Tracer

# The process-wide default surfaces. Disabled at import: every mutator's
# first statement is an `enabled` check, so un-configured runs pay one
# attribute read + branch per instrumented call site.
metrics = MetricsRegistry(enabled=False)
trace = Tracer(enabled=False)


def enabled() -> bool:
    return metrics.enabled or trace.enabled


def configure(on: bool = True, *, sim: Optional[Any] = None,
              clear: bool = False) -> None:
    """Flip the default registry + tracer on (or off).

    ``sim`` points the tracer's clock at a discrete-event simulator's
    virtual ``now`` (hetero EventSim runs); omitted, the clock resets to
    the monotonic wall clock. ``clear`` drops previously recorded
    metrics/events first (benchmark A/B hygiene).
    """
    if clear:
        metrics.clear()
        trace.clear()
    metrics.enabled = on
    trace.enabled = on
    if sim is not None:
        trace.use_sim(sim)
    else:
        trace.use_wall_clock()


def export_chrome_trace(path: str, process_name: str = "repro") -> int:
    """Write the default tracer's events as Perfetto-loadable JSON;
    returns the event count."""
    return write_chrome_trace(trace, path, process_name)


def export_jsonl(path: str) -> int:
    return write_jsonl(trace, path)


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Reservoir",
    "Span", "Tracer", "DEFAULT_BUCKETS",
    "chrome_trace", "write_chrome_trace", "write_jsonl",
    "validate_chrome_trace",
    "metrics", "trace", "configure", "enabled",
    "export_chrome_trace", "export_jsonl",
]
