"""Metrics registry: counters, gauges, bounded histograms — one store.

Every live telemetry surface in the repo (front-door SLO stats, hetero
sync accounting, engine counters, the recompile sentinel) records into
one :class:`MetricsRegistry` so a single exporter — Prometheus text
exposition on ``/metrics``, or a JSON snapshot — sees the whole system.

Design constraints, in order:

1. **Zero-cost when disabled.** The module-level default registry starts
   disabled; every mutator's first statement is an ``enabled`` check, so
   instrumented hot paths (engine ``step()``, decode chunks) pay one
   attribute read + branch per call site. Call sites bind metric handles
   once (``self._m_x = registry.counter(...)``) so the per-event cost
   never includes a name lookup.
2. **Bounded.** Histograms hold fixed bucket counts (no per-sample
   storage); label cardinality is capped per family so a bug that
   interpolates request ids into labels cannot grow without limit.
3. **Thread-safe.** Hetero sampler threads and the learner mutate
   concurrently; one registry lock guards creation and mutation (the
   rates here are per-batch / per-chunk, far below contention).

Metric identity is ``(name, sorted(labels))``; the same call always
returns the same child, so handles may be bound at construction and used
forever — enabling/disabling the registry flips live behavior without
rebinding.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Dict, Iterable, List, Optional, Tuple

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")

# Prometheus-style default latency buckets (seconds), exponential-ish.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
    2.5, 5.0, 10.0, 30.0, 60.0)

MAX_CHILDREN_PER_FAMILY = 256


def _sanitize(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _label_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_labels(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class _Metric:
    """Base child metric: holds its registry ref for the enabled check."""

    __slots__ = ("_reg", "name", "label_key")

    def __init__(self, reg: "MetricsRegistry", name: str,
                 label_key: Tuple[Tuple[str, str], ...]) -> None:
        self._reg = reg
        self.name = name
        self.label_key = label_key


class Counter(_Metric):
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self, reg, name, label_key) -> None:
        super().__init__(reg, name, label_key)
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        reg = self._reg
        if not reg.enabled:
            return
        if v < 0:
            raise ValueError(f"counter {self.name}: negative inc {v}")
        with reg._lock:
            self.value += v


class Gauge(_Metric):
    """Point-in-time value (may go up or down)."""

    __slots__ = ("value",)

    def __init__(self, reg, name, label_key) -> None:
        super().__init__(reg, name, label_key)
        self.value = float("nan")

    def set(self, v: float) -> None:
        reg = self._reg
        if not reg.enabled:
            return
        with reg._lock:
            self.value = float(v)

    def add(self, v: float) -> None:
        reg = self._reg
        if not reg.enabled:
            return
        with reg._lock:
            cur = self.value
            self.value = v if math.isnan(cur) else cur + v


class Histogram(_Metric):
    """Bounded histogram: fixed cumulative-bucket counts + sum + count.

    Storage is O(len(buckets)) regardless of how many samples are
    observed — the bounded contract a long-lived front door needs.
    """

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, reg, name, label_key,
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> None:
        super().__init__(reg, name, label_key)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError(f"histogram {name}: needs >= 1 bucket bound")
        self.buckets = bs
        self.counts = [0] * (len(bs) + 1)           # +1 = +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        reg = self._reg
        if not reg.enabled:
            return
        v = float(v)
        if math.isnan(v):
            return
        with reg._lock:
            i = 0
            for b in self.buckets:
                if v <= b:
                    break
                i += 1
            self.counts[i] += 1
            self.sum += v
            self.count += 1


class _Family:
    __slots__ = ("name", "kind", "help", "buckets", "children")

    def __init__(self, name: str, kind: str, help_: str,
                 buckets: Optional[Tuple[float, ...]] = None) -> None:
        self.name = name
        self.kind = kind
        self.help = help_
        self.buckets = buckets
        self.children: Dict[Tuple[Tuple[str, str], ...], _Metric] = {}


class MetricsRegistry:
    """One coherent metrics store; see module docstring for contracts."""

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}

    # -- creation / lookup (idempotent) --------------------------------
    def _child(self, name: str, kind: str, help_: str,
               labels: Dict[str, object],
               buckets: Optional[Tuple[float, ...]] = None) -> _Metric:
        name = _sanitize(name)
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name, kind, help_, buckets)
                self._families[name] = fam
            elif fam.kind != kind:
                raise ValueError(f"metric {name} already registered as "
                                 f"{fam.kind}, not {kind}")
            child = fam.children.get(key)
            if child is None:
                if len(fam.children) >= MAX_CHILDREN_PER_FAMILY:
                    raise ValueError(
                        f"metric {name}: label cardinality exceeds "
                        f"{MAX_CHILDREN_PER_FAMILY} — labels must be "
                        "bounded (no request ids)")
                if kind == "counter":
                    child = Counter(self, name, key)
                elif kind == "gauge":
                    child = Gauge(self, name, key)
                else:
                    child = Histogram(self, name, key,
                                      fam.buckets or DEFAULT_BUCKETS)
                fam.children[key] = child
            return child

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._child(name, "counter", help, labels)  # type: ignore

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._child(name, "gauge", help, labels)  # type: ignore

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._child(name, "histogram", help, labels,  # type: ignore
                           buckets=buckets)

    def set_many(self, prefix: str, values: Dict[str, float],
                 **labels) -> None:
        """Fan a metrics dict (e.g. one train step's scalars) into gauges
        ``<prefix>_<key>`` — the per-step fan-in used by the learner."""
        if not self.enabled:
            return
        for k, v in values.items():
            try:
                fv = float(v)
            except (TypeError, ValueError):
                continue
            self.gauge(f"{prefix}_{k}", **labels).set(fv)

    # -- export --------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """Flat ``{name{labels}: value}`` view (JSON-friendly).
        Histograms contribute ``_sum`` and ``_count``."""
        out: Dict[str, float] = {}
        with self._lock:
            for fam in self._families.values():
                for key, m in fam.children.items():
                    lab = _fmt_labels(key)
                    if isinstance(m, Histogram):
                        out[f"{fam.name}_sum{lab}"] = m.sum
                        out[f"{fam.name}_count{lab}"] = float(m.count)
                    else:
                        out[f"{fam.name}{lab}"] = m.value  # type: ignore
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition (format 0.0.4) of every family."""
        lines: List[str] = []
        with self._lock:
            for name in sorted(self._families):
                fam = self._families[name]
                if fam.help:
                    lines.append(f"# HELP {name} {fam.help}")
                lines.append(f"# TYPE {name} {fam.kind}")
                for key, m in sorted(fam.children.items()):
                    if isinstance(m, Histogram):
                        cum = 0
                        for b, c in zip(m.buckets,
                                        m.counts[:-1], strict=True):
                            cum += c
                            lk = _fmt_labels(key + (("le", _fmt_value(b)),))
                            lines.append(f"{name}_bucket{lk} {cum}")
                        cum += m.counts[-1]
                        lk = _fmt_labels(key + (("le", "+Inf"),))
                        lines.append(f"{name}_bucket{lk} {cum}")
                        lab = _fmt_labels(key)
                        lines.append(f"{name}_sum{lab} {_fmt_value(m.sum)}")
                        lines.append(f"{name}_count{lab} {m.count}")
                    else:
                        lab = _fmt_labels(key)
                        lines.append(
                            f"{name}{lab} {_fmt_value(m.value)}")  # type: ignore
        return "\n".join(lines) + "\n"

    def clear(self) -> None:
        """Reset every child's value **in place** — families and children
        survive, so handles bound before the clear keep recording into
        metrics the exporters can still see (the handles-bound-forever
        contract). Dropping families would silently orphan every
        already-instrumented call site."""
        with self._lock:
            for fam in self._families.values():
                for m in fam.children.values():
                    if isinstance(m, Histogram):
                        m.counts = [0] * (len(m.buckets) + 1)
                        m.sum = 0.0
                        m.count = 0
                    elif isinstance(m, Gauge):
                        m.value = float("nan")
                    else:
                        m.value = 0.0


class Reservoir:
    """Fixed-size uniform sample over an unbounded stream (Algorithm R).

    Keeps exact values below ``capacity``; beyond it, each new value
    replaces a uniformly random slot with probability ``capacity/n`` —
    nearest-rank percentiles over the sample stay unbiased, and a seeded
    RNG keeps them deterministic in tests. ``append`` aliases ``add`` so
    a Reservoir drops in for the unbounded lists it replaces.
    """

    __slots__ = ("capacity", "n", "_values", "_rng")

    def __init__(self, capacity: int = 2048, seed: int = 0) -> None:
        if capacity < 1:
            raise ValueError(f"capacity={capacity} < 1")
        import random
        self.capacity = capacity
        self.n = 0                       # total values offered
        self._values: List[float] = []
        self._rng = random.Random(seed)

    def add(self, v: float) -> None:
        self.n += 1
        if len(self._values) < self.capacity:
            self._values.append(float(v))
            return
        j = self._rng.randrange(self.n)
        if j < self.capacity:
            self._values[j] = float(v)

    append = add

    @property
    def values(self) -> List[float]:
        return list(self._values)

    def __len__(self) -> int:
        return len(self._values)

    def __iter__(self) -> Iterable[float]:
        return iter(self._values)
