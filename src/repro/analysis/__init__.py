"""repro.analysis — repo-specific static analysis + recompile sentinel.

Run as ``python -m repro.analysis src tests benchmarks``. See README
"Static analysis" for the rule catalogue (RA001–RA005), the
``# noqa: RAxxx`` suppression convention, and the baseline workflow.
"""
from repro.analysis.core import (Finding, RepoContext, SourceFile,
                                 collect_files, load_baseline,
                                 run_analysis, run_rules, save_baseline)
from repro.analysis.rules import RULE_DOCS, default_rules
from repro.analysis.sentinel import (RecompileSentinel, executable_bound,
                                     pow2_bucket_count,
                                     spec_verify_executable_bound,
                                     spec_verify_width_buckets)

__all__ = [
    "Finding", "RepoContext", "SourceFile", "collect_files",
    "load_baseline", "run_analysis", "run_rules", "save_baseline",
    "RULE_DOCS", "default_rules",
    "RecompileSentinel", "executable_bound", "pow2_bucket_count",
    "spec_verify_width_buckets", "spec_verify_executable_bound",
]
