"""Runtime recompile sentinel — the dynamic twin of RA002.

Counts XLA backend compiles via :mod:`jax.monitoring` event listeners so
tests and benchmarks can assert compile *budgets*, not just eyeball them:
the continuous engine's pow2-bucketed block tables promise O(log)
executables over a steady run, and this is where that claim is enforced.

Usage::

    with RecompileSentinel() as s:
        engine.step(); engine.step()
    assert s.compiles <= bound

Listeners in jax.monitoring are append-only (there is no unregister), so
a single module-level listener is registered on first use and fans out to
every active sentinel. Nested sentinels each see the compiles that happen
while they are open.
"""
from __future__ import annotations

import threading
from typing import List

import jax

from repro import obs

# Event key emitted once per XLA backend compile (observed on jax 0.4.x
# CPU and TPU backends alike). Duration listeners fire with
# (event_name, duration_secs, **kwargs).
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_active: List["RecompileSentinel"] = []
_active_lock = threading.Lock()
_registered = False

# Unified-registry mirror: every observed compile also increments this
# counter (and a compile-seconds counter), so steady-state recompiles
# surface on a scraped /metrics endpoint — paging an operator — instead
# of only failing tests/test_recompile.py after the fact. No-op while
# the registry is disabled.
_M_COMPILES = obs.metrics.counter(
    "xla_compiles_total", "XLA backend compiles observed")
_M_COMPILE_SECONDS = obs.metrics.counter(
    "xla_compile_seconds_total", "seconds spent in XLA backend compiles")


def _on_event(event: str, duration: float, **kwargs) -> None:
    if _COMPILE_EVENT not in event:
        return
    _M_COMPILES.inc()
    _M_COMPILE_SECONDS.inc(max(float(duration), 0.0))
    with _active_lock:
        for s in _active:
            s._record(event)


def _ensure_listener() -> None:
    global _registered
    with _active_lock:
        if _registered:
            return
        jax.monitoring.register_event_duration_secs_listener(_on_event)
        _registered = True


def install_metrics_listener() -> None:
    """Start counting XLA backend compiles into the unified registry
    without opening a sentinel — long-lived processes (the serving front
    door, hetero runtimes) call this once so ``xla_compiles_total`` is
    live for their whole lifetime."""
    _ensure_listener()


class RecompileSentinel:
    """Context manager counting XLA backend compiles while open."""

    def __init__(self, label: str = "") -> None:
        self.label = label
        self.compiles = 0
        self.events: List[str] = []
        self._lock = threading.Lock()

    def _record(self, event: str) -> None:
        with self._lock:
            self.compiles += 1
            self.events.append(event)

    def __enter__(self) -> RecompileSentinel:
        _ensure_listener()
        with _active_lock:
            _active.append(self)
        return self

    def __exit__(self, *exc) -> None:
        with _active_lock:
            if self in _active:
                _active.remove(self)

    def assert_bound(self, bound: int, context: str = "") -> None:
        if self.compiles > bound:
            where = f" [{context or self.label}]" if (context or self.label) \
                else ""
            raise AssertionError(
                f"recompile sentinel{where}: {self.compiles} XLA compiles "
                f"observed, bound is {bound}")


def pow2_bucket_count(max_pages: int) -> int:
    """Number of distinct block-table widths the engine's pow2 bucketing
    (`_live_width` in sampling/continuous.py) can produce for a cap of
    ``max_pages`` pages — the analytic executable bound per (phase,
    batch-shape) family. Mirrors `_live_width` exactly: widths are
    min(next_pow2(need), cap) for need in 1..cap.
    """
    widths = set()
    for need in range(1, max_pages + 1):
        w = 1
        while w < need:
            w *= 2
        widths.add(min(w, max_pages))
    return len(widths)


def executable_bound(max_pages: int, phases: int = 3, slack: int = 4) -> int:
    """Conservative compile-count bound for a steady engine run:
    ``phases`` shape families (prefill chunk / decode chunk / page copy),
    each over the pow2 width buckets, plus ``slack`` for one-off helper
    jits (sampling kernels, logprob gather).
    """
    return phases * pow2_bucket_count(max_pages) + slack


def spec_verify_width_buckets(spec_k: int) -> int:
    """Distinct jitted verify widths speculative decoding can request.
    Mirrors the width computation in ``_spec_round``
    (sampling/continuous.py): the window holds 1 pending token plus
    0..spec_k drafts, bucketed through the same pow2 rounding as
    `_live_width` with a floor of 2 (width-1 windows would route to the
    decode kernel, which has no query-recording path). Cross-checked
    against ``repro.sampling.spec.verify_width_buckets`` in tests.
    """
    widths = set()
    for k in range(spec_k + 1):
        need = 1 + k
        w = 1
        while w < need:
            w *= 2
        widths.add(max(2, min(w, spec_k + 1)))
    return len(widths)


def spec_verify_executable_bound(spec_k: int, max_pages: int) -> int:
    """Analytic ceiling on the spec engine's jitted round executables:
    verify compiles (``_verify_chunk_jit``) key on (verify width bucket,
    pow2 block-table width bucket), and no-draft fallback chunks
    (``_spec_decode_chunk_jit``) add one more family over the table-width
    buckets. Varying per-round acceptance lengths change neither key, so
    a steady spec-decode epoch compiles nothing new — the property
    tests/test_recompile.py asserts with this bound.
    """
    if spec_k <= 0:
        return 0
    return ((spec_verify_width_buckets(spec_k) + 1)
            * pow2_bucket_count(max_pages))


def prefill_executable_bound(prefill_chunk: int, max_pages: int) -> int:
    """Analytic ceiling on jitted prefill-chunk executables
    (``_prefill_chunk_jit``): each compile is keyed by
    (chunk width, pow2 block-table width bucket). Chunk widths are the
    configured ``prefill_chunk`` plus every shorter final tail a prompt
    can leave — at most ``prefill_chunk`` distinct values; table widths
    bucket through ``_live_width`` exactly as decode's do. Pass the
    engine's ``prefill_chunk`` (``None``/0 — whole-prompt prefill —
    degenerates to one width per distinct prompt length; this bound
    covers the chunked configuration the engine runs in production).
    """
    return (prefill_chunk or 1) * pow2_bucket_count(max_pages)


__all__ = ["RecompileSentinel", "pow2_bucket_count", "executable_bound",
           "prefill_executable_bound", "spec_verify_width_buckets",
           "spec_verify_executable_bound", "install_metrics_listener"]
