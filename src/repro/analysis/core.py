"""Shared infrastructure for the repo's static-analysis suite.

One parse per file, one repo-wide context pass, then every rule walks the
same trees. The moving parts:

- :class:`SourceFile` — parsed module + parent links + ``# noqa: RAxxx``
  suppression map;
- :class:`RepoContext` — the cross-file facts rules need (frozen-dataclass
  registry, donating-jit registry, class definitions);
- :class:`Finding` — one diagnostic, with a line-drift-stable baseline key
  (rule + path + stripped source line, so re-indenting a file does not
  invalidate the baseline);
- baseline load/save (``analysis_baseline.json``) and the driver
  :func:`run_analysis`.

Rules live in :mod:`repro.analysis.rules`; the CLI in ``__main__``.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# Paths never analyzed by default: fixture corpora are *deliberately*
# full of findings (the analyzer's own regression tests), and tool
# droppings aren't source.
DEFAULT_EXCLUDES = ("_fixtures", "fixtures", "__pycache__", ".git",
                    "build", ".venv", ".eggs")

_NOQA_RE = re.compile(
    r"#\s*noqa(?::\s*(?P<codes>[A-Z]+[0-9]+(?:[,\s]+[A-Z]+[0-9]+)*))?",
    re.IGNORECASE)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str          # "RA001"
    path: str          # repo-relative posix path
    line: int          # 1-based
    message: str
    snippet: str       # stripped source of the flagged line

    @property
    def key(self) -> str:
        """Baseline key — stable under line insertion/deletion elsewhere
        in the file (keys on content, not line number)."""
        return f"{self.rule}::{self.path}::{self.snippet}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class SourceFile:
    """One parsed module: tree with parent links, source lines, and the
    per-line ``# noqa`` suppression map."""

    def __init__(self, path: Path, rel: str, source: str) -> None:
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child.ra_parent = node  # type: ignore[attr-defined]
        self.noqa: Dict[int, Optional[frozenset]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _NOQA_RE.search(line)
            if m:
                codes = m.group("codes")
                self.noqa[i] = (frozenset(
                    c.strip().upper() for c in re.split(r"[,\s]+", codes))
                    if codes else None)      # None = bare noqa, all rules

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def suppressed(self, rule: str, lineno: int) -> bool:
        if lineno not in self.noqa:
            return False
        codes = self.noqa[lineno]
        return codes is None or rule in codes

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(rule=rule, path=self.rel, line=line, message=message,
                       snippet=self.line_text(line))


# -------------------------------------------------------------------------
# small AST helpers shared by the rules


def spelling(node: ast.AST) -> Optional[str]:
    """Dotted spelling of a Name/Attribute chain ("x", "self.pool",
    "np.asarray"); None for anything more dynamic."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = spelling(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def is_jax_jit(node: ast.AST) -> bool:
    """``jax.jit`` / bare ``jit`` (from jax import jit)."""
    return spelling(node) in ("jax.jit", "jit")


def jit_wrap_call(node: ast.AST) -> Optional[ast.Call]:
    """The ``jax.jit(...)`` or ``functools.partial(jax.jit, ...)`` call
    inside ``node``, if node is one of those wrap expressions."""
    if not isinstance(node, ast.Call):
        return None
    if is_jax_jit(node.func):
        return node
    if spelling(node.func) in ("functools.partial", "partial") \
            and node.args and is_jax_jit(node.args[0]):
        return node
    return None


def keyword_value(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def const_str_tuple(node: Optional[ast.AST]) -> Optional[Tuple[str, ...]]:
    """Literal tuple/list of strings (or a single string) -> tuple."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.append(el.value)
            else:
                return None
        return tuple(out)
    return None


def const_int_tuple(node: Optional[ast.AST]) -> Optional[Tuple[int, ...]]:
    """Literal tuple/list of ints (or a single int) -> tuple."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, int):
                out.append(el.value)
            else:
                return None
        return tuple(out)
    return None


def enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    cur = getattr(node, "ra_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = getattr(cur, "ra_parent", None)
    return None


def enclosing_class(node: ast.AST) -> Optional[ast.ClassDef]:
    cur = getattr(node, "ra_parent", None)
    while cur is not None:
        if isinstance(cur, ast.ClassDef):
            return cur
        cur = getattr(cur, "ra_parent", None)
    return None


def enclosing_statement(node: ast.AST) -> ast.stmt:
    """The smallest statement containing ``node``."""
    cur = node
    while not isinstance(cur, ast.stmt):
        cur = cur.ra_parent  # type: ignore[attr-defined]
    return cur


def loop_ancestors(node: ast.AST, *, stop_at: Optional[ast.AST] = None
                   ) -> List[ast.AST]:
    """For/While ancestors of ``node`` up to (not including) stop_at."""
    out = []
    cur = getattr(node, "ra_parent", None)
    while cur is not None and cur is not stop_at:
        if isinstance(cur, (ast.For, ast.AsyncFor, ast.While)):
            out.append(cur)
        cur = getattr(cur, "ra_parent", None)
    return out


def has_decorator(fn: ast.AST, *names: str) -> bool:
    for dec in getattr(fn, "decorator_list", ()):
        target = dec.func if isinstance(dec, ast.Call) else dec
        sp = spelling(target) or ""
        if sp in names or sp.split(".")[-1] in names:
            return True
    return False


def all_params(fn: ast.AST) -> List[ast.arg]:
    a = fn.args
    return [*a.posonlyargs, *a.args, *a.kwonlyargs]


def assign_targets(stmt: ast.stmt) -> List[str]:
    """Spellings bound by an assignment statement (tuple targets
    flattened); empty for non-assignments."""
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    out: List[str] = []
    stack = list(targets)
    while stack:
        t = stack.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            stack.extend(t.elts)
        else:
            sp = spelling(t)
            if sp:
                out.append(sp)
    return out


# -------------------------------------------------------------------------
# repo-wide context (pass 1)

# Jitted callables the repo builds with factory functions: calling an
# attribute with one of these names invokes a donated/jitted step.
# ``make_sharded_train_step``/``make_sharded_sft_step`` donate arg 0 (the
# TrainState) — the contract ``parallel/step.py`` documents.
ATTR_DONATORS: Dict[str, Tuple[int, ...]] = {"step_fn": (0,)}


@dataclasses.dataclass
class JitDef:
    name: str
    params: Tuple[str, ...]
    donated: Tuple[int, ...]        # positional indices into params


class RepoContext:
    """Cross-file facts collected before any rule runs."""

    def __init__(self, files: Sequence[SourceFile]) -> None:
        self.frozen_dataclasses: set = set()
        self.plain_dataclasses: set = set()
        self.class_defs: Dict[str, Tuple[SourceFile, ast.ClassDef]] = {}
        self.jit_defs: Dict[str, JitDef] = {}
        for f in files:
            self._scan(f)

    def _scan(self, f: SourceFile) -> None:
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ClassDef):
                self.class_defs[node.name] = (f, node)
                for dec in node.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    if (spelling(target) or "").split(".")[-1] != "dataclass":
                        continue
                    frozen = False
                    if isinstance(dec, ast.Call):
                        fz = keyword_value(dec, "frozen")
                        frozen = (isinstance(fz, ast.Constant)
                                  and fz.value is True)
                    (self.frozen_dataclasses if frozen
                     else self.plain_dataclasses).add(node.name)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    wrap = jit_wrap_call(dec) or (
                        dec if is_jax_jit(dec) else None)
                    if wrap is None:
                        continue
                    donated = const_int_tuple(
                        keyword_value(wrap, "donate_argnums")
                        if isinstance(wrap, ast.Call) else None) or ()
                    self.jit_defs[node.name] = JitDef(
                        name=node.name,
                        params=tuple(p.arg for p in all_params(node)),
                        donated=donated)

    def donated_params(self, callee: str) -> Optional[Tuple[Tuple[int, ...],
                                                            Tuple[str, ...]]]:
        """(donated positional indices, param names) for a known donating
        callee spelling, else None."""
        base = callee.split(".")[-1]
        jd = self.jit_defs.get(base)
        if jd is not None and jd.donated:
            return jd.donated, jd.params
        if base in ATTR_DONATORS:
            return ATTR_DONATORS[base], ()
        return None

    def is_jitted_callable(self, callee: str) -> bool:
        base = callee.split(".")[-1]
        return base in self.jit_defs or base in ATTR_DONATORS


# -------------------------------------------------------------------------
# baseline

BASELINE_VERSION = 1


def load_baseline(path: Path) -> Dict[str, int]:
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"{path}: unknown baseline version "
                         f"{data.get('version')!r}")
    return {str(k): int(v) for k, v in data["findings"].items()}


def save_baseline(path: Path, findings: Sequence[Finding]) -> None:
    counts: Dict[str, int] = {}
    for fd in findings:
        counts[fd.key] = counts.get(fd.key, 0) + 1
    payload = {"version": BASELINE_VERSION,
               "findings": dict(sorted(counts.items()))}
    path.write_text(json.dumps(payload, indent=1, sort_keys=False) + "\n")


def apply_baseline(findings: Sequence[Finding], baseline: Dict[str, int]
                   ) -> Tuple[List[Finding], List[str]]:
    """(new findings not covered by the baseline, stale baseline keys)."""
    remaining = dict(baseline)
    new: List[Finding] = []
    for fd in findings:
        if remaining.get(fd.key, 0) > 0:
            remaining[fd.key] -= 1
        else:
            new.append(fd)
    stale = sorted(k for k, v in remaining.items() if v > 0)
    return new, stale


# -------------------------------------------------------------------------
# driver


def collect_files(paths: Sequence[Path], *, root: Path,
                  excludes: Sequence[str] = DEFAULT_EXCLUDES
                  ) -> List[SourceFile]:
    out: List[SourceFile] = []
    seen = set()
    for p in paths:
        candidates = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for c in candidates:
            if c.suffix != ".py" or c in seen:
                continue
            if any(part in excludes for part in c.parts):
                continue
            seen.add(c)
            try:
                rel = c.relative_to(root).as_posix()
            except ValueError:
                rel = c.as_posix()
            out.append(SourceFile(c, rel, c.read_text()))
    return out


def run_rules(files: Sequence[SourceFile],
              rules: Optional[Iterable] = None) -> List[Finding]:
    from repro.analysis.rules import default_rules
    ctx = RepoContext(files)
    active = list(rules) if rules is not None else default_rules()
    findings: List[Finding] = []
    for f in files:
        for rule in active:
            for fd in rule.check(f, ctx):
                if not f.suppressed(fd.rule, fd.line):
                    findings.append(fd)
    findings.sort(key=lambda fd: (fd.path, fd.line, fd.rule))
    return findings


def run_analysis(paths: Sequence[Path], *, root: Path,
                 baseline_path: Optional[Path] = None,
                 excludes: Sequence[str] = DEFAULT_EXCLUDES,
                 select: Optional[Sequence[str]] = None
                 ) -> Tuple[List[Finding], List[str], int]:
    """Analyze ``paths``; returns (new findings, stale baseline keys,
    total findings before baselining)."""
    from repro.analysis.rules import default_rules
    files = collect_files(paths, root=root, excludes=excludes)
    rules = default_rules()
    if select:
        wanted = {s.upper() for s in select}
        rules = [r for r in rules if r.code in wanted]
    findings = run_rules(files, rules)
    baseline = load_baseline(baseline_path) if baseline_path else {}
    new, stale = apply_baseline(findings, baseline)
    return new, stale, len(findings)
