# Known-bad corpus for `python -m repro.analysis --selftest`.
#
# Every RA rule must fire on this file — it is the analyzer's regression
# fixture, never imported and never executed (the `_fixtures` directory
# is excluded from normal analysis runs and from packaging). Each block
# below reproduces one bug class the rules exist to catch; keep the
# blocks minimal and labelled so a selftest failure points at the rule
# that regressed.
import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


# --- RA001: donation-after-use ------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def donating_step(state, batch):
    return state


def ra001_read_after_donate(state, batch):
    new_state = donating_step(state, batch)   # `state` buffer is dead now
    stale = state["params"]                   # RA001: read of donated arg
    return new_state, stale


# --- RA002: jit static-arg hygiene --------------------------------------

@functools.partial(jax.jit, static_argnames=("opts", "missing"))
def ra002_unhashable_static(x, opts: list):   # RA002: list static arg
    return x                                  # RA002: `missing` not a param


def ra002_jit_in_loop(xs):
    out = []
    for x in xs:
        f = jax.jit(lambda v: v + 1)          # RA002: jit built per iteration
        out.append(f(x))
    return out


def decode_ra002_hot(x):
    g = jax.jit(lambda v: v * 2)              # RA002: jit built per call
    return g(x)


# --- RA003: host-sync in hot loops --------------------------------------

@jax.jit
def jitted_fwd(x):
    return x * 2


def step(x):
    y = jitted_fwd(x)
    loss = float(y)                           # RA003: host sync on result
    arr = np.asarray(y)                       # RA003: host sync on result
    return loss, arr


# --- RA004: Pallas kernel constraints -----------------------------------

def bad_kernel(x_ref, o_ref):
    v = x_ref[0, 0]
    if v > 0:                                 # RA004: python `if` on tracer
        o_ref[...] = x_ref[...]


def ra004_misaligned_call(x):
    return pl.pallas_call(
        bad_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec((8, 100), lambda i: (i, 0))],   # RA004: 100
        out_specs=pl.BlockSpec((8, 100), lambda i: (i, 0)),
        grid=(1,),
    )(x)


def ra004_prefetch_map_drops_refs(x, table):
    from jax.experimental.pallas import tpu as pltpu

    def imap_no_refs(i, j):                   # RA004: drops 1 prefetch ref
        return (i, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(4, 2),
        in_specs=[
            # RA004: index map takes 2 params, grid rank 2 + 1 prefetch
            # RA004: literal 100 on the q-chunk axis is not 8-aligned
            pl.BlockSpec((1, 100, 8, 128), imap_no_refs),
        ],
        out_specs=pl.BlockSpec((8, 128), lambda i, j, tbl: (i, 0)),  # ok
    )
    return pl.pallas_call(
        bad_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(table, x)


# --- RA005: unlocked cross-thread mutation ------------------------------

class SharedCounter:
    def __init__(self):
        self.count = 0
        self.items = []
        self._lock = threading.Lock()

    def bump(self):
        self.count += 1                       # RA005: no lock held
        self.items.append(self.count)         # RA005: no lock held

    def bump_locked(self):                    # exempt: caller holds lock
        self.count += 1

    def run(self):
        t = threading.Thread(target=self._loop)
        t.start()

    def _loop(self):
        while True:
            self.bump()


_ = (jnp, ra001_read_after_donate, ra002_unhashable_static,
     ra002_jit_in_loop, decode_ra002_hot, step, ra004_misaligned_call,
     ra004_prefetch_map_drops_refs, SharedCounter)
