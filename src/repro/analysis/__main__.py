"""CLI driver: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean (modulo baseline), 1 new findings, 2 usage/self-test
failure. ``--write-baseline`` rewrites the baseline to the current
finding set (use after auditing that every remaining finding is
intentional).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List

from repro.analysis.core import (collect_files, run_analysis, run_rules,
                                 save_baseline)
from repro.analysis.rules import RULE_DOCS, default_rules


def _selftest() -> int:
    """Assert every rule fires on the known-bad fixture corpus."""
    fixture = Path(__file__).resolve().parent / "_fixtures" / "known_bad.py"
    if not fixture.exists():
        print(f"selftest: fixture missing: {fixture}", file=sys.stderr)
        return 2
    files = collect_files([fixture], root=fixture.parent, excludes=())
    findings = run_rules(files)
    fired = {f.rule for f in findings}
    expected = set(RULE_DOCS)
    for f in findings:
        print(f.render())
    missing = sorted(expected - fired)
    if missing:
        print(f"selftest FAILED: rules did not fire on known-bad fixture: "
              f"{', '.join(missing)}", file=sys.stderr)
        return 2
    print(f"selftest OK: all {len(expected)} rules fired "
          f"({len(findings)} findings on fixture)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware repo-specific static analysis (RA001-RA005)")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to analyze "
                         "(default: src tests benchmarks)")
    ap.add_argument("--root", default=".",
                    help="repo root for relative paths and the baseline")
    ap.add_argument("--baseline", default="analysis_baseline.json",
                    help="baseline file, relative to --root")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes to run "
                         "(e.g. RA001,RA003)")
    ap.add_argument("--selftest", action="store_true",
                    help="run the rules against the known-bad fixture "
                         "and assert every rule fires")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code in sorted(RULE_DOCS):
            print(f"{code}  {RULE_DOCS[code]}")
        return 0
    if args.selftest:
        return _selftest()

    root = Path(args.root).resolve()
    raw = args.paths or ["src", "tests", "benchmarks"]
    paths: List[Path] = []
    for p in raw:
        cand = Path(p)
        if not cand.is_absolute():
            cand = root / cand
        if not cand.exists():
            print(f"warning: path does not exist, skipping: {p}",
                  file=sys.stderr)
            continue
        paths.append(cand)
    if not paths:
        print("error: no paths to analyze", file=sys.stderr)
        return 2

    select = ([s.strip().upper() for s in args.select.split(",")]
              if args.select else None)

    if args.write_baseline:
        files = collect_files(paths, root=root)
        rules = default_rules()
        if select:
            rules = [r for r in rules if r.code in set(select)]
        findings = run_rules(files, rules)
        save_baseline(root / args.baseline, findings)
        print(f"wrote {root / args.baseline}: {len(findings)} finding(s) "
              "baselined")
        return 0

    baseline_path = None if args.no_baseline else root / args.baseline
    new, stale, total = run_analysis(paths, root=root,
                                     baseline_path=baseline_path,
                                     select=select)
    for f in new:
        print(f.render())
    if stale:
        print(f"note: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (finding no longer "
              "present) — refresh with --write-baseline", file=sys.stderr)
    if new:
        print(f"\n{len(new)} new finding(s) ({total} total, "
              f"{total - len(new)} baselined). Fix, `# noqa: RAxxx` with "
              "a rationale, or re-baseline.", file=sys.stderr)
        return 1
    print(f"analysis clean: 0 new findings ({total} baselined)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
