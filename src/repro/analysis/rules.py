"""The RA rule set — repo-specific correctness contracts, machine-checked.

Each rule is one small visitor over the shared parse (see
:mod:`repro.analysis.core`). The contracts they enforce exist elsewhere
only as docstring convention:

- **RA001 donation-after-use** — a buffer passed to a ``donate_argnums``
  call is dead; reading it again before reassignment is the exact bug
  class ``LearnerNode``'s plan-placed copies defend against by hand.
- **RA002 jit static-arg hygiene** — every ``static_argnames`` target
  must resolve to a hashable/frozen type, and ``jax.jit`` wrappers must
  not be constructed per call (recompile storm).
- **RA003 host-sync in hot loops** — ``float()`` / ``.item()`` /
  ``np.asarray()`` / ``jax.device_get`` on jitted-call results inside
  engine hot paths blocks the dispatch pipeline; deliberate sync points
  carry a ``# noqa: RA003`` with a rationale or a baseline entry.
- **RA004 Pallas kernel constraints** — literal BlockSpec tiles must be
  8/128-aligned (or 1 / symbolic, e.g. the ``_fit_block`` idiom), and
  kernel bodies must branch with ``pl.when`` / ``jnp.where``, never a
  Python ``if`` on a tracer.
- **RA005 unlocked cross-thread mutation** — classes handed to
  ``threading.Thread`` targets must guard every ``self`` mutation with
  ``self._lock`` (methods named ``*_locked`` assert the caller holds it).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, RepoContext, SourceFile,
                                 all_params, assign_targets,
                                 const_str_tuple,
                                 enclosing_class, enclosing_function,
                                 enclosing_statement, has_decorator,
                                 jit_wrap_call, keyword_value,
                                 loop_ancestors, spelling)

# Function names treated as serving/training hot paths by RA002/RA003.
_HOT_EXACT = {"step", "generate", "train_on", "_sampler_loop"}
_HOT_RE = re.compile(r"decode|prefill")


def _is_hot_function(fn: ast.AST) -> bool:
    name = getattr(fn, "name", "")
    return name in _HOT_EXACT or bool(_HOT_RE.search(name))


def _function_statements(fn: ast.AST) -> List[ast.stmt]:
    """Every statement in ``fn`` (nested suites flattened), source order,
    excluding nested function/class bodies."""
    out: List[ast.stmt] = []

    def visit(body):
        for stmt in body:
            out.append(stmt)
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            for field in ("body", "orelse", "finalbody"):
                visit(getattr(stmt, field, []) or [])
            for handler in getattr(stmt, "handlers", []) or []:
                visit(handler.body)

    visit(fn.body)
    return out


def _reads_in(stmt: ast.stmt, target: str) -> bool:
    """Does ``stmt`` read ``target`` (Name/Attribute load)?"""
    for node in ast.walk(stmt):
        if isinstance(node, (ast.Name, ast.Attribute)) \
                and isinstance(getattr(node, "ctx", None), ast.Load) \
                and spelling(node) == target:
            return True
    return False


class Rule:
    code = "RA000"
    name = "base"

    def check(self, f: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        raise NotImplementedError


# -------------------------------------------------------------------------


class DonationAfterUse(Rule):
    """RA001: a variable passed in a donated argument position is read
    again before reassignment."""

    code = "RA001"
    name = "donation-after-use"

    def check(self, f: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        for call in ast.walk(f.tree):
            if not isinstance(call, ast.Call):
                continue
            callee = spelling(call.func)
            if callee is None:
                continue
            don = ctx.donated_params(callee)
            if don is None:
                continue
            indices, params = don
            donated_args: List[str] = []
            for k in indices:
                if k < len(call.args):
                    sp = spelling(call.args[k])
                    if sp:
                        donated_args.append(sp)
                elif params and k < len(params):
                    for kw in call.keywords:
                        if kw.arg == params[k]:
                            sp = spelling(kw.value)
                            if sp:
                                donated_args.append(sp)
            if not donated_args:
                continue
            fn = enclosing_function(call)
            if fn is None:
                continue
            stmt = enclosing_statement(call)
            rebound = set(assign_targets(stmt))
            stmts = _function_statements(fn)
            try:
                idx = stmts.index(stmt)
            except ValueError:
                continue
            loops = loop_ancestors(stmt, stop_at=fn)
            for target in donated_args:
                if target in rebound:
                    continue        # x = f(x): donated buffer rebound
                use = self._first_use_after(stmts, idx, target)
                if use is None and loops:
                    # the loop re-executes its body: reads at the top of
                    # the loop see the donated buffer of the previous
                    # iteration
                    loop = loops[0]
                    lstmts = _function_statements_of_body(loop)
                    try:
                        lidx = lstmts.index(stmt)
                    except ValueError:
                        lidx = len(lstmts)
                    use = self._first_use_after(lstmts, -1, target,
                                                stop=lidx)
                if use is not None:
                    yield f.finding(
                        self.code, use,
                        f"`{target}` was donated to `{callee}` (line "
                        f"{call.lineno}) and is read again here before "
                        "reassignment — the buffer is dead after "
                        "donation; rebind the result or pass a copy")

    @staticmethod
    def _first_use_after(stmts: List[ast.stmt], idx: int, target: str,
                         stop: Optional[int] = None) -> Optional[ast.stmt]:
        for j in range(idx + 1, stop if stop is not None else len(stmts)):
            s = stmts[j]
            binds = target in assign_targets(s)
            reads = _reads_in(s, target)
            if reads and not (binds and isinstance(s, ast.Assign)
                              and not _reads_in_value_only(s, target)):
                return s
            if binds:
                return None
        return None


def _reads_in_value_only(stmt: ast.Assign, target: str) -> bool:
    """True when the only appearance of ``target`` in an assignment is on
    the target side (a pure rebind, not a read)."""
    return not _reads_in_expr(stmt.value, target)


def _reads_in_expr(expr: ast.AST, target: str) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, (ast.Name, ast.Attribute)) \
                and spelling(node) == target:
            return True
    return False


def _function_statements_of_body(loop: ast.AST) -> List[ast.stmt]:
    out: List[ast.stmt] = []

    def visit(body):
        for stmt in body:
            out.append(stmt)
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            for field in ("body", "orelse", "finalbody"):
                visit(getattr(stmt, field, []) or [])
            for handler in getattr(stmt, "handlers", []) or []:
                visit(handler.body)

    visit(loop.body)
    return out


# -------------------------------------------------------------------------


_UNHASHABLE_BASES = {"list", "List", "dict", "Dict", "set", "Set",
                     "bytearray", "MutableMapping", "MutableSequence",
                     "MutableSet", "ndarray", "Array", "ArrayLike",
                     "DeviceArray"}
_HASHABLE_BASES = {"int", "float", "bool", "str", "bytes", "complex",
                   "tuple", "Tuple", "frozenset", "FrozenSet", "type",
                   "Type", "Callable", "Literal", "Any", "None",
                   "NoneType"}


class JitStaticArgHygiene(Rule):
    """RA002: static_argnames must resolve to hashable/frozen types, and
    jit wrappers must not be constructed per call."""

    code = "RA002"
    name = "jit-static-arg-hygiene"

    def check(self, f: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        yield from self._check_static_args(f, ctx)
        yield from self._check_construction_sites(f)

    # ---- half 1: static_argnames hashability ---------------------------
    def _check_static_args(self, f: SourceFile, ctx: RepoContext
                           ) -> Iterator[Finding]:
        for fn in ast.walk(f.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in fn.decorator_list:
                wrap = jit_wrap_call(dec)
                if wrap is None:
                    continue
                statics = const_str_tuple(
                    keyword_value(wrap, "static_argnames"))
                if not statics:
                    continue
                params = {p.arg: p for p in all_params(fn)}
                for sname in statics:
                    if sname not in params:
                        yield f.finding(
                            self.code, dec,
                            f"static_argnames names `{sname}` but "
                            f"`{fn.name}` has no such parameter")
                        continue
                    ann = params[sname].annotation
                    verdict = self._classify(ann, ctx)
                    if verdict is not None:
                        yield f.finding(
                            self.code, params[sname],
                            f"static arg `{sname}` of `{fn.name}` is "
                            f"annotated {verdict} — static args are jit "
                            "cache keys and must be hashable (frozen "
                            "dataclass / scalar / tuple)")

    def _classify(self, ann: Optional[ast.AST], ctx: RepoContext
                  ) -> Optional[str]:
        """None = fine/unknown; else a description of the problem."""
        if ann is None:
            return None
        if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
            try:
                ann = ast.parse(ann.value, mode="eval").body
            except SyntaxError:
                return None
        if isinstance(ann, ast.Subscript):
            base = (spelling(ann.value) or "").split(".")[-1]
            if base in ("Optional", "Union"):
                inner = ann.slice
                elts = inner.elts if isinstance(inner, ast.Tuple) else [inner]
                for el in elts:
                    v = self._classify(el, ctx)
                    if v is not None:
                        return v
                return None
            if base in _UNHASHABLE_BASES:
                return f"`{base}[...]` (unhashable)"
            return None
        if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
            for side in (ann.left, ann.right):
                v = self._classify(side, ctx)
                if v is not None:
                    return v
            return None
        base = (spelling(ann) or "").split(".")[-1]
        if not base:
            return None
        if base in _UNHASHABLE_BASES:
            return f"`{base}` (unhashable)"
        if base in ctx.plain_dataclasses:
            return (f"`{base}`, a non-frozen dataclass (declare "
                    "@dataclass(frozen=True) so it hashes by value)")
        return None

    # ---- half 2: per-call jit construction -----------------------------
    def _check_construction_sites(self, f: SourceFile) -> Iterator[Finding]:
        for node in ast.walk(f.tree):
            wrap = jit_wrap_call(node)
            if wrap is None:
                continue
            parent = getattr(node, "ra_parent", None)
            # decorators run once at def time
            if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node in parent.decorator_list:
                continue
            # jax.jit(f).lower(...) is one-shot AOT lowering, not a
            # per-call cache (the dry-run idiom)
            if isinstance(parent, ast.Attribute) and parent.attr == "lower":
                continue
            # jax.jit(...)(x): a fresh wrapper (and usually a fresh
            # executable) every evaluation
            if isinstance(parent, ast.Call) and parent.func is node:
                yield f.finding(
                    self.code, node,
                    "`jax.jit(...)` constructed and invoked in one "
                    "expression — the wrapper (and its compile cache) is "
                    "rebuilt per call; hoist it to module scope or an "
                    "lru_cache'd builder")
                continue
            fn = enclosing_function(node)
            if fn is None:
                continue        # module scope: built once at import
            if has_decorator(fn, "lru_cache", "cache"):
                continue        # the step.py cached-builder idiom
            if loop_ancestors(node, stop_at=fn):
                yield f.finding(
                    self.code, node,
                    f"`jax.jit` constructed inside a loop in "
                    f"`{fn.name}` — every iteration builds a fresh "
                    "wrapper; hoist it out or wrap the builder in "
                    "functools.lru_cache")
            elif _is_hot_function(fn):
                yield f.finding(
                    self.code, node,
                    f"`jax.jit` constructed inside hot-path function "
                    f"`{fn.name}` — a per-call wrapper recompiles every "
                    "step; build it once (module scope, __init__, or an "
                    "lru_cache'd builder)")


# -------------------------------------------------------------------------


_SYNC_CALLS = {"float", "int", "bool", "np.asarray", "np.array",
               "numpy.asarray", "numpy.array", "jax.device_get",
               "device_get"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}


class HostSyncInHotLoop(Rule):
    """RA003: host synchronization on jitted-call results inside engine
    hot paths. Deliberate sync points are documented with a noqa or a
    baseline entry — that is the point: syncs become visible."""

    code = "RA003"
    name = "host-sync-in-hot-loop"

    def check(self, f: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        for fn in ast.walk(f.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _is_hot_function(fn):
                continue
            tainted = self._device_results(fn, ctx)
            if not tainted:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                callee = spelling(node.func) or ""
                is_sync = callee in _SYNC_CALLS
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _SYNC_METHODS:
                    is_sync = True
                    args: List[ast.AST] = [node.func.value]
                else:
                    args = list(node.args)
                if not is_sync:
                    continue
                hit = next((sp for a in args
                            for sp in self._spellings(a) if sp in tainted),
                           None)
                if hit is not None:
                    yield f.finding(
                        self.code, node,
                        f"host sync `{callee or node.func.attr}` on "
                        f"jitted result `{hit}` inside hot path "
                        f"`{fn.name}` — blocks dispatch; if deliberate, "
                        "annotate `# noqa: RA003` with a rationale")

    @staticmethod
    def _device_results(fn: ast.AST, ctx: RepoContext) -> Set[str]:
        """Spellings assigned from calls to known-jitted callables."""
        out: Set[str] = set()
        for stmt in ast.walk(fn):
            if not isinstance(stmt, ast.Assign):
                continue
            calls = [n for n in ast.walk(stmt.value)
                     if isinstance(n, ast.Call)
                     and spelling(n.func) is not None
                     and ctx.is_jitted_callable(spelling(n.func))]
            if calls:
                out.update(assign_targets(stmt))
        return out

    @staticmethod
    def _spellings(expr: ast.AST) -> Iterator[str]:
        for node in ast.walk(expr):
            sp = spelling(node)
            if sp:
                yield sp


# -------------------------------------------------------------------------


class PallasKernelConstraints(Rule):
    """RA004: TPU kernel hygiene — literal BlockSpec tiles 8/128-aligned,
    no Python-level control flow on tracer (Ref-derived) values inside
    kernel bodies (use ``pl.when`` / ``jnp.where``)."""

    code = "RA004"
    name = "pallas-kernel-constraints"

    def check(self, f: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        if "pallas" not in f.source:
            return
        yield from self._check_blockspecs(f)
        yield from self._check_prefetch_grid_specs(f)
        for kfn in self._kernel_functions(f):
            yield from self._check_kernel_body(f, kfn)

    # ---- BlockSpec literal tiles ---------------------------------------
    def _check_blockspecs(self, f: SourceFile) -> Iterator[Finding]:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            if (spelling(node.func) or "").split(".")[-1] != "BlockSpec":
                continue
            shape = node.args[0] if node.args else None
            if not isinstance(shape, (ast.Tuple, ast.List)):
                continue
            elts = shape.elts
            for pos, mult in ((-1, 128), (-2, 8)):
                if len(elts) < abs(pos):
                    continue
                el = elts[pos]
                if isinstance(el, ast.Constant) \
                        and isinstance(el.value, int):
                    v = el.value
                    if v != 1 and v % mult != 0:
                        yield f.finding(
                            self.code, el,
                            f"BlockSpec tile dim {v} in the "
                            f"{'lane' if mult == 128 else 'sublane'} "
                            f"position is not {mult}-aligned (and not 1) "
                            "— Mosaic pads or rejects it; derive the "
                            "tile via the `_fit_block` idiom")

    # ---- PrefetchScalarGridSpec contract --------------------------------
    # The paged kernels prefetch the block table + per-slot scalars so
    # BlockSpec index maps can resolve logical→physical pages in place.
    # Pallas appends every scalar-prefetch operand to each index_map call
    # (after the grid indices), so a map whose arity is not
    # grid_rank + num_scalar_prefetch silently drops (or worse, shifts)
    # the prefetch refs. Literal >1 tile dims above the sublane/lane pair
    # (the q-chunk axis of the prefill kernel) must be 8-aligned — they
    # flatten into the MXU row count; derive them via `_fit_block`.
    def _check_prefetch_grid_specs(self, f: SourceFile) -> Iterator[Finding]:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            last = (spelling(node.func) or "").split(".")[-1]
            if last != "PrefetchScalarGridSpec":
                continue
            npf_node = keyword_value(node, "num_scalar_prefetch")
            if npf_node is None and node.args:
                npf_node = node.args[0]
            if not (isinstance(npf_node, ast.Constant)
                    and isinstance(npf_node.value, int)):
                continue
            npf = npf_node.value
            grid = keyword_value(node, "grid")
            grid_rank = (len(grid.elts)
                         if isinstance(grid, (ast.Tuple, ast.List)) else None)
            scope = enclosing_function(node)
            for bs in ast.walk(node):
                if not (isinstance(bs, ast.Call)
                        and (spelling(bs.func) or "").split(".")[-1]
                        == "BlockSpec"):
                    continue
                imap = bs.args[1] if len(bs.args) > 1 \
                    else keyword_value(bs, "index_map")
                arity = self._index_map_arity(imap, scope)
                if arity is not None and grid_rank is not None \
                        and arity != grid_rank + npf:
                    yield f.finding(
                        self.code, bs,
                        f"BlockSpec index map takes {arity} params but "
                        f"this PrefetchScalarGridSpec calls it with "
                        f"{grid_rank} grid indices + {npf} scalar-prefetch "
                        "refs — prefetch operands are appended to every "
                        "index_map call, so the map must consume them")
                shape = bs.args[0] if bs.args else None
                if isinstance(shape, (ast.Tuple, ast.List)):
                    for el in shape.elts[:-2]:
                        if isinstance(el, ast.Constant) \
                                and isinstance(el.value, int) \
                                and el.value != 1 and el.value % 8 != 0:
                            yield f.finding(
                                self.code, el,
                                f"BlockSpec tile dim {el.value} on a "
                                "q-chunk (pre-sublane) axis of a "
                                "scalar-prefetch kernel is not 8-aligned "
                                "(and not 1) — it flattens into the MXU "
                                "row count; derive it via the `_fit_block` "
                                "idiom")

    @staticmethod
    def _index_map_arity(imap: Optional[ast.AST],
                         scope: Optional[ast.AST]) -> Optional[int]:
        """Parameter count of an index_map expression: a literal lambda,
        or a name resolved to a single FunctionDef in the enclosing
        function's body (ambiguous / non-local names are skipped)."""
        if isinstance(imap, ast.Lambda):
            return len(imap.args.posonlyargs) + len(imap.args.args)
        name = spelling(imap) if imap is not None else None
        if not name or "." in name or scope is None:
            return None
        defs = [n for n in ast.walk(scope)
                if isinstance(n, ast.FunctionDef) and n.name == name]
        if len(defs) != 1:
            return None
        return len(all_params(defs[0]))

    # ---- kernel bodies --------------------------------------------------
    def _kernel_functions(self, f: SourceFile) -> List[ast.FunctionDef]:
        names: Set[str] = set()
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            if (spelling(node.func) or "").split(".")[-1] != "pallas_call":
                continue
            target = node.args[0] if node.args else None
            if isinstance(target, ast.Call) and \
                    (spelling(target.func) or "").split(".")[-1] == "partial":
                target = target.args[0] if target.args else None
            sp = spelling(target) if target is not None else None
            if sp:
                names.add(sp.split(".")[-1])
        return [n for n in ast.walk(f.tree)
                if isinstance(n, ast.FunctionDef) and n.name in names]

    def _check_kernel_body(self, f: SourceFile, kfn: ast.FunctionDef
                           ) -> Iterator[Finding]:
        tainted = self._taint(kfn)
        for node in ast.walk(kfn):
            if isinstance(node, (ast.If, ast.While)):
                hit = self._tainted_in(node.test, tainted)
                if hit:
                    kw = "while" if isinstance(node, ast.While) else "if"
                    yield f.finding(
                        self.code, node,
                        f"Python `{kw}` on tracer value `{hit}` inside "
                        f"kernel `{kfn.name}` — kernel-side control flow "
                        "must use pl.when / jnp.where (a Python branch "
                        "is resolved at trace time, not per grid step)")
            elif isinstance(node, ast.Assert):
                hit = self._tainted_in(node.test, tainted)
                if hit:
                    yield f.finding(
                        self.code, node,
                        f"Python `assert` on tracer value `{hit}` inside "
                        f"kernel `{kfn.name}` — raises at trace time; "
                        "use checkify or a pl.when-guarded debug path")

    @staticmethod
    def _taint(kfn: ast.FunctionDef) -> Set[str]:
        """Names carrying per-grid-step (tracer) values: Ref reads and
        pl.program_id results, propagated through assignments.
        ``ref.shape`` / partial-bound config scalars stay untainted."""
        tainted: Set[str] = set()
        refs = {p.arg for p in all_params(kfn) if p.arg.endswith("_ref")}

        def expr_tainted(expr: ast.AST) -> bool:
            for n in ast.walk(expr):
                if isinstance(n, ast.Subscript):
                    base = spelling(n.value)
                    if base in refs:
                        return True
                if isinstance(n, ast.Call) and \
                        (spelling(n.func) or "").endswith("program_id"):
                    return True
                sp = spelling(n)
                if sp in tainted:
                    return True
            return False

        changed = True
        while changed:
            changed = False
            for stmt in ast.walk(kfn):
                if isinstance(stmt, ast.Assign) \
                        and expr_tainted(stmt.value):
                    for t in assign_targets(stmt):
                        if t not in tainted:
                            tainted.add(t)
                            changed = True
        return tainted | refs

    @staticmethod
    def _tainted_in(expr: ast.AST, tainted: Set[str]) -> Optional[str]:
        for n in ast.walk(expr):
            if isinstance(n, ast.Subscript):
                base = spelling(n.value)
                if base in tainted:
                    return base
            if isinstance(n, ast.Call) and \
                    (spelling(n.func) or "").endswith("program_id"):
                return "pl.program_id(...)"
            sp = spelling(n)
            if sp in tainted and isinstance(n, ast.Name):
                return sp
        return None


# -------------------------------------------------------------------------


_MUTATOR_METHODS = {"append", "appendleft", "add", "update", "pop",
                    "popitem", "popleft", "extend", "insert", "remove",
                    "discard", "clear", "setdefault", "difference_update",
                    "intersection_update", "symmetric_difference_update"}
# attribute types that are themselves synchronized — calling into them
# from several threads is their job
_THREADSAFE_CTORS = {"Queue", "LifoQueue", "PriorityQueue", "SimpleQueue",
                     "Event", "Lock", "RLock", "Condition", "Semaphore",
                     "BoundedSemaphore", "Barrier"}


class UnlockedCrossThreadMutation(Rule):
    """RA005: classes handed to ``threading.Thread`` targets (directly or
    via annotated parameters of the target function) must guard every
    ``self`` mutation with ``with self._lock`` — methods named
    ``*_locked`` are exempt (convention: the caller holds the lock)."""

    code = "RA005"
    name = "unlocked-cross-thread-mutation"

    def check(self, f: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        shared = self._thread_shared_classes(f, ctx)
        for cls_name in sorted(shared):
            entry = ctx.class_defs.get(cls_name)
            if entry is None:
                continue
            cf, cls = entry
            if cf.rel != f.rel:
                # report in the file that *defines* the class only when
                # that file is the one being checked — avoids duplicate
                # findings when both files are in the run set. The class
                # is checked when its defining file comes through.
                if cls_name not in self._thread_shared_classes(cf, ctx):
                    yield from self._check_class(cf, cls)
                continue
            yield from self._check_class(cf, cls)

    # ---- which classes cross threads -----------------------------------
    def _thread_shared_classes(self, f: SourceFile, ctx: RepoContext
                               ) -> Set[str]:
        shared: Set[str] = set()
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = (spelling(node.func) or "").split(".")[-1]
            if callee != "Thread":
                continue
            target = keyword_value(node, "target")
            if target is None and node.args:
                target = node.args[0]
            if target is None:
                continue
            sp = spelling(target) or ""
            entry_fn: Optional[ast.AST] = None
            if sp.startswith("self."):
                cls = enclosing_class(node)
                if cls is not None:
                    shared.add(cls.name)
                    entry_fn = next(
                        (m for m in cls.body
                         if isinstance(m, (ast.FunctionDef,
                                           ast.AsyncFunctionDef))
                         and m.name == sp.split(".", 1)[1]), None)
            else:
                base = sp.split(".")[-1]
                entry_fn = next(
                    (n for n in ast.walk(f.tree)
                     if isinstance(n, (ast.FunctionDef,
                                       ast.AsyncFunctionDef))
                     and n.name == base), None)
            if entry_fn is not None:
                for p in all_params(entry_fn):
                    ann = p.annotation
                    if isinstance(ann, ast.Constant) \
                            and isinstance(ann.value, str):
                        try:
                            ann = ast.parse(ann.value, mode="eval").body
                        except SyntaxError:
                            ann = None
                    base = (spelling(ann) or "").split(".")[-1] \
                        if ann is not None else ""
                    if base in ctx.class_defs:
                        shared.add(base)
        return shared

    # ---- per-class check ------------------------------------------------
    def _check_class(self, f: SourceFile, cls: ast.ClassDef
                     ) -> Iterator[Finding]:
        safe_attrs = self._threadsafe_attrs(cls)
        for method in cls.body:
            if not isinstance(method, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                continue
            if method.name in ("__init__", "__post_init__") \
                    or method.name.endswith("_locked"):
                continue
            for node, desc in self._mutations(method):
                attr = desc.split(".")[1] if "." in desc else desc
                if attr in safe_attrs or "lock" in attr:
                    continue
                if self._under_lock(node, method):
                    continue
                yield f.finding(
                    self.code, node,
                    f"`{cls.name}.{method.name}` mutates `{desc}` "
                    "without holding self._lock, but instances of "
                    f"`{cls.name}` cross threads (threading.Thread "
                    "target) — guard with `with self._lock:` or rename "
                    "the method `*_locked` if the caller holds it")

    @staticmethod
    def _threadsafe_attrs(cls: ast.ClassDef) -> Set[str]:
        out: Set[str] = set()
        init = next((m for m in cls.body
                     if isinstance(m, ast.FunctionDef)
                     and m.name == "__init__"), None)
        if init is None:
            return out
        for stmt in ast.walk(init):
            if not isinstance(stmt, ast.Assign):
                continue
            if not isinstance(stmt.value, ast.Call):
                continue
            ctor = (spelling(stmt.value.func) or "").split(".")[-1]
            if ctor in _THREADSAFE_CTORS:
                for t in assign_targets(stmt):
                    if t.startswith("self."):
                        out.add(t.split(".", 1)[1])
        return out

    @staticmethod
    def _mutations(method: ast.AST) -> Iterator[Tuple[ast.AST, str]]:
        for node in ast.walk(method):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    stack = [t]
                    while stack:
                        el = stack.pop()
                        if isinstance(el, (ast.Tuple, ast.List)):
                            stack.extend(el.elts)
                            continue
                        base = el
                        if isinstance(base, ast.Subscript):
                            base = base.value
                        sp = spelling(base) or ""
                        if sp.startswith("self."):
                            yield node, ".".join(sp.split(".")[:2])
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _MUTATOR_METHODS:
                sp = spelling(node.func.value) or ""
                if sp.startswith("self."):
                    yield node, ".".join(sp.split(".")[:2])

    @staticmethod
    def _under_lock(node: ast.AST, method: ast.AST) -> bool:
        cur = getattr(node, "ra_parent", None)
        while cur is not None and cur is not method:
            if isinstance(cur, (ast.With, ast.AsyncWith)):
                for item in cur.items:
                    sp = spelling(item.context_expr) or ""
                    if isinstance(item.context_expr, ast.Call):
                        sp = spelling(item.context_expr.func) or ""
                    if sp.startswith("self.") and "lock" in sp.lower():
                        return True
            cur = getattr(cur, "ra_parent", None)
        return False


# -------------------------------------------------------------------------


def default_rules() -> List[Rule]:
    return [DonationAfterUse(), JitStaticArgHygiene(), HostSyncInHotLoop(),
            PallasKernelConstraints(), UnlockedCrossThreadMutation()]


RULE_DOCS: Dict[str, str] = {
    r.code: f"{r.name}: {r.__doc__.strip().splitlines()[0]}"
    for r in default_rules()
}
