"""Expert-parallel MoE via ``shard_map`` (§Perf optimization, beyond the
GSPMD baseline in ``moe.py``).

Why: under pure GSPMD the sort/scatter dispatch is a *global* token
permutation — the partitioner replicates the full (T·k, d) token buffer in
f32 on every device (measured: 64 GiB per buffer at jamba-prefill shapes).

Layout:
- tokens stay sharded over the data axes, replicated over 'model';
- experts are sharded over 'model'; expert weights may additionally be
  sharded over a data axis (mode-dependent) and are all-gathered *inside*
  the shard to full (E_loc, d, f) — a per-layer weight AG instead of a
  per-token data AG;
- each model rank selects + computes its own experts' tokens from its
  local replica (pure local gather), then one ``psum`` over 'model'
  combines expert outputs — a Megatron row-parallel all-reduce.

Per-device working set: (E_loc, C_loc, d) with C_loc = T_loc·k/E·cap —
independent of the global token count.

Enabled by the launcher via ``cfg.moe_ep`` = "train" | "serve" (weights
FSDP-sharded on d_model vs f) + ``cfg.ep_dp_axes``.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers import swiglu

# jax >= 0.6 exposes shard_map at top level with ``check_vma``; older
# releases ship jax.experimental.shard_map with ``check_rep``.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = {"check_vma": False}
else:                                                   # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = {"check_rep": False}


def moe_ffn_ep(cfg: ModelConfig, p: Dict, x: jax.Array
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B,S,d) -> (B,S,d). Requires a mesh context (inside jit under
    ``with mesh:``) and cfg.moe_ep/'ep_dp_axes' set by the launcher."""
    from repro.runtime_context import get_mesh
    e, k = cfg.num_experts, cfg.experts_per_token
    mode = cfg.moe_ep
    dp = tuple(cfg.ep_dp_axes or ())
    tp = "model"
    mesh = get_mesh()
    tp_size = mesh.shape[tp]
    assert e % tp_size == 0, (e, tp_size)
    e_loc = e // tp_size
    # long-context decode has batch=1: tokens replicate over the data axes
    dp_prod = 1
    for ax in dp:
        dp_prod *= mesh.shape[ax]
    if x.shape[0] % max(dp_prod, 1):
        dp = ()

    # FSDP axis of the expert weights to re-gather inside the shard:
    #  train: (E, d, f) sharded P(model, dp[-1], None) — gather dim 1
    #  serve: (E, d, f) sharded P(model, None, 'data') — gather dim 2
    if mode == "train":
        wg_axis, g_dim_up, g_dim_down = dp[-1], 1, 2
        w_up_spec = P(tp, wg_axis, None)
        w_dn_spec = P(tp, None, wg_axis)
    else:
        wg_axis, g_dim_up, g_dim_down = "data", 2, 1
        w_up_spec = P(tp, None, wg_axis)
        w_dn_spec = P(tp, wg_axis, None)

    def gather(w, dim):
        return jax.lax.all_gather(w, wg_axis, axis=dim, tiled=True)

    x_spec = P(dp if dp else None, None, None)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(x_spec, P(None, None),
                  w_up_spec, w_up_spec, w_dn_spec),
        out_specs=(x_spec, P(), P(), P()),
        **_CHECK_KW)
    def inner(x_loc, router, w_gate, w_up, w_down):
        b_loc, s, d = x_loc.shape
        t_loc = b_loc * s
        xf = x_loc.reshape(t_loc, d)
        w_gate = gather(w_gate, g_dim_up)                # (E_loc, d, f)
        w_up = gather(w_up, g_dim_up)
        w_down = gather(w_down, g_dim_down)              # (E_loc, f, d)

        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)          # (T_loc, E)
        gate, ids = jax.lax.top_k(probs, k)
        if k > 1:
            gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        rank_id = jax.lax.axis_index(tp)
        cap = max(int(t_loc * k / e * cfg.capacity_factor), 4)
        # accumulate/psum in the model dtype: the f32 (T_loc, d) combine
        # buffers were the residual memory peak (measured 2 GiB/layer)
        y = jnp.zeros((t_loc, d), x_loc.dtype)
        drop = jnp.zeros((), jnp.float32)
        for el in range(e_loc):
            ge = rank_id * e_loc + el
            sel = (ids == ge)                            # (T_loc, k)
            tok_gate = (gate * sel).sum(-1)
            routed = sel.any(-1)
            order = jnp.argsort(~routed)                 # routed first
            idx = order[:cap]
            valid = routed[idx]
            xe = xf[idx] * valid[:, None].astype(xf.dtype)
            h = jax.nn.silu(xe @ w_gate[el]) * (xe @ w_up[el])
            out = h @ w_down[el]
            out = out * (tok_gate[idx] * valid)[:, None].astype(out.dtype)
            y = y.at[idx].add(out.astype(y.dtype), mode="drop")
            drop += routed.sum().astype(jnp.float32) \
                - valid.sum().astype(jnp.float32)

        y = jax.lax.psum(y, tp)                          # combine experts

        me = probs.mean(axis=0)
        ce = jnp.zeros((e,)).at[ids.reshape(-1)].add(1.0) / (t_loc * k)
        lb = jax.lax.pmean(e * jnp.sum(me * ce), tp)
        zl = jax.lax.pmean(jnp.mean(jax.nn.logsumexp(logits, -1) ** 2), tp)
        df = jax.lax.pmean(drop / (t_loc * k), tp)
        for ax in dp:
            lb = jax.lax.pmean(lb, ax)
            zl = jax.lax.pmean(zl, ax)
            df = jax.lax.pmean(df, ax)
        return (y.reshape(b_loc, s, d), lb, zl, df)

    y, lb, zl, df = inner(x, p["router"], p["w_gate"], p["w_up"],
                          p["w_down"])
    if cfg.shared_expert:
        y = y + swiglu(x, p["shared"])
    return y, {"moe_load_balance": lb, "moe_z_loss": zl,
               "moe_drop_frac": df}
