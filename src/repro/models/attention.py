"""Attention: naive, chunked (flash-style online softmax in pure jnp) and
single-token decode paths. GQA is handled with grouped einsums (no kv
materialized repeats). Supports causal, sliding-window and bidirectional
masks plus Gemma-2 attention-logit softcapping.

The chunked path is the default for large shapes: it never materializes the
(Sq, Sk) score matrix, scanning kv blocks with running (m, l, acc) — the
same algorithm the Pallas `flash_attention` kernel implements on TPU (the
kernel is used on real hardware; this path is the lowering/CPU oracle).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30
PAD_POS = 2 ** 30          # sentinel position marking padded keys


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def _mask(pos_q, pos_k, kind: str, window: int):
    """(..., Sq, Sk) boolean mask. kind: causal | local | bidir.
    Keys at the PAD_POS sentinel are masked in every kind."""
    valid = (pos_k < PAD_POS)[..., None, :]
    d = pos_q[..., :, None] - pos_k[..., None, :]
    if kind == "causal":
        return (d >= 0) & valid
    if kind == "local":
        return (d >= 0) & (d < window) & valid
    if kind == "bidir":
        return jnp.broadcast_to(valid, d.shape)
    raise ValueError(kind)


def _scores(q, k, cap: Optional[float]):
    """q (B,Sq,G,R,D), k (B,Sk,G,D) -> (B,G,R,Sq,Sk), pre-softmax."""
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    s = s * (q.shape[-1] ** -0.5)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    return s


def naive_attention(q, k, v, *, pos_q, pos_k, kind="causal", window=4096,
                    softcap=None):
    """Reference O(Sq*Sk) attention. q (B,Sq,Hq,D); k,v (B,Sk,Hkv,D)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g, r = hkv, hq // hkv
    qg = q.reshape(b, sq, g, r, d)
    s = _scores(qg, k, softcap)                              # (B,G,R,Sq,Sk)
    m = _mask(pos_q, pos_k, kind, window)[:, None, None]     # (B,1,1,Sq,Sk)
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, d).astype(q.dtype)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(5, 6, 7, 8, 9))
def _flash(q, k, v, pos_q, pos_k, kind, window, softcap, q_chunk, kv_chunk):
    o, _ = _flash_fwd_impl(q, k, v, pos_q, pos_k, kind, window, softcap,
                           q_chunk, kv_chunk)
    return o


def _flash_fwd_impl(q, k, v, pos_q, pos_k, kind, window, softcap,
                    q_chunk, kv_chunk):
    o, lse = _chunked_fwd(q, k, v, pos_q=pos_q, pos_k=pos_k, kind=kind,
                          window=window, softcap=softcap, q_chunk=q_chunk,
                          kv_chunk=kv_chunk)
    return o, lse


def _flash_vjp_fwd(q, k, v, pos_q, pos_k, kind, window, softcap, q_chunk,
                   kv_chunk):
    o, lse = _flash_fwd_impl(q, k, v, pos_q, pos_k, kind, window, softcap,
                             q_chunk, kv_chunk)
    return o, (q, k, v, pos_q, pos_k, o, lse)


def _flash_vjp_bwd(kind, window, softcap, q_chunk, kv_chunk, res, do):
    q, k, v, pos_q, pos_k, o, lse = res
    dq, dk, dv = _chunked_bwd(q, k, v, pos_q, pos_k, o, lse, do,
                              kind=kind, window=window, softcap=softcap,
                              q_chunk=q_chunk, kv_chunk=kv_chunk)
    return dq, dk, dv, None, None


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def _pad_blocks(q, k, v, pos_q, pos_k, q_chunk, kv_chunk):
    sq, sk = q.shape[1], k.shape[1]
    pad_q = (-sq) % q_chunk
    pad_k = (-sk) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        pos_q = jnp.pad(pos_q, ((0, 0), (0, pad_q)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        pos_k = jnp.pad(pos_k, ((0, 0), (0, pad_k)), constant_values=PAD_POS)
    return q, k, v, pos_q, pos_k


def _block_scores(qi, ki, pq, pk, kind, window, softcap):
    """Masked pre-softmax scores for one (q-block, kv-block) pair.
    qi (B,qc,G,R,D), ki (B,kc,G,D) -> (B,G,R,qc,kc)."""
    s = _scores(qi, ki, softcap)
    msk = _mask(pq, pk, kind, window)[:, None, None]
    return jnp.where(msk, s, NEG_INF), msk


def _chunked_fwd(q, k, v, *, pos_q, pos_k, kind, window, softcap,
                 q_chunk, kv_chunk):
    """Returns (o, lse) — lse (B,G,R,Sq) saved for the flash backward."""
    b, sq_orig, hq, d = q.shape
    hkv = k.shape[2]
    g, r = hkv, hq // hkv
    q, k, v, pos_q, pos_k = _pad_blocks(q, k, v, pos_q, pos_k, q_chunk,
                                        kv_chunk)
    sq, sk = q.shape[1], k.shape[1]
    nq, nk = sq // q_chunk, sk // kv_chunk

    qb = q.reshape(b, nq, q_chunk, g, r, d).transpose(1, 0, 2, 3, 4, 5)
    pqb = pos_q.reshape(b, nq, q_chunk).transpose(1, 0, 2)
    kb = k.reshape(b, nk, kv_chunk, g, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, kv_chunk, g, d).transpose(1, 0, 2, 3, 4)
    pkb = pos_k.reshape(b, nk, kv_chunk).transpose(1, 0, 2)

    def q_block(qi_pq):
        qi, pq = qi_pq

        def kv_block(carry, kv):
            m_run, l_run, acc = carry
            ki, vi, pk = kv
            s, _ = _block_scores(qi, ki, pq, pk, kind, window, softcap)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            scale = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * scale + p.sum(axis=-1)
            acc = acc * scale[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc), None

        qc = qi.shape[1]
        m0 = jnp.full((b, g, r, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, r, qc), jnp.float32)
        a0 = jnp.zeros((b, g, r, qc, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                          (kb, vb, pkb))
        l_safe = jnp.maximum(l_f, 1e-30)
        o = acc / l_safe[..., None]
        lse = m_f + jnp.log(l_safe)
        return o.transpose(0, 3, 1, 2, 4), lse               # (B,qc,G,R,D)

    o, lse = jax.lax.map(q_block, (qb, pqb))
    o = o.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, d)
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(b, g, r, sq)
    return o[:, :sq_orig].astype(q.dtype), lse[..., :sq_orig]


def _chunked_bwd(q, k, v, pos_q, pos_k, o, lse, do, *, kind, window,
                 softcap, q_chunk, kv_chunk):
    """Flash backward: recompute scores blockwise; nothing O(Sq·Sk) is ever
    materialized. Two passes: kv-major for (dk, dv), q-major for dq."""
    b, sq_orig, hq, d = q.shape
    sk_orig, hkv = k.shape[1], k.shape[2]
    g, r = hkv, hq // hkv
    q, k, v, pos_q, pos_k = _pad_blocks(q, k, v, pos_q, pos_k, q_chunk,
                                        kv_chunk)
    sq, sk = q.shape[1], k.shape[1]
    nq, nk = sq // q_chunk, sk // kv_chunk
    if sq != sq_orig:
        o = jnp.pad(o, ((0, 0), (0, sq - sq_orig), (0, 0), (0, 0)))
        do = jnp.pad(do, ((0, 0), (0, sq - sq_orig), (0, 0), (0, 0)))
        lse = jnp.pad(lse, ((0, 0), (0, 0), (0, 0), (0, sq - sq_orig)))

    f32 = jnp.float32
    qb = q.reshape(b, nq, q_chunk, g, r, d).transpose(1, 0, 2, 3, 4, 5)
    pqb = pos_q.reshape(b, nq, q_chunk).transpose(1, 0, 2)
    kb = k.reshape(b, nk, kv_chunk, g, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, kv_chunk, g, d).transpose(1, 0, 2, 3, 4)
    pkb = pos_k.reshape(b, nk, kv_chunk).transpose(1, 0, 2)
    dob = do.astype(f32).reshape(b, nq, q_chunk, g, r, d
                                 ).transpose(1, 0, 2, 3, 4, 5)
    lseb = lse.reshape(b, g, r, nq, q_chunk).transpose(3, 0, 1, 2, 4)
    # D_i = sum_d dO_id O_id   (nq, B, G, R, qc)
    ob = o.astype(f32).reshape(b, nq, q_chunk, g, r, d
                               ).transpose(1, 0, 2, 3, 4, 5)
    db = (dob * ob).sum(-1).transpose(0, 1, 3, 4, 2)

    scale = d ** -0.5

    def p_and_dsraw(qi, ki, pq, pk, lse_i):
        """p (B,G,R,qc,kc) and raw-score derivative chain."""
        u = jnp.einsum("bqgrd,bkgd->bgrqk", qi.astype(f32),
                       ki.astype(f32)) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(u / softcap)
            dchain = 1.0 - (s / softcap) ** 2
        else:
            s = u
            dchain = jnp.ones_like(s)
        msk = _mask(pq, pk, kind, window)[:, None, None]
        p = jnp.where(msk, jnp.exp(s - lse_i[..., None]), 0.0)
        return p, dchain

    # ---- pass 1: dq (scan kv blocks per q block) -------------------------
    def q_major(args):
        qi, pq, lse_i, do_i, d_i = args

        def kv_step(dq_acc, kv):
            ki, vi, pk = kv
            p, dchain = p_and_dsraw(qi, ki, pq, pk, lse_i)
            dp = jnp.einsum("bqgrd,bkgd->bgrqk", do_i, vi.astype(f32))
            ds = p * (dp - d_i[..., None]) * dchain
            dq_acc += jnp.einsum("bgrqk,bkgd->bqgrd", ds,
                                 ki.astype(f32)) * scale
            return dq_acc, None

        dq0 = jnp.zeros((b, q_chunk, g, r, d), f32)
        dq_i, _ = jax.lax.scan(kv_step, dq0, (kb, vb, pkb))
        return dq_i

    dq = jax.lax.map(q_major, (qb, pqb, lseb, dob, db))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, d)

    # ---- pass 2: dk, dv (scan q blocks per kv block) ---------------------
    def kv_major(args):
        ki, vi, pk = args

        def q_step(carry, qs):
            dk_acc, dv_acc = carry
            qi, pq, lse_i, do_i, d_i = qs
            p, dchain = p_and_dsraw(qi, ki, pq, pk, lse_i)
            dv_acc += jnp.einsum("bgrqk,bqgrd->bkgd", p, do_i)
            dp = jnp.einsum("bqgrd,bkgd->bgrqk", do_i, vi.astype(f32))
            ds = p * (dp - d_i[..., None]) * dchain
            dk_acc += jnp.einsum("bgrqk,bqgrd->bkgd", ds,
                                 qi.astype(f32)) * scale
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, kv_chunk, g, d), f32)
        (dk_i, dv_i), _ = jax.lax.scan(q_step, (z, z),
                                       (qb, pqb, lseb, dob, db))
        return dk_i, dv_i

    dk, dv = jax.lax.map(kv_major, (kb, vb, pkb))
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(b, sk, hkv, d)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(b, sk, hkv, d)
    return (dq[:, :sq_orig].astype(q.dtype),
            dk[:, :sk_orig].astype(k.dtype),
            dv[:, :sk_orig].astype(v.dtype))


def chunked_attention(q, k, v, *, pos_q, pos_k, kind="causal", window=4096,
                      softcap=None, q_chunk=512, kv_chunk=512):
    """Flash-style attention with a flash *backward* (custom VJP): neither
    direction materializes the (Sq, Sk) score matrix, and — critically for
    training memory — autodiff never sees the online-softmax scan, so no
    O(Sq·Sk) scan residuals are saved. This is the jnp twin of the Pallas
    ``flash_attention`` kernel."""
    return _flash(q, k, v, pos_q, pos_k, kind, window, softcap,
                  min(q_chunk, q.shape[1]), min(kv_chunk, k.shape[1]))


def _chunked_attention_legacy(q, k, v, *, pos_q, pos_k, kind="causal",
                              window=4096, softcap=None, q_chunk=512,
                              kv_chunk=512):
    """Flash-style attention: outer scan over query blocks, inner scan over
    kv blocks with online-softmax accumulators. Peak live memory is
    O(q_chunk * kv_chunk) scores instead of O(Sq * Sk)."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g, r = hkv, hq // hkv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    sq_orig = sq
    pad_q = (-sq) % q_chunk
    pad_k = (-sk) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        pos_q = jnp.pad(pos_q, ((0, 0), (0, pad_q)))
        sq += pad_q
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        pos_k = jnp.pad(pos_k, ((0, 0), (0, pad_k)),
                        constant_values=PAD_POS)
        sk += pad_k
    nq, nk = sq // q_chunk, sk // kv_chunk

    qb = q.reshape(b, nq, q_chunk, g, r, d).transpose(1, 0, 2, 3, 4, 5)
    pqb = pos_q.reshape(b, nq, q_chunk).transpose(1, 0, 2)
    kb = k.reshape(b, nk, kv_chunk, g, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, kv_chunk, g, d).transpose(1, 0, 2, 3, 4)
    pkb = pos_k.reshape(b, nk, kv_chunk).transpose(1, 0, 2)

    def q_block(qi_pq):
        qi, pq = qi_pq                                    # (B,qc,G,R,D), (B,qc)

        def kv_block(carry, kv):
            m_run, l_run, acc = carry
            ki, vi, pk = kv
            s = _scores(qi, ki, softcap)                  # (B,G,R,qc,kc)
            msk = _mask(pq, pk, kind, window)[:, None, None]
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            scale = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * scale + p.sum(axis=-1)
            acc = acc * scale[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc), None

        qc = qi.shape[1]
        m0 = jnp.full((b, g, r, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, r, qc), jnp.float32)
        a0 = jnp.zeros((b, g, r, qc, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                          (kb, vb, pkb))
        o = acc / jnp.maximum(l_f, 1e-30)[..., None]      # (B,G,R,qc,D)
        return o.transpose(0, 3, 1, 2, 4)                 # (B,qc,G,R,D)

    o = jax.lax.map(q_block, (qb, pqb))                   # (nq,B,qc,G,R,D)
    o = o.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, d)
    return o[:, :sq_orig].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, pos, kind="causal",
                     window=4096, softcap=None, length=None):
    """Single-token attention against a (B, Smax, Hkv, D) cache.

    q: (B, 1, Hq, D); pos: current position — a scalar, or a (B,) vector
    when rows decode at heterogeneous positions (continuous batching).
    Entries > pos are masked. ``length`` (static int) is an optional
    upper bound on ``pos + 1``: entries at ``>= length`` are provably
    masked, so the cache is sliced to ``length`` and the score/mask/
    softmax work on the padded tail is skipped entirely — bit-identical
    output (masked tail entries contribute exact zeros either way).
    """
    b, _, hq, d = q.shape
    if length is not None and length < k_cache.shape[1]:
        k_cache = k_cache[:, :length]
        v_cache = v_cache[:, :length]
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g, r = hkv, hq // hkv
    qg = q.reshape(b, 1, g, r, d)
    s = _scores(qg, k_cache, softcap)[:, :, :, 0]          # (B,G,R,Smax)
    idx = jnp.arange(smax)
    posv = jnp.reshape(jnp.asarray(pos), (-1, 1))          # (1|B, 1)
    valid = idx[None, :] <= posv
    if kind == "local":
        valid &= idx[None, :] > posv - window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, hq, d).astype(q.dtype)


def ring_decode_attention(q, k_ring, v_ring, *, pos, window,
                          softcap=None):
    """Single-token attention against a ring-buffered local-window cache.

    k_ring/v_ring: (B, W, Hkv, D) where slot s holds the key of position
    p = pos − ((pos − s) mod W) (the unique p ≡ s (mod W) in
    (pos−W, pos]); entries with p < 0 have not been written yet.
    """
    b, _, hq, d = q.shape
    w, hkv = k_ring.shape[1], k_ring.shape[2]
    g, r = hkv, hq // hkv
    qg = q.reshape(b, 1, g, r, d)
    s = _scores(qg, k_ring, softcap)[:, :, :, 0]             # (B,G,R,W)
    slot = jnp.arange(w)
    p = pos - jnp.mod(pos - slot, w)                         # slot position
    valid = p >= 0
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", prob, v_ring.astype(jnp.float32))
    return o.reshape(b, 1, hq, d).astype(q.dtype)


def fill_ring(k: jax.Array, window: int) -> jax.Array:
    """Arrange the last `window` entries of k (B,S,H,D) into ring order
    (slot s = position p with p ≡ s mod window). For S < window the tail
    slots stay zero (masked via the position-recovery rule)."""
    b, s_len = k.shape[0], k.shape[1]
    w = window
    if s_len < w:
        pad = jnp.zeros((b, w - s_len) + k.shape[2:], k.dtype)
        return jnp.concatenate([k, pad], axis=1)
    k_last = k[:, s_len - w:]
    idx = jnp.mod(jnp.arange(w) - (s_len % w), w)
    return jnp.take(k_last, idx, axis=1)


def _standard_positions(pos) -> bool:
    """Concrete positions must be the contiguous arange layout the flash
    kernel's offset-derived masks assume; traced positions cannot be
    inspected and are trusted (the documented ``impl="pallas"`` caveat —
    in-repo jit callers guarantee it, the paged-prefill offset path
    downgrades explicitly)."""
    if isinstance(pos, jax.core.Tracer):
        return True
    arr = jnp.asarray(pos)
    return bool((arr == jnp.arange(arr.shape[-1])).all())


def attention(q, k, v, *, pos_q, pos_k, kind="causal", window=4096,
              softcap=None, impl="chunked", chunk=512):
    """Full-sequence attention dispatch: naive | chunked | pallas.

    ``impl="pallas"`` routes to the Mosaic flash kernel (interpret mode
    off-TPU). The kernel derives its masks from absolute block offsets,
    so it assumes the standard contiguous layout ``pos_q = arange(Sq)``,
    ``pos_k = arange(Sk)`` with no PAD_POS sentinels — the model's
    training/prefill forward. Concrete (eager) positions are checked and
    quietly fall back to the jnp paths when they don't match; callers
    under jit with offset or padded positions (paged chunked prefill)
    must pick a jnp impl themselves.
    """
    if (impl == "pallas" and kind in ("causal", "local", "bidir")
            and _standard_positions(pos_q) and _standard_positions(pos_k)):
        from repro.kernels.ops import flash_attention
        return flash_attention(q, k, v, causal=(kind != "bidir"),
                               window=(window if kind == "local" else None),
                               softcap=softcap,
                               block_q=min(chunk, 128), block_k=min(chunk, 128))
    if impl == "naive" or q.shape[1] <= chunk:
        return naive_attention(q, k, v, pos_q=pos_q, pos_k=pos_k, kind=kind,
                               window=window, softcap=softcap)
    return chunked_attention(q, k, v, pos_q=pos_q, pos_k=pos_k, kind=kind,
                             window=window, softcap=softcap,
                             q_chunk=chunk, kv_chunk=chunk)
