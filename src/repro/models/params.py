"""Parameter templates.

Every parameter leaf is declared once as a ``ParamTemplate`` carrying its
shape, initializer and *logical axes*. From the template tree we derive:

- ``init_params``      — materialized arrays (smoke tests / real training)
- ``abstract_params``  — ``jax.ShapeDtypeStruct`` stand-ins (multi-pod
                          dry-run: no allocation ever happens)
- sharding specs       — ``repro.parallel.axes`` maps logical axes to
                          mesh axes per execution mode (consumed through
                          ``repro.parallel.ExecutionPlan``)

Logical axis vocabulary:
  vocab, embed (d_model), ffn (d_ff), qkv (flattened heads*head_dim),
  kv (flattened kv_heads*head_dim), experts, dinner (SSM inner),
  ssm_in (SSM in-proj fan-out), conv, heads (SSM heads), state, None
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (ATTN, CROSS, LOCAL, MAMBA, MLP, MOE, NONE,
                          ModelConfig)


@dataclasses.dataclass(frozen=True)
class ParamTemplate:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | ssm_a | ssm_dt
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _mlp_templates(cfg: ModelConfig) -> Dict[str, ParamTemplate]:
    d, f = cfg.d_model, cfg.d_ff
    out_scale = 0.02 / np.sqrt(2 * cfg.num_layers)
    return {
        "w_gate": ParamTemplate((d, f), ("embed", "ffn")),
        "w_up": ParamTemplate((d, f), ("embed", "ffn")),
        "w_down": ParamTemplate((f, d), ("ffn", "embed"), scale=out_scale),
    }


def _moe_templates(cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    out_scale = 0.02 / np.sqrt(2 * cfg.num_layers)
    t = {
        "router": ParamTemplate((d, e), ("embed", None)),
        "w_gate": ParamTemplate((e, d, f), ("experts", "embed", "expert_ffn")),
        "w_up": ParamTemplate((e, d, f), ("experts", "embed", "expert_ffn")),
        "w_down": ParamTemplate((e, f, d), ("experts", "expert_ffn", "embed"),
                                scale=out_scale),
    }
    if cfg.shared_expert:
        t["shared"] = _mlp_templates(cfg)
    return t


def _attn_templates(cfg: ModelConfig, cross: bool = False) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    out_scale = 0.02 / np.sqrt(2 * max(cfg.num_layers, 1))
    t = {
        "wq": ParamTemplate((d, nq * h), ("embed", "qkv")),
        "wk": ParamTemplate((d, nkv * h), ("embed", "kv")),
        "wv": ParamTemplate((d, nkv * h), ("embed", "kv")),
        "wo": ParamTemplate((nq * h, d), ("qkv", "embed"), scale=out_scale),
    }
    if cfg.qkv_bias and not cross:
        t["bq"] = ParamTemplate((nq * h,), ("qkv",), init="zeros")
        t["bk"] = ParamTemplate((nkv * h,), ("kv",), init="zeros")
        t["bv"] = ParamTemplate((nkv * h,), ("kv",), init="zeros")
    return t


def _mamba_templates(cfg: ModelConfig) -> Dict[str, ParamTemplate]:
    d = cfg.d_model
    di, N, G, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_heads
    conv_ch = di + 2 * G * N
    fan_out = 2 * di + 2 * G * N + H      # [z, x, B, C, dt]
    out_scale = 0.02 / np.sqrt(2 * cfg.num_layers)
    return {
        "in_proj": ParamTemplate((d, fan_out), ("embed", "ssm_in")),
        "conv_w": ParamTemplate((cfg.ssm_conv, conv_ch), (None, "dinner")),
        "conv_b": ParamTemplate((conv_ch,), ("dinner",), init="zeros"),
        "A_log": ParamTemplate((H,), ("heads",), init="ssm_a"),
        "D": ParamTemplate((H,), ("heads",), init="ones"),
        "dt_bias": ParamTemplate((H,), ("heads",), init="ssm_dt"),
        "gate_norm": ParamTemplate((di,), ("dinner",), init="ones"),
        "out_proj": ParamTemplate((di, d), ("dinner", "embed"),
                                  scale=out_scale),
    }


def _layer_templates(cfg: ModelConfig, kind: str, ffn_kind: str,
                     decoder: bool = False) -> Dict[str, Any]:
    d = cfg.d_model
    t: Dict[str, Any] = {"norm": ParamTemplate((d,), ("embed",), init="ones")}
    if kind in (ATTN, LOCAL):
        t["attn"] = _attn_templates(cfg)
        if cfg.is_encdec and decoder:      # whisper decoder: +cross-attn
            t["cross_norm"] = ParamTemplate((d,), ("embed",), init="ones")
            t["cross"] = _attn_templates(cfg, cross=True)
    elif kind == CROSS:
        t["attn"] = _attn_templates(cfg, cross=True)
    elif kind == MAMBA:
        t["mamba"] = _mamba_templates(cfg)
    else:
        raise ValueError(kind)
    if ffn_kind == MLP:
        t["ffn_norm"] = ParamTemplate((d,), ("embed",), init="ones")
        t["mlp"] = _mlp_templates(cfg)
    elif ffn_kind == MOE:
        t["ffn_norm"] = ParamTemplate((d,), ("embed",), init="ones")
        t["moe"] = _moe_templates(cfg)
    elif ffn_kind == NONE:
        pass
    else:
        raise ValueError(ffn_kind)
    return t


def _stack(tree: Any, n: int) -> Any:
    """Prepend a stacking dimension of size n to every template (for the
    scanned super-blocks)."""
    def f(t: ParamTemplate) -> ParamTemplate:
        return dataclasses.replace(t, shape=(n,) + t.shape,
                                   axes=(None,) + t.axes)
    return jax.tree_util.tree_map(f, tree,
                                  is_leaf=lambda x: isinstance(x, ParamTemplate))


def param_templates(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    block = {
        f"layer_{i}": _layer_templates(cfg, kind, cfg.ffn_kind(i),
                                       decoder=True)
        for i, kind in enumerate(cfg.block_pattern)
    }
    t: Dict[str, Any] = {
        "embed": ParamTemplate((v, d), ("vocab", "embed"), scale=1.0),
        "blocks": _stack(block, cfg.num_blocks),
        "final_norm": ParamTemplate((d,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamTemplate((d, v), ("embed", "vocab"))
    if cfg.encoder_layers:
        enc_layer = _layer_templates(
            dataclasses.replace(cfg, qkv_bias=False, num_layers=cfg.encoder_layers),
            ATTN, MLP, decoder=False)
        t["encoder"] = {
            "blocks": _stack(enc_layer, cfg.encoder_layers),
            "final_norm": ParamTemplate((d,), ("embed",), init="ones"),
        }
    return t


# --------------------------------------------------------------------------
# materialization


def _is_t(x) -> bool:
    return isinstance(x, ParamTemplate)


def init_params(cfg: ModelConfig, key: jax.Array) -> Any:
    """Materialize real parameters (used for smoke-scale models and RL
    training; the full configs are only ever abstract)."""
    templates = param_templates(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(templates, is_leaf=_is_t)
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.dtype)

    def mk(t: ParamTemplate, k: jax.Array) -> jax.Array:
        if t.init == "zeros":
            return jnp.zeros(t.shape, dtype)
        if t.init == "ones":
            return jnp.ones(t.shape, dtype)
        if t.init == "ssm_a":          # A in [1, 16), stored as log
            u = jax.random.uniform(k, t.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dtype)
        if t.init == "ssm_dt":         # dt bias ~ softplus^-1(U[1e-3, 1e-1])
            u = jax.random.uniform(k, t.shape, jnp.float32, 1e-3, 1e-1)
            return jnp.log(jnp.expm1(u)).astype(dtype)
        return (t.scale * jax.random.normal(k, t.shape, jnp.float32)
                ).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [mk(t, k) for t, k in zip(leaves, keys, strict=True)])


def abstract_params(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct tree for .lower() — no device allocation."""
    dtype = jnp.dtype(cfg.dtype)
    return jax.tree_util.tree_map(
        lambda t: jax.ShapeDtypeStruct(t.shape, dtype),
        param_templates(cfg), is_leaf=_is_t)


def param_axes(cfg: ModelConfig) -> Any:
    """Tree of logical-axis tuples matching the params tree."""
    return jax.tree_util.tree_map(lambda t: t.axes, param_templates(cfg),
                                  is_leaf=_is_t)
