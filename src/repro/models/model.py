"""The unified language model: forward / encode / prefill / decode for every
supported architecture family (dense, MoE, SSM, hybrid, VLM, audio enc-dec).

Layers are applied in scanned *super-blocks* of one ``block_pattern`` period
(homogeneous across depth), keeping HLO size O(1) in depth. Activation
checkpointing (``jax.checkpoint``) wraps the block body when ``cfg.remat``.

All functions are pure; parameters come from ``repro.models.params``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ATTN, CROSS, LOCAL, MAMBA, MLP, MOE, ModelConfig
from repro.models import attention as attn_mod
from repro.models.layers import rmsnorm, rope, softcap, swiglu
from repro.models.moe import moe_ffn
from repro.models.ssm import mamba_block


# --------------------------------------------------------------------------
# sub-layer application


def _project_qkv(cfg: ModelConfig, p: Dict, xq: jax.Array,
                 xkv: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, sq, _ = xq.shape
    sk = xkv.shape[1]
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, sq, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, sk, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, sk, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _self_attn(cfg: ModelConfig, p: Dict, x: jax.Array, *, kind: str,
               positions: jax.Array, cache: Optional[Dict], pos,
               bidir: bool = False, page_table: Optional[jax.Array] = None,
               record: bool = False):
    """Self-attention sub-layer body (input already normed).

    Returns (out, new_cache). In decode mode (pos is not None) x is
    (B,1,d) and the cache k/v are updated in place at ``pos``. When the
    cache is *paged* (holds "kp"/"vp" page pools and ``page_table`` maps
    (slot, logical_page) -> physical page), both chunked prefill and
    decode go through the paged scatter/gather path instead.

    ``record=True`` (paged chunked-prefill path only — the speculative
    verification forward) returns a third element: the post-rope queries
    and the per-layer attention output, both (B, Sq, Hq, Dh), so the
    caller can replay all layers' attention through one fused
    ``paged_prefill_layers`` launch.
    """
    q, k, v = _project_qkv(cfg, p, x, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cfg.attn_gather_qkv and cfg.act_sharding is not None and pos is None:
        # §Perf H-A1 (kept for the record; REFUTED — GSPMD's own layout
        # beat it 3.3× on collective bytes): gather the sequence here and
        # run attention head-sharded.
        dp = cfg.act_sharding[0]
        spec = jax.sharding.PartitionSpec(dp, None, "model", None)
        q = jax.lax.with_sharding_constraint(q, spec)
        k = jax.lax.with_sharding_constraint(k, spec)
        v = jax.lax.with_sharding_constraint(v, spec)
    mask_kind = ("bidir" if bidir else
                 "local" if kind == LOCAL else "causal")

    if cache is not None and "kp" in cache:               # paged KV cache
        b, sq = x.shape[0], x.shape[1]
        kp, vp = cache["kp"], cache["vp"]
        page_size = kp.shape[1]
        page = positions // page_size                     # (B, Sq) logical
        off = positions % page_size
        # logical pages past the block-table width (only padded prefill
        # tails reach here) must gather an OOB sentinel so the scatter
        # below drops the write instead of clamping onto a live page
        phys = jnp.take_along_axis(page_table, page, axis=1, mode="fill",
                                   fill_value=jnp.iinfo(jnp.int32).min)
        kp = kp.at[phys, off].set(k.astype(kp.dtype))
        vp = vp.at[phys, off].set(v.astype(vp.dtype))
        if sq == 1:                                       # decode
            # hot loop: attend the pools in place (or via the bit-exact
            # gather fallback) — repro.kernels.ops.paged_decode. The
            # engine narrows page_table to the live high-water mark, so
            # every impl scales with context, not pool capacity.
            from repro.kernels.ops import paged_decode
            o = paged_decode(q, kp, vp, page_table, positions[:, 0] + 1,
                             kind=mask_kind, window=cfg.sliding_window,
                             softcap=cfg.attn_softcap,
                             impl=cfg.paged_attn_impl)
        else:                                             # chunked prefill
            # attend the pools in place (ref/pallas) or via the dense
            # per-slot gather (the bit-exact ModelConfig default) —
            # repro.kernels.ops.paged_prefill. The engine narrows
            # page_table to pages_for(c0 + C), so the gather view is
            # bounded by the chunk's pow2 width bucket; the kernel/ref
            # paths never materialize it at all.
            from repro.kernels.ops import paged_prefill
            o = paged_prefill(q, kp, vp, page_table, positions,
                              kind=mask_kind, window=cfg.sliding_window,
                              softcap=cfg.attn_softcap,
                              impl=cfg.paged_attn_impl,
                              attn_impl=cfg.attn_impl, chunk=cfg.attn_chunk)
            if record:
                return (o.reshape(b, sq, -1) @ p["wo"], {"kp": kp, "vp": vp},
                        {"q": q, "o": o})
        return o.reshape(b, sq, -1) @ p["wo"], {"kp": kp, "vp": vp}

    ring = (cfg.local_ring_kv and kind == LOCAL)
    if pos is not None:                                   # decode
        w_pos = jnp.mod(pos, cache["k"].shape[1]) if ring else pos
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, w_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, w_pos, 0, 0))
        if ring:
            o = attn_mod.ring_decode_attention(
                q, kc, vc, pos=pos, window=cfg.sliding_window,
                softcap=cfg.attn_softcap)
        else:
            o = attn_mod.decode_attention(q, kc, vc, pos=pos,
                                          kind=mask_kind,
                                          window=cfg.sliding_window,
                                          softcap=cfg.attn_softcap)
        new_cache = {"k": kc, "v": vc}
    else:
        o = attn_mod.attention(q, k, v, pos_q=positions, pos_k=positions,
                               kind=mask_kind, window=cfg.sliding_window,
                               softcap=cfg.attn_softcap,
                               impl=cfg.attn_impl, chunk=cfg.attn_chunk)
        new_cache = None
        if cache is not None:                             # prefill fills cache
            if ring:
                w = cache["k"].shape[1]
                kc = attn_mod.fill_ring(k, w).astype(cache["k"].dtype)
                vc = attn_mod.fill_ring(v, w).astype(cache["v"].dtype)
            else:
                kc = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": kc, "v": vc}
    b, sq = x.shape[0], x.shape[1]
    return o.reshape(b, sq, -1) @ p["wo"], new_cache


def _cross_attn(cfg: ModelConfig, p: Dict, x: jax.Array, *,
                memory: Optional[jax.Array], cache: Optional[Dict]):
    """Cross-attention to a modality/encoder memory. If ``cache`` holds
    precomputed k_mem/v_mem they are used (decode); otherwise projected
    from ``memory``."""
    b, sq, _ = x.shape
    q = (x @ p["wq"]).reshape(b, sq, cfg.num_heads, cfg.head_dim)
    if cache is not None and "k_mem" in cache:
        k, v = cache["k_mem"], cache["v_mem"]
    else:
        sk = memory.shape[1]
        k = (memory @ p["wk"]).reshape(b, sk, cfg.num_kv_heads, cfg.head_dim)
        v = (memory @ p["wv"]).reshape(b, sk, cfg.num_kv_heads, cfg.head_dim)
    sk = k.shape[1]
    pos_q = jnp.zeros((b, sq), jnp.int32)
    pos_k = jnp.zeros((b, sk), jnp.int32)
    o = attn_mod.attention(q, k, v, pos_q=pos_q, pos_k=pos_k, kind="bidir",
                           impl=cfg.attn_impl, chunk=cfg.attn_chunk)
    return o.reshape(b, sq, -1) @ p["wo"]


def _ffn(cfg: ModelConfig, kind: str, p: Dict, x: jax.Array,
         aux: Dict[str, jax.Array]):
    if kind == MLP:
        return x + swiglu(rmsnorm(x, p["ffn_norm"], cfg.norm_eps), p["mlp"]), aux
    if kind == MOE:
        h_in = rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
        if cfg.moe_ep is not None:
            from repro.models.moe_ep import moe_ffn_ep
            y, a = moe_ffn_ep(cfg, p["moe"], h_in)
        else:
            y, a = moe_ffn(cfg, p["moe"], h_in)
        aux = {k: aux.get(k, 0.0) + v for k, v in a.items()}
        return x + y, aux
    return x, aux                                          # NONE


def _apply_layer(cfg: ModelConfig, idx_in_block: int, p: Dict, x: jax.Array,
                 *, positions, memory, cache, pos, aux,
                 encoder: bool = False, page_table=None, record: bool = False):
    kind = ATTN if encoder else cfg.block_pattern[idx_in_block]
    ffn_kind = MLP if encoder else cfg.ffn_kind(idx_in_block)
    new_cache: Dict[str, Any] = {}
    tape = None

    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    if kind in (ATTN, LOCAL):
        res = _self_attn(cfg, p["attn"], h, kind=kind, positions=positions,
                         cache=None if cache is None else cache.get("self"),
                         pos=pos, bidir=encoder, page_table=page_table,
                         record=record)
        o, c = res[0], res[1]
        if record:
            tape = res[2]
        x = x + o
        if c is not None:
            new_cache["self"] = c
        if cfg.is_encdec and not encoder:                 # whisper decoder
            h2 = rmsnorm(x, p["cross_norm"], cfg.norm_eps)
            x = x + _cross_attn(cfg, p["cross"], h2, memory=memory,
                                cache=None if cache is None else cache.get("mem"))
            if cache is not None and "mem" in cache:
                new_cache["mem"] = cache["mem"]
    elif kind == CROSS:
        x = x + _cross_attn(cfg, p["attn"], h, memory=memory,
                            cache=None if cache is None else cache.get("mem"))
        if cache is not None and "mem" in cache:
            new_cache["mem"] = cache["mem"]
    elif kind == MAMBA:
        o, c = mamba_block(cfg, p["mamba"], h,
                           cache=None if cache is None else cache.get("ssm_c"),
                           decode=pos is not None)
        x = x + o
        if cache is not None:
            new_cache["ssm_c"] = c
    else:
        raise ValueError(kind)

    x, aux = _ffn(cfg, ffn_kind, p, x, aux)
    if record:
        if tape is None:
            raise ValueError(
                f"record_queries needs every layer on the paged attention "
                f"path; layer kind {kind!r} is not")
        return x, new_cache, aux, tape
    return x, new_cache, aux


# --------------------------------------------------------------------------
# block scan drivers


def _constrain(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Residual-stream sharding constraint (Megatron-SP-style sequence
    sharding between blocks) — active only when the launcher sets
    ``cfg.act_sharding`` and a mesh is in scope."""
    if cfg.act_sharding is None:
        return x
    spec = jax.sharding.PartitionSpec(*cfg.act_sharding)
    return jax.lax.with_sharding_constraint(x, spec)


def _aux_init(cfg: ModelConfig) -> Dict[str, jax.Array]:
    if MOE in cfg.ffn_pattern:
        return {"moe_load_balance": jnp.zeros(()), "moe_z_loss": jnp.zeros(()),
                "moe_drop_frac": jnp.zeros(())}
    return {}


def _run_blocks(cfg: ModelConfig, blocks: Dict, x: jax.Array, *,
                positions, memory, cache, pos, encoder=False,
                page_table=None, record=False):
    """Scan super-blocks. cache (if given) is a pytree stacked on axis 0
    matching ``blocks``; returns (x, new_cache, aux). With ``record``
    (paged-prefill path only) aux additionally carries ``q_tape`` /
    ``o_tape`` — per-layer post-rope queries and attention outputs,
    (L, B, S, Hq, Dh) with L enumerated block-major (the same order
    ``kernels.ops._fold_layers`` folds pool leaves)."""
    aux0 = {} if encoder else _aux_init(cfg)
    n_layers = cfg.encoder_layers if encoder else len(cfg.block_pattern)

    def body(carry, xs):
        x, aux = carry
        x = _constrain(cfg, x)
        bp, bc = xs
        new_bc = {}
        tapes = []
        for i in range(n_layers if encoder else len(cfg.block_pattern)):
            key = f"layer_{i}" if not encoder else "layer"
            lp = bp[key] if not encoder else bp
            lc = None if bc is None else bc.get(f"layer_{i}")
            out = _apply_layer(cfg, i, lp, x, positions=positions,
                               memory=memory, cache=lc, pos=pos,
                               aux=aux, encoder=encoder,
                               page_table=page_table, record=record)
            x, nc, aux = out[0], out[1], out[2]
            if record:
                tapes.append(out[3])
            if bc is not None:
                new_bc[f"layer_{i}"] = nc
        ys = new_bc if bc is not None else 0
        if record:
            # stack the period's layers -> (P, B, S, Hq, Dh); the scan
            # stacks blocks in front -> (nb, P, ...)
            ys = (ys, {k: jnp.stack([t[k] for t in tapes])
                       for k in ("q", "o")})
        return (x, aux), ys

    if encoder:
        # encoder blocks are a single stacked layer dict
        def ebody(carry, bp):
            x, aux = carry
            x, _, aux = _apply_layer(cfg, 0, bp, x, positions=positions,
                                     memory=None, cache=None, pos=None,
                                     aux=aux, encoder=True)
            return (x, aux), 0
        fn = jax.checkpoint(ebody) if cfg.remat else ebody
        (x, aux), _ = jax.lax.scan(fn, (x, aux0), blocks)
        return x, None, aux

    fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), ys = jax.lax.scan(fn, (x, aux0), (blocks, cache))
    if record:
        new_cache, tape = ys
        for k, name in (("q", "q_tape"), ("o", "o_tape")):
            t = tape[k]                      # (nb, P, B, S, Hq, Dh)
            aux[name] = t.reshape((-1,) + t.shape[2:])
    else:
        new_cache = ys
    return x, (new_cache if cache is not None else None), aux


# --------------------------------------------------------------------------
# public API


def _embed(cfg: ModelConfig, params: Dict, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _logits(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    if (cfg.act_sharding is not None and logits.ndim == 3
            and cfg.act_sharding[1] == "model"):
        # Megatron-SP exit: gather sequence, keep vocab sharded on model.
        dp = cfg.act_sharding[0]
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.PartitionSpec(dp, None, "model"))
    if cfg.final_softcap is not None:
        logits = softcap(logits, cfg.final_softcap)
    return logits


def encode(cfg: ModelConfig, params: Dict, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over stub frame embeddings (B, S_enc, d)."""
    assert cfg.is_encdec
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, _, _ = _run_blocks(cfg, params["encoder"]["blocks"], frames,
                          positions=positions, memory=None, cache=None,
                          pos=None, encoder=True)
    return rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array, *,
            memory: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            cache: Optional[Dict] = None,
            page_table: Optional[jax.Array] = None,
            record_queries: bool = False,
            ) -> Tuple[jax.Array, Optional[Dict], Dict]:
    """Full-sequence forward (training / prefill).

    tokens (B, S) -> logits (B, S, V_padded) in f32.
    If ``cache`` is provided it is filled (prefill) and returned. A paged
    cache (page pools from ``repro.sampling.paged_cache``) additionally
    needs ``page_table`` (B, pages_per_slot) and explicit ``positions``
    for chunked prefill at an offset.

    ``record_queries`` (paged-cache forwards only) adds ``q_tape`` /
    ``o_tape`` — per-layer post-rope queries and per-layer attention
    outputs, (L, B, S, Hq, Dh) — to the returned aux dict, so a
    speculative verifier can rescore acceptance through one
    ``paged_prefill_layers`` launch instead of L.
    """
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = _embed(cfg, params, tokens)
    x, new_cache, aux = _run_blocks(cfg, params["blocks"], x,
                                    positions=positions, memory=memory,
                                    cache=cache, pos=None,
                                    page_table=page_table,
                                    record=record_queries)
    return _logits(cfg, params, x), new_cache, aux


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                token: jax.Array, pos: jax.Array, *,
                memory: Optional[jax.Array] = None,
                page_table: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict]:
    """One decode step. token (B,) int32; pos scalar int32, or a (B,)
    vector when rows decode at heterogeneous positions (requires a paged
    cache + ``page_table`` — the dense cache layout assumes one shared
    write position).

    Returns (logits (B, V_padded) f32, new_cache).
    """
    b = token.shape[0]
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        positions = jnp.full((b, 1), pos, jnp.int32)
    else:
        positions = pos.astype(jnp.int32)[:, None]
    x = _embed(cfg, params, token[:, None])
    x, new_cache, _ = _run_blocks(cfg, params["blocks"], x,
                                  positions=positions, memory=memory,
                                  cache=cache, pos=pos,
                                  page_table=page_table)
    return _logits(cfg, params, x)[:, 0], new_cache


def init_cache(cfg: ModelConfig, params: Dict, batch: int, max_len: int, *,
               memory: Optional[jax.Array] = None,
               dtype: Optional[str] = None) -> Dict:
    """Decode cache pytree, stacked on the block axis.

    For CROSS / enc-dec layers the memory k/v are projected once here.
    """
    dt = jnp.dtype(dtype or cfg.dtype)
    nb = cfg.num_blocks
    cache: Dict[str, Any] = {}

    def kv(b, kind=ATTN):
        ml = max_len
        if cfg.local_ring_kv and kind == LOCAL:
            ml = min(max_len, cfg.sliding_window)
        return {"k": jnp.zeros((b, ml, cfg.num_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((b, ml, cfg.num_kv_heads, cfg.head_dim), dt)}

    def mem_kv(i):
        """(nb, B, M, Hkv, hd) memory projections for layer slot i."""
        wk = params["blocks"][f"layer_{i}"]["cross" if cfg.is_encdec
                                            else "attn"]["wk"]
        wv = params["blocks"][f"layer_{i}"]["cross" if cfg.is_encdec
                                            else "attn"]["wv"]
        m = memory.shape[1]

        def proj(w):
            return jnp.einsum("bmd,ndh->nbmh", memory, w).reshape(
                nb, batch, m, cfg.num_kv_heads, cfg.head_dim).astype(dt)
        return {"k_mem": proj(wk), "v_mem": proj(wv)}

    for i, kind in enumerate(cfg.block_pattern):
        lc: Dict[str, Any] = {}
        if kind in (ATTN, LOCAL):
            lc["self"] = jax.tree_util.tree_map(
                lambda z: jnp.broadcast_to(z, (nb,) + z.shape).copy(),
                kv(batch, kind))
            if cfg.is_encdec:
                lc["mem"] = mem_kv(i)
        elif kind == CROSS:
            lc["mem"] = mem_kv(i)
        elif kind == MAMBA:
            conv_ch = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
            lc["ssm_c"] = {
                "conv": jnp.zeros((nb, batch, cfg.ssm_conv - 1, conv_ch), dt),
                "ssm": jnp.zeros((nb, batch, cfg.ssm_heads, cfg.ssm_headdim,
                                  cfg.ssm_state), jnp.float32),
            }
        cache[f"layer_{i}"] = lc
    return cache
