"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Tokens are routed top-k, sorted by expert id, placed into a dense
(E, C, d) buffer (capacity C per expert, overflow dropped — Switch-style),
run through batched expert matmuls, and gathered/combined back. This keeps
compiled FLOPs proportional to *active* experts (unlike dense all-expert
dispatch) and, with the expert axis sharded over `model`, lets GSPMD turn
the scatter/gather into expert-parallel collectives.

Aux losses: router z-loss and load-balance loss (returned for logging, not
folded into the RL objective by default).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import swiglu


# Explicit expert-parallel sharding constraints were tried and REFUTED:
# they force GSPMD reshards that *triple* peak temp memory (see
# EXPERIMENTS.md §Perf, hypothesis H-MoE-1). Kept behind a flag for the
# record.
ENABLE_CONSTRAINTS = False


def _token_axes(cfg: ModelConfig):
    """Flattened (B·S) sharding axes derived from the residual-stream
    constraint (batch axes + sequence axis collapse into the token dim)."""
    if cfg.act_sharding is None:
        return None
    axes = []
    for entry in cfg.act_sharding[:2]:
        if entry is None:
            continue
        axes.extend(entry if isinstance(entry, tuple) else (entry,))
    return tuple(axes) if axes else None


def _wsc(x, spec):
    return jax.lax.with_sharding_constraint(x, jax.sharding.PartitionSpec(
        *spec))


def moe_ffn(cfg: ModelConfig, p: Dict, x: jax.Array
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (B, S, d), aux metrics."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    xf = x.reshape(t, d)
    tok_ax = _token_axes(cfg) if ENABLE_CONSTRAINTS else None
    if tok_ax:
        xf = _wsc(xf, (tok_ax, None))

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate, ids = jax.lax.top_k(probs, k)                        # (T, k)
    if k > 1:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # capacity per expert (static)
    cap = max(int(t * k / e * cfg.capacity_factor), 4)

    flat_ids = ids.reshape(-1)                                 # (T*k,)
    order = jnp.argsort(flat_ids)                              # stable
    sorted_ids = flat_ids[order]
    # rank of each entry within its expert segment
    rank = jnp.arange(t * k) - jnp.searchsorted(sorted_ids, sorted_ids,
                                                side="left")
    tok_of = order // k                                        # source token
    keep = rank < cap

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[
        jnp.where(keep, sorted_ids, e - 1),
        jnp.where(keep, rank, cap - 1),
    ].set(jnp.where(keep[:, None], xf[tok_of], 0), mode="drop")
    if tok_ax:
        buf = _wsc(buf, ("model", None, None))      # expert-parallel

    # batched expert MLPs: (E, C, d) x (E, d, f) -> (E, C, f)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])       # (E, C, d)
    if tok_ax:
        out_buf = _wsc(out_buf, ("model", None, None))

    y_sorted = out_buf[sorted_ids, rank] * keep[:, None]       # (T*k, d)
    y_flat = jnp.zeros((t * k, d), x.dtype).at[order].set(y_sorted)
    if tok_ax:
        y_flat = _wsc(y_flat, (tok_ax, None))
    y = (y_flat.reshape(t, k, d)
         * gate[..., None].astype(x.dtype)).sum(axis=1)        # (T, d)

    if cfg.shared_expert:
        y = y + swiglu(xf, p["shared"])

    # --- aux metrics (Switch-style load balance + z-loss) ---------------
    me = probs.mean(axis=0)                                    # (E,)
    ce = jnp.zeros((e,)).at[flat_ids].add(1.0) / (t * k)
    aux = {
        "moe_load_balance": e * jnp.sum(me * ce),
        "moe_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "moe_drop_frac": 1.0 - keep.mean(),
    }
    return y.reshape(b, s, d), aux
