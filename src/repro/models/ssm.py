"""Mamba2 / SSD (state-space duality) blocks.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060): the
sequence is split into chunks of length L; within a chunk the recurrence is
computed as a masked quadratic form (MXU-friendly), and chunk states are
propagated with a short sequential scan. Decode is the O(1) recurrent
update. All decays are computed in log-space (exponents ≤ 0, so every
exp() is ≤ 1 — numerically stable).

Shapes:  x (B,S,H,P)  dt (B,S,H)  A (H,) [negative]  B,C (B,S,G,N)
State: (B,H,P,N).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import rmsnorm


def _expand_groups(t: jax.Array, h: int) -> jax.Array:
    """(B,...,G,N) -> (B,...,H,N) by repeating each group H/G times."""
    g = t.shape[-2]
    reps = h // g
    return jnp.repeat(t, reps, axis=-2)


def ssd_chunked(x, dt, a_log_neg, b, c, *, chunk: int,
                init_state: Optional[jax.Array] = None,
                head_slice: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD with optional head slicing.

    The intra-chunk quadratic form materializes (B, nc, L, L, H) decay /
    score tensors — at production shapes that is tens of GB per device if
    all heads are computed at once. ``head_slice`` > 0 processes heads in
    slices of that size under ``jax.lax.map`` with a rematerialized body,
    bounding the live working set to (B, nc, L, L, head_slice) (and its
    backward recomputes instead of saving). 0 = all heads at once (small
    models / tests)."""
    bsz, s, h, p = x.shape
    if head_slice and head_slice < h:
        assert h % head_slice == 0, (h, head_slice)
        g = b.shape[2]
        ns = h // head_slice
        xs = x.reshape(bsz, s, ns, head_slice, p).transpose(2, 0, 1, 3, 4)
        dts = dt.reshape(bsz, s, ns, head_slice).transpose(2, 0, 1, 3)
        als = a_log_neg.reshape(ns, head_slice)
        # §Perf H-C2: B/C stay in GROUP form per slice. When all heads
        # share one group (ngroups=1) b/c are CLOSED OVER, not mapped —
        # putting a broadcast into lax.map xs would materialize the
        # (ns, B, S, N) copy it exists to avoid.
        init_s = (jnp.zeros((ns, bsz, head_slice, p, b.shape[-1]),
                            jnp.float32) if init_state is None else
                  init_state.reshape(bsz, ns, head_slice, p, -1
                                     ).transpose(1, 0, 2, 3, 4))

        if g == 1:
            @jax.checkpoint
            def one(args):
                xi, dti, ai, s0 = args
                return _ssd_chunked_core(xi, dti, ai, b, c, chunk=chunk,
                                         init_state=s0)

            y, fin = jax.lax.map(one, (xs, dts, als, init_s))
        else:
            if g % ns == 0:
                gs = g // ns
                bh = b.reshape(bsz, s, ns, gs, -1).transpose(2, 0, 1, 3, 4)
                ch = c.reshape(bsz, s, ns, gs, -1).transpose(2, 0, 1, 3, 4)
            else:  # incommensurate: fall back to per-head expansion
                bh = _expand_groups(b, h).reshape(
                    bsz, s, ns, head_slice, -1).transpose(2, 0, 1, 3, 4)
                ch = _expand_groups(c, h).reshape(
                    bsz, s, ns, head_slice, -1).transpose(2, 0, 1, 3, 4)

            @jax.checkpoint
            def one(args):
                xi, dti, ai, bi, ci, s0 = args
                return _ssd_chunked_core(xi, dti, ai, bi, ci, chunk=chunk,
                                         init_state=s0)

            y, fin = jax.lax.map(one, (xs, dts, als, bh, ch, init_s))
        y = y.transpose(1, 2, 0, 3, 4).reshape(bsz, s, h, p)
        fin = fin.transpose(1, 0, 2, 3, 4).reshape(bsz, h, p, -1)
        return y, fin
    return _ssd_chunked_core(x, dt, a_log_neg, b, c, chunk=chunk,
                             init_state=init_state)


def _ssd_chunked_core(x, dt, a_log_neg, b, c, *, chunk: int,
                      init_state: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    l = min(chunk, s)
    s_orig = s
    pad = (-s) % l
    if pad:
        # dt=0 on padded steps: decay exp(0)=1, contribution 0 — the state
        # and all real outputs are untouched.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s += pad
    nc = s // l

    f32 = jnp.float32
    x, dt = x.astype(f32), dt.astype(f32)
    b = _expand_groups(b.astype(f32), h)            # (B,S,H,N)
    c = _expand_groups(c.astype(f32), h)

    la = (a_log_neg.astype(f32) * dt)               # log a_t  (B,S,H), <= 0
    u = x * dt[..., None]                           # input contribution

    # chunk views
    xc = u.reshape(bsz, nc, l, h, p)
    bc = b.reshape(bsz, nc, l, h, n)
    cc = c.reshape(bsz, nc, l, h, n)
    lac = la.reshape(bsz, nc, l, h)
    cum = jnp.cumsum(lac, axis=2)                   # inclusive  (B,nc,L,H)

    # ---- intra-chunk (quadratic, masked) --------------------------------
    # decay[t,s] = exp(cum_t - cum_s) for t >= s
    dec = cum[:, :, :, None] - cum[:, :, None, :, :]        # (B,nc,L,L,H)
    mask = jnp.tril(jnp.ones((l, l), bool))
    dec = jnp.where(mask[None, None, :, :, None], dec, -jnp.inf)
    w = jnp.einsum("bcthn,bcshn->bctsh", cc, bc) * jnp.exp(dec)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", w, xc)

    # ---- chunk-local end states ----------------------------------------
    w_end = jnp.exp(cum[:, :, -1:, :] - cum)                 # (B,nc,L,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn", bc, w_end, xc)

    # ---- inter-chunk scan ----------------------------------------------
    total_dec = jnp.exp(cum[:, :, -1])                       # (B,nc,H)
    s0 = (jnp.zeros((bsz, h, p, n), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st_c, dec_c = inp                                    # local state, decay
        new = carry * dec_c[:, :, None, None] + st_c
        return new, carry                                    # emit state *before* chunk

    final, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), total_dec.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (B,nc,H,P,N)

    # ---- inter-chunk contribution ---------------------------------------
    dec_in = jnp.exp(cum)                                    # decay start->t
    y_inter = jnp.einsum("bcthn,bcth,bchpn->bcthp", cc, dec_in, prev_states)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y[:, :s_orig], final


def ssd_reference(x, dt, a_log_neg, b, c, *,
                  init_state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Sequential recurrence oracle (slow, for tests)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    f32 = jnp.float32
    bh = _expand_groups(b.astype(f32), h)
    ch = _expand_groups(c.astype(f32), h)
    a = jnp.exp(a_log_neg.astype(f32) * dt.astype(f32))      # (B,S,H)
    u = x.astype(f32) * dt.astype(f32)[..., None]
    s0 = (jnp.zeros((bsz, h, p, n), f32) if init_state is None
          else init_state.astype(f32))

    def step(state, t):
        a_t, u_t, b_t, c_t = t
        state = state * a_t[:, :, None, None] + jnp.einsum(
            "bhp,bhn->bhpn", u_t, b_t)
        y_t = jnp.einsum("bhn,bhpn->bhp", c_t, state)
        return state, y_t

    xs = (a.transpose(1, 0, 2), u.transpose(1, 0, 2, 3),
          bh.transpose(1, 0, 2, 3), ch.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), final


def ssd_decode_step(state, x_t, dt_t, a_log_neg, b_t, c_t):
    """One-token recurrence. state (B,H,P,N); x_t (B,H,P); dt_t (B,H);
    b_t,c_t (B,G,N)."""
    h = x_t.shape[1]
    f32 = jnp.float32
    bh = _expand_groups(b_t.astype(f32), h)
    ch = _expand_groups(c_t.astype(f32), h)
    a_t = jnp.exp(a_log_neg.astype(f32) * dt_t.astype(f32))
    u_t = x_t.astype(f32) * dt_t.astype(f32)[..., None]
    state = state * a_t[:, :, None, None] + jnp.einsum("bhp,bhn->bhpn",
                                                       u_t, bh)
    y_t = jnp.einsum("bhn,bhpn->bhp", ch, state)
    return state, y_t


# --------------------------------------------------------------------------
# full Mamba2 block


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv. xbc (B,S,ch), w (K,ch)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    return out + bias


def _split_in_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, n, g, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n:]
    return z, xbc, dt


def _split_xbc(cfg: ModelConfig, xbc: jax.Array):
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
    x = xbc[..., :di]
    b = xbc[..., di:di + g * n]
    c = xbc[..., di + g * n:]
    shp = x.shape[:-1]
    return (x.reshape(*shp, cfg.ssm_heads, cfg.ssm_headdim),
            b.reshape(*shp, g, n), c.reshape(*shp, g, n))


def mamba_block(cfg: ModelConfig, p: Dict, x_in: jax.Array,
                cache: Optional[Dict] = None, decode: bool = False
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """x_in (B,S,d) (S==1 for decode). Returns (out, new_cache)."""
    zxbcdt = x_in @ p["in_proj"]                    # (B,S,fan_out)
    z, xbc, dt_raw = _split_in_proj(cfg, zxbcdt)

    if decode:
        assert cache is not None
        window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,K,ch)
        k = p["conv_w"].shape[0]
        conv_out = jnp.einsum("bkc,kc->bc", window[:, -k:], p["conv_w"])
        conv_out = (conv_out + p["conv_b"])[:, None]            # (B,1,ch)
        new_conv = window[:, 1:]
    else:
        conv_out = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        new_conv = xbc[:, -(p["conv_w"].shape[0] - 1):]

    xbc = jax.nn.silu(conv_out)
    xs, b, c = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a_log_neg = -jnp.exp(p["A_log"].astype(jnp.float32))

    if decode:
        state, y = ssd_decode_step(cache["ssm"], xs[:, 0], dt[:, 0],
                                   a_log_neg, b[:, 0], c[:, 0])
        y = y[:, None]                                          # (B,1,H,P)
    else:
        init = cache["ssm"] if cache is not None else None
        # bound the intra-chunk working set to ~256 MB f32 per head-slice
        bsz, s = xs.shape[0], xs.shape[1]
        l = min(cfg.ssm_chunk, s)
        nc = -(-s // l)
        budget = 2 ** 26                       # elements
        hc = max(1, budget // max(bsz * nc * l * l, 1))
        h = cfg.ssm_heads
        while hc < h and h % hc:               # round down to a divisor
            hc -= 1
        head_slice = 0 if hc >= h else hc
        y, state = ssd_chunked(xs, dt, a_log_neg, b, c,
                               chunk=cfg.ssm_chunk, init_state=init,
                               head_slice=head_slice)

    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(*y.shape[:2], cfg.d_inner)                    # (B,S,di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y.astype(x_in.dtype), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]

    new_cache = {"conv": new_conv, "ssm": state} if (decode or cache is not None
                                                     ) else {"conv": new_conv,
                                                             "ssm": state}
    return out, new_cache
