"""Elementary layers: RMSNorm, rotary embeddings, (SwiGLU) MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: (..., S, H, D) with D even; positions: broadcastable to (..., S).
    """
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq     # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, p: dict) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)
