from repro.models.model import (decode_step, encode, forward, init_cache)
from repro.models.params import (abstract_params, init_params, param_axes,
                                 param_templates)

__all__ = ["forward", "encode", "decode_step", "init_cache",
           "init_params", "abstract_params", "param_axes", "param_templates"]
