"""Synthetic verifiable math tasks + char tokenizer.

The paper trains on MATH level 3-5 with exact-match rewards. On a single
CPU we substitute arithmetic problems whose rewards are computable
programmatically (same binary exact-match structure), keeping the RL
mechanics — group sampling, verifiable reward, reward collapse dynamics —
identical.

Prompts are rendered at a FIXED width (left-padded with spaces) so batches
need no prompt-side padding mask; the space is an ordinary token.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

PAD, BOS, EOS = 0, 1, 2
_CHARS = "0123456789+-*= "


class Tokenizer:
    """Char-level tokenizer over digits/operators; ids 0..2 are specials."""

    def __init__(self) -> None:
        self.itos = {PAD: "<pad>", BOS: "<bos>", EOS: "<eos>"}
        self.stoi = {}
        for i, ch in enumerate(_CHARS):
            self.stoi[ch] = 3 + i
            self.itos[3 + i] = ch

    @property
    def vocab_size(self) -> int:
        return 3 + len(_CHARS)

    def encode(self, s: str, bos: bool = False, eos: bool = False
               ) -> List[int]:
        ids = [self.stoi[c] for c in s]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        out = []
        for i in ids:
            i = int(i)
            if i == EOS:
                break
            if i in (PAD, BOS):
                continue
            out.append(self.itos.get(i, "?"))
        return "".join(out)


@dataclasses.dataclass
class Problem:
    prompt: str            # fixed-width rendered prompt, ends with '='
    answer: str            # canonical answer string


class ArithmeticTask:
    """a OP b = ?  with OP in {+,-,*}; difficulty via operand size."""

    def __init__(self, max_operand: int = 99, ops: str = "+-",
                 prompt_width: int = 8, seed: int = 0) -> None:
        self.max_operand = max_operand
        self.ops = ops
        self.prompt_width = prompt_width
        self.rng = np.random.default_rng(seed)

    def sample(self) -> Problem:
        a = int(self.rng.integers(0, self.max_operand + 1))
        b = int(self.rng.integers(0, self.max_operand + 1))
        op = self.ops[int(self.rng.integers(len(self.ops)))]
        if op == "-" and b > a:
            a, b = b, a                       # keep answers non-negative
        expr = f"{a}{op}{b}="
        ans = str(eval(f"{a}{op}{b}"))        # noqa: S307 - ints only
        return Problem(prompt=expr.rjust(self.prompt_width), answer=ans)

    def sample_batch(self, n: int) -> List[Problem]:
        return [self.sample() for _ in range(n)]

    @staticmethod
    def reward(problem: Problem, completion: str) -> float:
        """Binary exact match (the paper's verifiable-reward setting)."""
        return 1.0 if completion.strip() == problem.answer else 0.0


def encode_prompts(tok: Tokenizer, problems: Sequence[Problem]
                   ) -> np.ndarray:
    """(B, Tp) int32 — all prompts share the fixed width."""
    rows = [tok.encode(p.prompt) for p in problems]
    width = len(rows[0])
    assert all(len(r) == width for r in rows)
    return np.asarray(rows, np.int32)
