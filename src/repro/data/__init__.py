from repro.data.pipeline import PromptPipeline, RolloutRequest, score_rollouts
from repro.data.tasks import (ArithmeticTask, EOS, PAD, BOS, Problem,
                              Tokenizer, encode_prompts)

__all__ = ["ArithmeticTask", "Tokenizer", "Problem", "encode_prompts",
           "PromptPipeline", "RolloutRequest", "score_rollouts",
           "PAD", "BOS", "EOS"]
