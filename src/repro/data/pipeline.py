"""Prompt batching with group replication.

Each batch row group of G consecutive rows shares one prompt — matching
the paper's localized-reward invariant (App. F): a group is generated and
scored on a single node, so group statistics need no cross-node gather.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List

import numpy as np

from repro.data.tasks import ArithmeticTask, Problem, Tokenizer, encode_prompts


@dataclasses.dataclass
class RolloutRequest:
    """What a sampler node pulls from its local task stream."""
    prompts: np.ndarray            # (n_prompts*G, Tp) group-replicated
    problems: List[Problem]        # len n_prompts (one per group)
    group_size: int


class PromptPipeline:
    def __init__(self, task: ArithmeticTask, tok: Tokenizer,
                 prompts_per_batch: int, group_size: int) -> None:
        self.task = task
        self.tok = tok
        self.n = prompts_per_batch
        self.g = group_size

    def next_batch(self) -> RolloutRequest:
        problems = self.task.sample_batch(self.n)
        enc = encode_prompts(self.tok, problems)            # (n, Tp)
        rep = np.repeat(enc, self.g, axis=0)                # (n*G, Tp)
        return RolloutRequest(prompts=rep, problems=problems,
                              group_size=self.g)

    def __iter__(self) -> Iterator[RolloutRequest]:
        while True:
            yield self.next_batch()


def score_rollouts(task: ArithmeticTask, tok: Tokenizer,
                   problems: List[Problem], completions: np.ndarray,
                   group_size: int) -> np.ndarray:
    """Localized reward computation (App. F): decode + exact-match per
    group, no cross-process communication. completions (n*G, Tnew)."""
    rewards = np.zeros(len(problems) * group_size, np.float32)
    for i, prob in enumerate(problems):
        for j in range(group_size):
            row = completions[i * group_size + j]
            rewards[i * group_size + j] = task.reward(prob, tok.decode(row))
    return rewards
