"""Device meshes for every execution scale.

Production target is TPU v5e: a single pod is 256 chips as
(data=16, model=16); multi-pod is 2 pods × 256 chips as
(pod=2, data=16, model=16) — the ``pod`` axis is the slow inter-pod
(DCN/WAN) dimension; HeteroRL's design keeps cross-pod traffic to
checkpoint broadcast + rollout streaming, but the dry-run also proves the
*learner step itself* shards across pods.

``local_mesh`` is the degenerate (data=1, model=1) mesh every runtime path
uses when no parallelism is requested — one code path for 1 and N devices.
``mesh_from_flag`` parses the ``--mesh DxM`` / ``PxDxM`` CLI form; host
testing at D·M > 1 needs ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
exported before the first jax import.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_BF16_FLOPS = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *,
                    multi_pod: bool = False) -> jax.sharding.Mesh:
    """Small mesh for CI-scale dry-run tests (requires
    --xla_force_host_platform_device_count >= product)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


@functools.lru_cache(maxsize=1)
def local_mesh() -> jax.sharding.Mesh:
    """The (data=1, model=1) mesh backing single-device execution plans.
    Cached so every caller sees the same Mesh object (stable jit keys)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_from_flag(spec: str) -> jax.sharding.Mesh:
    """Parse a ``DxM`` (or ``PxDxM`` multi-pod) mesh spec, e.g. "1x1",
    "2x4", "2x2x2". Validates against the visible device count with the
    host-device-count recipe in the error."""
    try:
        dims = tuple(int(p) for p in spec.lower().split("x"))
    except ValueError:
        dims = ()
    if len(dims) not in (2, 3) or any(d < 1 for d in dims):
        raise ValueError(f"mesh spec {spec!r}: expected DxM or PxDxM "
                         "positive integers, e.g. '2x2' or '2x2x2'")
    need = 1
    for d in dims:
        need *= d
    have = len(jax.devices())
    if need > have:
        raise RuntimeError(
            f"mesh {spec} needs {need} devices but only {have} visible — "
            "on CPU export XLA_FLAGS=--xla_force_host_platform_device_count"
            f"={need} before the first jax import")
    if len(dims) == 2:
        if dims == (1, 1):
            return local_mesh()
        return jax.make_mesh(dims, ("data", "model"))
    return jax.make_mesh(dims, ("pod", "data", "model"))


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))
