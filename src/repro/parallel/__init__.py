"""Unified sharded execution layer.

One mesh/sharding path for train, sample, and dry-run: logical-axis rules
(``axes``) + meshes (``mesh``) feed an ``ExecutionPlan`` (``plan``) that
every executing surface — learner train step, sampler engines, checkpoint
round-trips, the lowering-only dry-run — consumes for placement.
"""
from repro.parallel.mesh import (HBM_BW, ICI_BW, PEAK_BF16_FLOPS,
                                 data_axes, local_mesh, make_debug_mesh,
                                 make_production_mesh, mesh_from_flag)
from repro.parallel.plan import (ExecutionPlan, local_plan, make_plan,
                                 plan_for_params, plan_from_flag)
from repro.parallel.step import make_sharded_sft_step, make_sharded_train_step

__all__ = [
    "ExecutionPlan", "make_plan", "local_plan", "plan_from_flag",
    "plan_for_params",
    "make_sharded_train_step", "make_sharded_sft_step",
    "make_production_mesh", "make_debug_mesh", "local_mesh",
    "mesh_from_flag", "data_axes",
    "PEAK_BF16_FLOPS", "HBM_BW", "ICI_BW",
]
