"""Logical-axis → mesh-axis resolution per execution mode.

This is the single source of placement rules for the whole stack: the
``ExecutionPlan`` (repro.parallel.plan) turns these specs into fitted
``NamedSharding`` trees consumed by the real train/sample steps, and the
multi-pod dry-run lowers against the same trees.

Modes:
  train        FSDP(+pod) on d_model rows × tensor parallel on heavy dims,
               Megatron-SP residual sharding (batch→dp, seq→model).
  serve        tensor parallel weights (replicated over data), batch→dp;
               expert FFN additionally sharded over data (big-MoE serving).
  long         context-parallel decode (batch=1): weight heavy dims over
               (data×model) [(pod×data×model) multi-pod], KV-cache sequence
               over data(+pod), heads over model.

Anything GSPMD cannot divide evenly it pads — acceptable for lowering and
flagged by the roofline analysis; runtime jit boundaries instead use
``fit_spec`` to prune non-dividing axes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.models.params import param_axes
from repro.optim import AdafactorState, AdamWState
from repro.parallel.mesh import data_axes

MODES = ("train", "train_fsdp", "serve", "long")


def _rules(mode: str, mesh: jax.sharding.Mesh) -> Dict[str, Any]:
    dp = data_axes(mesh)                     # ("pod","data") or ("data",)
    dm = dp[:-1] + ("data", "model") if "pod" in mesh.axis_names \
        else ("data", "model")               # full fold for long mode
    if mode == "train":
        return {"vocab": "model", "embed": dp, "ffn": "model",
                "qkv": "model", "kv": "model", "experts": "model",
                "expert_ffn": None, "ssm_in": "model", "dinner": "model",
                "heads": "model", None: None}
    if mode == "train_fsdp":
        # §Perf H-A3: pure ZeRO-3 — every weight sharded on exactly one
        # fan-out dim over the WHOLE mesh, batch data-parallel over the
        # whole mesh, no tensor parallelism (no per-layer activation
        # collectives; params are all-gathered per layer instead).
        return {"vocab": dm, "embed": None, "ffn": dm, "qkv": dm,
                "kv": dm, "experts": "model", "expert_ffn": dp[-1],
                "ssm_in": dm, "dinner": dm, "heads": None, None: None}
    if mode == "serve":
        return {"vocab": "model", "embed": None, "ffn": "model",
                "qkv": "model", "kv": "model", "experts": "model",
                "expert_ffn": "data", "ssm_in": "model", "dinner": "model",
                "heads": "model", None: None}
    if mode == "long":
        return {"vocab": dm, "embed": None, "ffn": dm, "qkv": dm,
                "kv": dm, "experts": "model", "expert_ffn": "data",
                "ssm_in": dm, "dinner": dm, "heads": dm, None: None}
    raise ValueError(mode)


def resolve_spec(axes: Tuple[Optional[str], ...], mode: str,
                 mesh: jax.sharding.Mesh) -> P:
    rules = _rules(mode, mesh)
    return P(*[rules.get(a) for a in axes])


def param_specs(cfg: ModelConfig, mode: str, mesh: jax.sharding.Mesh):
    return jax.tree_util.tree_map(
        lambda axes: resolve_spec(axes, mode, mesh), param_axes(cfg),
        is_leaf=lambda x: isinstance(x, tuple))


def opt_specs(pspecs: Any, optimizer: str):
    """Optimizer-state specs derived from the parameter specs."""
    if optimizer == "adamw":
        return AdamWState(step=P(), m=pspecs, v=pspecs)

    def row(spec: P) -> P:
        return P(*spec[:-1]) if len(spec) >= 2 else spec

    def col(spec: P) -> P:
        return P(*(spec[:-2] + spec[-1:])) if len(spec) >= 2 else P(None)

    return AdafactorState(
        step=P(),
        vr=jax.tree_util.tree_map(row, pspecs,
                                  is_leaf=lambda x: isinstance(x, P)),
        vc=jax.tree_util.tree_map(col, pspecs,
                                  is_leaf=lambda x: isinstance(x, P)))


def batch_specs(cfg: ModelConfig, mesh: jax.sharding.Mesh) -> Dict[str, P]:
    dp = data_axes(mesh)
    out = {"tokens": P(dp, None), "mask": P(dp, None),
           "sampler_lp": P(dp, None), "rewards": P(dp)}
    if cfg.is_encdec:
        out["frames"] = P(dp, None, None)
    elif cfg.memory_seq:
        out["image_embeds"] = P(dp, None, None)
    return out


def cache_specs(cfg: ModelConfig, cache: Any, mode: str,
                mesh: jax.sharding.Mesh):
    """Specs for a decode-cache pytree built by ``init_cache`` (or its
    abstract twin), or a paged page-pool built by ``init_paged_pool``.
    Leaf roles are recognized by path name.

    The dense KV-cache *sequence* dim is sharded over 'model' (serve) or
    the whole mesh (long): GQA kv-head counts (4–8) cannot shard 16-way,
    and at 32k–500k contexts the cache dominates HBM — context-parallel
    decode (partial-softmax flash-decode, inserted by GSPMD) is the only
    layout that fits. Per-device cache = total / (dp × model).

    Paged pools (``kp``/``vp``, shape (nb, pages, page, Hkv, hd)) instead
    shard kv-heads over 'model' — pages are the unit of allocator locality,
    so splitting inside a page would defeat the block table; ``fit_spec``
    falls back to replication when Hkv doesn't divide. The paged-decode
    backends compose with this layout: gather/ref partition natively
    under GSPMD, the Pallas kernel dispatches per-shard via shard_map
    (grid over local kv-heads; see tests/test_paged_attention.py)."""
    dp = data_axes(mesh)
    long = mode == "long"
    if long and "pod" in mesh.axis_names:
        seq_axes = ("pod", "data", "model")
    elif long:
        seq_axes = ("data", "model")
    else:
        seq_axes = "model"
    batch_axes = (None if long else dp)

    def spec_for(path, leaf) -> P:
        names = [str(getattr(p, "key", "")) for p in path]
        if "kp" in names or "vp" in names:      # (nb, pages, page, Hkv, hd)
            return P(None, None, None, "model", None)
        if "k" in names or "v" in names or "k_mem" in names or "v_mem" in names:
            # (nb, B, S, Hkv, hd)
            return P(None, batch_axes, seq_axes, None, None)
        if "conv" in names:                     # (nb, B, K-1, conv_ch)
            return P(None, batch_axes, None,
                     seq_axes if long else "model")
        if "ssm" in names:                      # (nb, B, H, P, N)
            return P(None, batch_axes,
                     seq_axes if long else "model", None, None)
        raise ValueError(f"unknown cache leaf {names}")

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def act_sharding_for(mode: str, mesh: jax.sharding.Mesh
                     ) -> Optional[Tuple]:
    """Residual-stream constraint handed to the model config."""
    dp = data_axes(mesh)
    if mode == "train":
        return (dp, "model", None)             # batch→dp, seq→model (SP)
    if mode == "train_fsdp":
        return (dp + ("model",), None, None)   # batch over the whole mesh
    return None


def to_named(mesh: jax.sharding.Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def fit_spec(mesh: jax.sharding.Mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Prune mesh axes that do not evenly divide the dimension (jit
    in/out_shardings demand exact divisibility — e.g. 8 kv heads cannot
    shard over model=16; GQA heads then stay partially sharded)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries, strict=True):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = []
        prod = 1
        for ax in axes:
            n = mesh.shape[ax]
            if dim % (prod * n) == 0:
                keep.append(ax)
                prod *= n
            else:
                break
        out.append(tuple(keep) if len(keep) > 1 else
                   (keep[0] if keep else None))
    return P(*out)


def to_named_fit(mesh: jax.sharding.Mesh, spec_tree: Any,
                 aval_tree: Any) -> Any:
    """NamedShardings with divisibility-fitted specs (shapes taken from the
    matching ShapeDtypeStruct tree)."""
    return jax.tree_util.tree_map(
        lambda s, a: NamedSharding(mesh, fit_spec(mesh, s, a.shape)),
        spec_tree, aval_tree,
        is_leaf=lambda x: isinstance(x, P))
