"""Jitted sharded step builders.

``make_sharded_train_step`` is the only way the repo builds a runnable
train step: explicit ``in_shardings``/``out_shardings`` from the
``ExecutionPlan`` and a **donated** ``TrainState`` (params + optimizer
buffers are consumed in place — no 2× param footprint inside the step).
On the default 1×1 plan this degenerates to single-device execution with
the exact same code path.

Donation contract: the state passed in is dead after the call. Nodes that
keep a replica of the learner's params (samplers) must hold their own
copies (``ExecutionPlan.device_put_params(copy=True)``).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RLConfig, TrainConfig
from repro.parallel.plan import ExecutionPlan
from repro.runtime_context import mesh_context


def _sig(tree: Dict[str, Any]) -> Tuple:
    """Hashable (key, shape, dtype) signature of a dict batch — retrace
    key for the shape-specialized executables below."""
    return tuple(sorted((k, tuple(v.shape), jnp.dtype(v.dtype).name)
                        for k, v in tree.items()))


def make_sharded_train_step(cfg: ModelConfig, rl: RLConfig, tc: TrainConfig,
                            plan: ExecutionPlan, *,
                            optimizer: str = "adamw",
                            donate: bool = True) -> Callable:
    """(state, batch) -> (state, metrics), jitted against the plan.

    Batch shardings are fitted per batch shape (cached), state shardings
    once per (cfg, optimizer). Grad-accum microbatch slicing is pinned
    shard-local via ``plan.constrain_microbatches``.
    """
    from repro.training import train_step
    state_sh = plan.state_shardings(cfg, optimizer)
    mb_con = plan.microbatch_constraint(cfg, tc.grad_accum)

    def step(state, batch):
        return train_step(cfg, rl, tc, state, batch, optimizer=optimizer,
                          mb_constraint=mb_con)

    @functools.lru_cache(maxsize=16)
    def build(sig):
        batch_sh = plan.batch_shardings(cfg, {
            k: jax.ShapeDtypeStruct(shape, jnp.dtype(dt))
            for k, shape, dt in sig})
        return jax.jit(step, in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None),
                       donate_argnums=(0,) if donate else ())

    def step_fn(state, batch):
        with mesh_context(plan.mesh):
            return build(_sig(batch))(state, batch)

    step_fn.plan = plan
    return step_fn


def make_sharded_sft_step(cfg: ModelConfig, tc: TrainConfig,
                          plan: ExecutionPlan, *,
                          donate: bool = True) -> Callable:
    """(state, tokens, mask) -> (state, loss) with plan shardings and a
    donated ``TrainState`` — the SFT warm-start twin of the RL step."""
    from repro.optim import (adamw_update, clip_by_global_norm,
                             warmup_schedule)
    from repro.training import TrainState, sft_loss_fn
    state_sh = plan.state_shardings(cfg, "adamw")

    def step(state, tokens, mask):
        loss, grads = jax.value_and_grad(
            lambda p: sft_loss_fn(cfg, p, tokens, mask,
                                  logprob_impl=tc.logprob_impl))(
            state.params)
        grads, _ = clip_by_global_norm(grads, tc.grad_clip)
        lr = warmup_schedule(tc, state.step)
        new_params, new_opt = adamw_update(tc, grads, state.opt,
                                           state.params, lr)
        return TrainState(new_params, new_opt, state.step + 1), loss

    @functools.lru_cache(maxsize=8)
    def build(tok_shape, mask_shape):
        sh = plan.batch_shardings(cfg, {
            "tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
            "mask": jax.ShapeDtypeStruct(mask_shape, jnp.float32)})
        in_sh = (state_sh, sh["tokens"], sh["mask"])
        return jax.jit(step, in_shardings=in_sh,
                       out_shardings=(state_sh, None),
                       donate_argnums=(0,) if donate else ())

    def step_fn(state, tokens, mask):
        with mesh_context(plan.mesh):
            return build(tuple(tokens.shape), tuple(mask.shape))(
                state, tokens, mask)

    step_fn.plan = plan
    return step_fn
