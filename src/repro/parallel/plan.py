"""ExecutionPlan: the one object that owns placement for a running system.

A plan is (mesh, mode) — hashable, so it rides through ``jax.jit`` as a
static argument and keys executable caches. From it every layer derives
its fitted ``NamedSharding`` trees (params, optimizer state, batch, KV
cache) out of the logical-axis rules in ``repro.parallel.axes``:

- the learner jits its train step with explicit in/out shardings and
  donated ``TrainState`` buffers (``repro.parallel.step``),
- sampler engines constrain params and the (paged) KV cache inside their
  prefill/decode executables,
- checkpoint round-trips ``device_put`` onto the plan on fetch and
  host-gather on publish,
- the multi-pod dry-run lowers against the same trees instead of
  duplicating resolution.

``local_plan`` (a 1×1 mesh) backs single-device execution so there is one
code path regardless of scale; multi-device CPU testing forces host
devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.models import abstract_params
from repro.optim import adafactor_init, adamw_init
from repro.parallel import axes
from repro.parallel.mesh import data_axes, local_mesh, mesh_from_flag


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Mesh + parameter-sharding mode. Frozen/hashable: equal plans mean
    equal placement, so jit caches and ``lru_cache`` key on it directly."""
    mesh: jax.sharding.Mesh
    mode: str = "train"            # train | train_fsdp | serve | long

    def __post_init__(self):
        if self.mode not in axes.MODES:
            raise ValueError(f"mode {self.mode!r} not in {axes.MODES}")

    # ---- descriptive ----------------------------------------------------
    @property
    def num_devices(self) -> int:
        return int(self.mesh.devices.size)

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return data_axes(self.mesh)

    @property
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def describe(self) -> str:
        shape = "x".join(f"{self.mesh.shape[a]}{a[0]}"
                         for a in self.mesh.axis_names)
        return (f"ExecutionPlan(mode={self.mode}, mesh={shape}, "
                f"devices={self.num_devices})")

    # ---- fitted NamedSharding trees -------------------------------------
    def _fit(self, spec: P, shape: Tuple[int, ...]) -> NamedSharding:
        return NamedSharding(self.mesh, axes.fit_spec(self.mesh, spec,
                                                      tuple(shape)))

    def param_shardings(self, cfg: ModelConfig) -> Any:
        return _param_shardings(self, cfg)

    def state_shardings(self, cfg: ModelConfig,
                        optimizer: str = "adamw") -> Any:
        """``TrainState``-shaped tree of fitted shardings (params + opt
        buffers + step). Opt-state avals come from ``jax.eval_shape`` of
        the real optimizer init, so they can never drift from it."""
        return _state_shardings(self, cfg, optimizer)

    def batch_shardings(self, cfg: ModelConfig,
                        batch: Dict[str, Any]) -> Dict[str, NamedSharding]:
        """Fitted shardings for the keys present in ``batch`` (arrays or
        avals). Unknown keys are an error — placement must be total."""
        specs = axes.batch_specs(cfg, self.mesh)
        unknown = sorted(set(batch) - set(specs))
        if unknown:
            raise ValueError(f"no batch sharding rule for keys {unknown}")
        return {k: self._fit(specs[k], v.shape) for k, v in batch.items()}

    def cache_shardings(self, cfg: ModelConfig, cache: Any) -> Any:
        cspecs = axes.cache_specs(cfg, cache, self.mode, self.mesh)
        return axes.to_named_fit(self.mesh, cspecs, cache)

    # ---- in-trace constraints -------------------------------------------
    def constrain_params(self, cfg: ModelConfig, params: Any) -> Any:
        specs = axes.param_specs(cfg, self.mode, self.mesh)
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, self._fit(s, x.shape)),
            params, specs, is_leaf=lambda x: isinstance(x, P))

    def constrain_cache(self, cfg: ModelConfig, cache: Any) -> Any:
        specs = axes.cache_specs(cfg, cache, self.mode, self.mesh)
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, self._fit(s, x.shape)),
            cache, specs, is_leaf=lambda x: isinstance(x, P))

    def microbatch_constraint(self, cfg: ModelConfig,
                              grad_accum: int) -> Optional[Any]:
        """The ``mb_constraint`` hook for ``train_step`` — one shared
        construction site so the runtime step and the dry-run lowering
        can never disagree about grad-accum sharding."""
        if grad_accum <= 1:
            return None
        return functools.partial(self.constrain_microbatches, cfg)

    def constrain_microbatches(self, cfg: ModelConfig,
                               mbs: Dict[str, Any]) -> Dict[str, Any]:
        """Pin the reshaped grad-accum tree (accum, mb, ...) so each
        microbatch stays data-sharded on its own axis. Without this GSPMD
        propagates the global-batch sharding onto the scanned *accum* axis
        and replicates every microbatch slice (the PR-2 lesson: reshapes
        across the data axis must be re-constrained shard-local)."""
        specs = axes.batch_specs(cfg, self.mesh)
        return {k: jax.lax.with_sharding_constraint(
                    v, self._fit(P(None, *specs[k]), v.shape))
                for k, v in mbs.items()}

    # ---- placement / gather ---------------------------------------------
    def device_put_params(self, cfg: ModelConfig, params: Any, *,
                          copy: bool = False) -> Any:
        """Place a param tree onto the plan. ``copy=True`` forces fresh
        buffers (via host) — required when the source tree belongs to a
        node whose step donates its buffers (e.g. a sampler keeping its
        own replica of learner params)."""
        sh = self.param_shardings(cfg)
        src = (jax.tree_util.tree_map(np.asarray, params) if copy
               else params)
        return jax.tree_util.tree_map(jax.device_put, src, sh)

    def device_put_state(self, cfg: ModelConfig, state: Any,
                         optimizer: str = "adamw", *,
                         copy: bool = False) -> Any:
        """Place a ``TrainState`` onto the plan. ``copy=True`` gives the
        caller-owned buffers a fresh on-device twin first (``jnp.copy``)
        — required by nodes whose train step donates the state while the
        source (e.g. a shared warm start) stays live elsewhere."""
        sh = self.state_shardings(cfg, optimizer)
        src = jax.tree_util.tree_map(jnp.copy, state) if copy else state
        return jax.tree_util.tree_map(jax.device_put, src, sh)

    def device_put_batch(self, cfg: ModelConfig,
                         batch: Dict[str, Any]) -> Dict[str, Any]:
        sh = self.batch_shardings(cfg, batch)
        return {k: jax.device_put(v, sh[k]) for k, v in batch.items()}

    @staticmethod
    def host_gather(tree: Any) -> Any:
        """Gather a (possibly sharded) pytree to host numpy arrays — the
        publish half of the checkpoint round-trip."""
        return jax.tree_util.tree_map(np.asarray, tree)


# Fitted-tree builders are pure in (plan, cfg[, optimizer]) — all
# hashable — and O(param leaves) of host-side spec resolution, so they
# are memoized here (device_put_params runs once per run_online step).
@functools.lru_cache(maxsize=64)
def _param_shardings(plan: ExecutionPlan, cfg: ModelConfig) -> Any:
    return axes.to_named_fit(plan.mesh,
                             axes.param_specs(cfg, plan.mode, plan.mesh),
                             abstract_params(cfg))


@functools.lru_cache(maxsize=64)
def _state_shardings(plan: ExecutionPlan, cfg: ModelConfig,
                     optimizer: str) -> Any:
    from repro.training import TrainState
    p_avals = abstract_params(cfg)
    init = adamw_init if optimizer == "adamw" else adafactor_init
    opt_avals = jax.eval_shape(init, p_avals)
    avals = TrainState(params=p_avals, opt=opt_avals,
                       step=jax.ShapeDtypeStruct((), jnp.int32))
    pspecs = axes.param_specs(cfg, plan.mode, plan.mesh)
    specs = TrainState(params=pspecs,
                       opt=axes.opt_specs(pspecs, optimizer),
                       step=P())
    return axes.to_named_fit(plan.mesh, specs, avals)


def make_plan(mesh: Optional[jax.sharding.Mesh] = None,
              mode: str = "train") -> ExecutionPlan:
    return ExecutionPlan(mesh=mesh if mesh is not None else local_mesh(),
                         mode=mode)


@functools.lru_cache(maxsize=8)
def local_plan(mode: str = "train") -> ExecutionPlan:
    """Single-device (1×1 mesh) plan — the default execution path."""
    return ExecutionPlan(mesh=local_mesh(), mode=mode)


@functools.lru_cache(maxsize=32)
def plan_from_flag(spec: Optional[str], mode: str) -> ExecutionPlan:
    """Plan from a ``--mesh``/config knob ("DxM" or "PxDxM"); None or
    "1x1" gives the local plan."""
    if spec is None or spec in ("", "1x1"):
        return local_plan(mode)
    return ExecutionPlan(mesh=mesh_from_flag(spec), mode=mode)


def plan_for_params(params: Any, mode: str = "serve") -> ExecutionPlan:
    """Plan matching the mesh a param tree already lives on — the default
    for callers (eval, ad-hoc generation) that receive placed params
    rather than a plan. Falls back to the local plan for single-device
    arrays."""
    leaves = jax.tree_util.tree_leaves(params)
    mesh = getattr(getattr(leaves[0], "sharding", None), "mesh", None) \
        if leaves else None
    if isinstance(mesh, jax.sharding.Mesh):
        return ExecutionPlan(mesh=mesh, mode=mode)
    return local_plan(mode)
