"""HeteroRL / GEPO - heterogeneous asynchronous RL for LLM post-training,
reproduced as a production-grade JAX framework.

Paper: "GEPO: Group Expectation Policy Optimization for Stable
Heterogeneous Reinforcement Learning" (Zhang, Zheng et al., 2025).
"""

__version__ = "0.1.0"
