"""Registry of the assigned architectures (+ the paper's own model).

Every entry cites its source; the exact dimensions come from the assignment
table. ``get_config(name)`` returns the full-size config; ``smoke(name)``
returns the reduced same-family variant used by CPU smoke tests.
"""
from repro.config import ModelConfig, smoke_variant

from repro.configs.qwen1_5_32b import CONFIG as _qwen15_32b
from repro.configs.llama3_2_vision_11b import CONFIG as _llama32v
from repro.configs.jamba1_5_large_398b import CONFIG as _jamba
from repro.configs.llama4_scout_17b_a16e import CONFIG as _scout
from repro.configs.gemma2_9b import CONFIG as _gemma2
from repro.configs.llama4_maverick_400b_a17b import CONFIG as _maverick
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.internlm2_1_8b import CONFIG as _internlm2
from repro.configs.mamba2_1_3b import CONFIG as _mamba2
from repro.configs.qwen2_7b import CONFIG as _qwen2
from repro.configs.qwen3_paper import CONFIG as _qwen3, CONFIG_8B as _qwen3_8b

ARCHS = {c.name: c for c in (
    _qwen15_32b, _llama32v, _jamba, _scout, _gemma2, _maverick,
    _whisper, _internlm2, _mamba2, _qwen2,
)}
# The paper's own training targets (Qwen3-1.7B/8B proxies).
PAPER_ARCHS = {c.name: c for c in (_qwen3, _qwen3_8b)}
ALL = {**ARCHS, **PAPER_ARCHS}

# Architectures with a sub-quadratic (or natively windowed) path that run
# the long_500k decode shape; all others skip it (see DESIGN.md).
LONG_CONTEXT_OK = frozenset({
    "mamba2-1.3b", "jamba-1.5-large-398b", "gemma2-9b",
})


def get_config(name: str) -> ModelConfig:
    try:
        return ALL[name]
    except KeyError as e:
        raise KeyError(
            f"unknown arch {name!r}; have {sorted(ALL)}") from e


def smoke(name: str, **over) -> ModelConfig:
    return smoke_variant(get_config(name), **over)


def supports_shape(name: str, shape_name: str) -> bool:
    cfg = get_config(name)
    if shape_name == "long_500k":
        return name in LONG_CONTEXT_OK
    if shape_name in ("decode_32k", "prefill_32k") and cfg.is_encdec:
        # whisper decoder: architecturally fine (decoder-side KV cache);
        # encoder memory stays at its native frame count.
        return True
    return True
