"""Qwen1.5-32B [dense] — 64L d_model=5120 40H (GQA kv=40 == MHA)
d_ff=27392 vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family card]"""
from repro.config import ModelConfig, ATTN, MLP

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    block_pattern=(ATTN,),
    ffn_pattern=(MLP,),
    rope_theta=1_000_000.0,
)
