"""Llama-4-Maverick-400B-A17B [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 + shared expert, early fusion; MoE and
dense FFN layers interleave 1:1 (which is what puts the total at ~400B).
[hf:meta-llama/Llama-4-Scout-17B-16E card family]"""
from repro.config import ModelConfig, ATTN, MOE, MLP

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=(ATTN, ATTN),
    ffn_pattern=(MOE, MLP),
    num_experts=128,
    experts_per_token=1,
    shared_expert=True,
    rope_theta=500_000.0,
)
