"""Llama-3.2-11B-Vision [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision]

The vision encoder (ViT) + projector is a STUB per the assignment carve-out:
``input_specs`` provides precomputed patch embeddings (B, 1601, d_model) that
the cross-attention layers consume as memory."""
from repro.config import ModelConfig, ATTN, CROSS, MLP

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    # 8 cross-attn layers interleaved among 40 -> period-5 blocks.
    block_pattern=(ATTN, ATTN, ATTN, ATTN, CROSS),
    ffn_pattern=(MLP,),
    memory_seq=1601,          # 560/14 patches^2 + CLS
    rope_theta=500_000.0,
)
