"""Qwen2-7B [dense] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, QKV bias. [arXiv:2407.10671]"""
from repro.config import ModelConfig, ATTN, MLP

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    block_pattern=(ATTN,),
    ffn_pattern=(MLP,),
    rope_theta=1_000_000.0,
)
