"""The paper's own training targets: Qwen3-1.7B and Qwen3-8B proxies
(GEPO §4.1 trains these on MATH level 3-5). [arXiv:2505.09388]"""
from repro.config import ModelConfig, ATTN, MLP

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    block_pattern=(ATTN,),
    ffn_pattern=(MLP,),
    rope_theta=1_000_000.0,
)

CONFIG_8B = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    block_pattern=(ATTN,),
    ffn_pattern=(MLP,),
    rope_theta=1_000_000.0,
)
