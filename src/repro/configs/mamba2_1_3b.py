"""Mamba2-1.3B [ssm] — 48L d_model=2048, attention-free (SSD, state-space
duality), no FFN (d_ff=0), vocab=50280, ssm_state=128. [arXiv:2405.21060]"""
from repro.config import ModelConfig, MAMBA, NONE

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    block_pattern=(MAMBA,),
    ffn_pattern=(NONE,),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    tie_embeddings=True,
)
