"""Gemma2-9B [dense] — 42L d_model=3584 16H (GQA kv=8, head_dim=256)
d_ff=14336 vocab=256000, alternating local (SWA 4096) / global attention,
attention logit softcap 50, final logit softcap 30. [arXiv:2408.00118]"""
from repro.config import ModelConfig, LOCAL, ATTN, MLP

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    block_pattern=(LOCAL, ATTN),
    ffn_pattern=(MLP,),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    scale_embed=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
)
