"""Whisper-small [audio] — 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865, encoder-decoder, conv/mel frontend STUB. [arXiv:2212.04356]

Per the assignment carve-out, the mel-spectrogram + conv feature extractor
is stubbed: ``input_specs`` provides precomputed frame embeddings
(B, 1500, d_model) consumed by the transformer encoder. The decoder
cross-attends to the encoder output every layer."""
from repro.config import ModelConfig, ATTN, MLP

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,             # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    block_pattern=(ATTN,),     # decoder: self-attn + per-layer cross-attn
    ffn_pattern=(MLP,),
    encoder_layers=12,
    encoder_seq=1500,          # 30 s audio at 50 Hz after conv stride
    rope_theta=10_000.0,
)
