"""Jamba-1.5-Large-398B [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba:attention 7:1 interleave,
MoE every second layer. [arXiv:2403.19887]"""
from repro.config import ModelConfig, ATTN, MAMBA, MOE, MLP

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    # one attention layer per 8 (1:7 attn:mamba interleave)
    block_pattern=(ATTN, MAMBA, MAMBA, MAMBA, MAMBA, MAMBA, MAMBA, MAMBA),
    # MoE replaces the MLP on every other layer
    ffn_pattern=(MOE, MLP),
    num_experts=16,
    experts_per_token=2,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
)
