"""Checkpointing: pytree ⇄ npz bytes, plus the versioned policy store that
plays the role of App. E's ``Model_Sync_Path`` (learner publishes, samplers
pull the latest version after their simulated transmission delay)."""
from __future__ import annotations

import io
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       if hasattr(p, "idx") else str(p) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def save_pytree(tree: Any) -> bytes:
    buf = io.BytesIO()
    arrays = dict(_flatten_with_paths(tree))
    np.savez(buf, **arrays)
    return buf.getvalue()


def load_pytree(data: bytes, like: Any) -> Any:
    """Restore into the structure of ``like`` (paths must match)."""
    buf = io.BytesIO(data)
    with np.load(buf) as z:
        arrays = {k: z[k] for k in z.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       if hasattr(p, "idx") else str(p) for p in path)
        arr = arrays[key]
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class PolicyStore:
    """Versioned checkpoint store (thread-safe for the threaded runtime).

    The learner ``publish``es (version, bytes); samplers ``fetch`` the
    newest version. Old versions are pruned beyond ``keep``.
    """

    def __init__(self, keep: int = 8) -> None:
        self._lock = threading.Lock()
        self._store: Dict[int, bytes] = {}
        self._latest = -1
        self._keep = keep
        self.bytes_published = 0

    def publish(self, version: int, data: bytes) -> None:
        with self._lock:
            self._store[version] = data
            self._latest = max(self._latest, version)
            self.bytes_published += len(data)
            stale = sorted(self._store)[:-self._keep]
            for v in stale:
                del self._store[v]

    def latest_version(self) -> int:
        with self._lock:
            return self._latest

    def fetch(self, version: Optional[int] = None) -> Tuple[int, bytes]:
        with self._lock:
            v = self._latest if version is None else version
            return v, self._store[v]
