"""Checkpointing: pytree ⇄ npz bytes, plus the versioned policy store that
plays the role of App. E's ``Model_Sync_Path`` (learner publishes, samplers
pull the latest version after their simulated transmission delay).

Round-trips are sharding-aware at the call sites: whole-blob callers
host-gather (``ExecutionPlan.host_gather``) before ``save_pytree`` and
``device_put`` the loaded tree onto their own plan. The chunked transport
(``repro.transport``) instead streams per-shard views and uses this module
only for the shared raw-byte codec (``encode_array``/``decode_array``) and
the versioned store, which doubles as its chunk index
(``put_chunk``/``publish_manifest``).
"""
from __future__ import annotations

import io
import json
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

# npz sidecar key describing leaves whose dtype numpy cannot round-trip
# natively (ml_dtypes: bfloat16, float8_*...). Those are stored as raw
# bytes and re-viewed on load — without this, np.savez round-trips
# bfloat16 as opaque void16 ("|V2") and the restore either crashes or
# silently mangles the published sampler weights.
_EXOTIC_META = "__exotic_dtypes__"


def path_key(path: Tuple) -> str:
    """Stable string key for a tree_flatten_with_path entry — the one
    leaf-naming scheme shared by the npz blob format and the chunked
    transport manifests (keys must agree for a sampler to restore)."""
    return "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                    if hasattr(p, "idx") else str(p) for p in path)


def flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_key(path), leaf) for path, leaf in flat]


def encode_array(arr: Any) -> bytes:
    """Raw C-order bytes of an array — dtype-agnostic (bf16 and other
    ml_dtypes included), the wire encoding of transport chunks."""
    return np.ascontiguousarray(np.asarray(arr)).tobytes()


def decode_array(data: bytes, dtype: str, shape: Tuple[int, ...]) -> np.ndarray:
    """Inverse of ``encode_array`` given the (dtype, shape) sidecar; the
    re-view never upcasts exotic dtypes."""
    return np.frombuffer(data, jax.numpy.dtype(dtype)).reshape(shape)


def save_pytree(tree: Any) -> bytes:
    buf = io.BytesIO()
    arrays = {}
    exotic: Dict[str, Dict] = {}
    for key, leaf in flatten_with_paths(tree):
        arr = np.asarray(leaf)
        if np.dtype(arr.dtype).isbuiltin != 1:      # ml_dtypes et al.
            exotic[key] = {"dtype": arr.dtype.name,
                           "shape": list(arr.shape)}
            arrays[key] = np.frombuffer(encode_array(arr), np.uint8)
        else:
            arrays[key] = arr
    if exotic:
        arrays[_EXOTIC_META] = np.frombuffer(
            json.dumps(exotic).encode(), np.uint8)
    np.savez(buf, **arrays)
    return buf.getvalue()


def load_pytree(data: bytes, like: Any) -> Any:
    """Restore into the structure of ``like`` (paths must match), leaf
    dtypes following ``like``. Exotic-dtype leaves (bfloat16, ...) are
    re-viewed from their raw-byte encoding, never upcast."""
    buf = io.BytesIO(data)
    with np.load(buf) as z:
        arrays = {k: z[k] for k in z.files}
    exotic = {}
    if _EXOTIC_META in arrays:
        exotic = json.loads(arrays.pop(_EXOTIC_META).tobytes().decode())
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        key = path_key(path)
        arr = arrays[key]
        if key in exotic:
            meta = exotic[key]
            arr = decode_array(arr.tobytes(), meta["dtype"],
                               tuple(meta["shape"]))
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class PolicyStore:
    """Versioned checkpoint store (thread-safe for the threaded runtime).

    The learner ``publish``es (version, bytes); samplers ``fetch`` the
    newest version. Old versions are pruned beyond ``keep``; fetching a
    version that was pruned degrades to the oldest retained one (counted
    in ``stale_fetches``) — a sampler behind a long WAN delay should get
    the closest surviving policy, not an exception.

    The store is also the chunk-index backend of the shard-streamed
    transport (``repro.transport``): content-addressed chunks live in
    ``put_chunk``/``get_chunk`` and each published version's *manifest*
    rides the same versioned ``_store`` (same prune/degrade semantics).
    Chunks no longer referenced by any retained manifest are garbage
    collected on prune, so a long run holds at most ``keep`` manifests
    plus their live chunk set.

    Bookkeeping is bounded: the exact set of ever-published versions is
    trimmed to the most recent ``track`` entries; versions older than the
    tracking horizon are treated as published-then-pruned (degrade +
    ``stale_fetches``) rather than growing an unbounded set.
    ``bytes_published`` counts net-new bytes only — re-publishing a
    version counts the delta against the blob it replaces, and a chunk
    already in the index costs nothing.
    """

    def __init__(self, keep: int = 8, track: int = 512) -> None:
        self._lock = threading.Lock()
        self._store: Dict[int, bytes] = {}
        self._published: set = set()     # recent versions, bounded by track
        self._forgotten_below: Optional[int] = None  # bookkeeping horizon
        self._latest = -1
        self._keep = keep
        self._track = max(track, keep)
        # chunk index (transport backend)
        self._chunks: Dict[str, bytes] = {}
        self._chunk_refs: Dict[int, frozenset] = {}  # version -> chunk hashes
        self.bytes_published = 0
        self.stale_fetches = 0
        self.chunks_gced = 0

    # ---- whole-blob / manifest versions ---------------------------------
    def publish(self, version: int, data: bytes) -> None:
        with self._lock:
            prev = self._store.get(version)
            self._store[version] = data
            self._published.add(version)
            self._latest = max(self._latest, version)
            self.bytes_published += len(data) - (len(prev) if prev is not None
                                                 else 0)
            self._prune_locked()

    def _prune_locked(self) -> None:
        stale = sorted(self._store)[:-self._keep]
        released = False
        for v in stale:
            del self._store[v]
            released |= self._chunk_refs.pop(v, None) is not None
        if released:
            alive = frozenset().union(*self._chunk_refs.values()) \
                if self._chunk_refs else frozenset()
            dead = [h for h in self._chunks if h not in alive]
            for h in dead:
                del self._chunks[h]
            self.chunks_gced += len(dead)
        if len(self._published) > self._track:
            evicted = sorted(self._published)[:-self._track]
            self._published.difference_update(evicted)
            self._forgotten_below = evicted[-1] + 1

    def latest_version(self) -> int:
        with self._lock:
            return self._latest

    def fetch(self, version: Optional[int] = None) -> Tuple[int, bytes]:
        with self._lock:
            if not self._store:
                raise KeyError("PolicyStore is empty — nothing published")
            if version is None:
                return self._latest, self._store[self._latest]
            if version in self._store:
                return version, self._store[version]
            if version in self._published or (
                    self._forgotten_below is not None
                    and version < self._forgotten_below):
                # published once, pruned (or below the bookkeeping horizon)
                self.stale_fetches += 1
                oldest = min(self._store)
                return oldest, self._store[oldest]
            raise KeyError(
                f"version {version} was never published (retained: "
                f"{sorted(self._store)}, latest: {self._latest})")

    # ---- chunk index (transport backend) --------------------------------
    def put_chunk(self, chunk_hash: str, data: bytes) -> bool:
        """Insert a content-addressed chunk; returns True when net-new
        (and only then counts its bytes as published)."""
        with self._lock:
            if chunk_hash in self._chunks:
                return False
            self._chunks[chunk_hash] = data
            self.bytes_published += len(data)
            return True

    def has_chunk(self, chunk_hash: str) -> bool:
        with self._lock:
            return chunk_hash in self._chunks

    def get_chunk(self, chunk_hash: str) -> bytes:
        with self._lock:
            try:
                return self._chunks[chunk_hash]
            except KeyError:
                raise KeyError(
                    f"chunk {chunk_hash} not in store (referenced by a "
                    "pruned manifest, or never published)") from None

    def get_chunks(self, chunk_hashes) -> Dict[str, bytes]:
        """Atomic multi-get: a subscriber snapshots every chunk it is
        about to transfer under one lock, so a concurrent publisher
        pruning the manifest mid-(simulated)-transfer cannot yank chunks
        from under it."""
        with self._lock:
            missing = [h for h in chunk_hashes if h not in self._chunks]
            if missing:
                raise KeyError(
                    f"{len(missing)} chunks not in store (first: "
                    f"{missing[0]}) — referenced by a pruned manifest, "
                    "or never published")
            return {h: self._chunks[h] for h in chunk_hashes}

    def publish_manifest(self, version: int, manifest_blob: bytes,
                         chunk_hashes) -> None:
        """Version a transport manifest (its JSON bytes ride ``_store``
        with the blob semantics) and pin its chunks against GC."""
        with self._lock:
            missing = [h for h in chunk_hashes if h not in self._chunks]
            if missing:
                raise KeyError(f"manifest {version} references "
                               f"{len(missing)} chunks not in the store "
                               f"(first: {missing[0]}) — put_chunk first")
            self._chunk_refs[version] = frozenset(chunk_hashes)
        self.publish(version, manifest_blob)

    @property
    def num_chunks(self) -> int:
        with self._lock:
            return len(self._chunks)

    def chunk_index_bytes(self) -> int:
        with self._lock:
            return sum(len(d) for d in self._chunks.values())
