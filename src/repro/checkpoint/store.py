"""Checkpointing: pytree ⇄ npz bytes, plus the versioned policy store that
plays the role of App. E's ``Model_Sync_Path`` (learner publishes, samplers
pull the latest version after their simulated transmission delay).

Round-trips are sharding-aware at the call sites: the learner host-gathers
(``ExecutionPlan.host_gather``) before ``save_pytree`` and samplers
``device_put`` the loaded tree onto their own plan — bytes on the wire are
always plain host numpy.
"""
from __future__ import annotations

import io
import json
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

# npz sidecar key describing leaves whose dtype numpy cannot round-trip
# natively (ml_dtypes: bfloat16, float8_*...). Those are stored as raw
# bytes and re-viewed on load — without this, np.savez round-trips
# bfloat16 as opaque void16 ("|V2") and the restore either crashes or
# silently mangles the published sampler weights.
_EXOTIC_META = "__exotic_dtypes__"


def _flatten_with_paths(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       if hasattr(p, "idx") else str(p) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def save_pytree(tree: Any) -> bytes:
    buf = io.BytesIO()
    arrays = {}
    exotic: Dict[str, Dict] = {}
    for key, arr in _flatten_with_paths(tree):
        if np.dtype(arr.dtype).isbuiltin != 1:      # ml_dtypes et al.
            exotic[key] = {"dtype": arr.dtype.name,
                           "shape": list(arr.shape)}
            arrays[key] = np.frombuffer(arr.tobytes(), np.uint8)
        else:
            arrays[key] = arr
    if exotic:
        arrays[_EXOTIC_META] = np.frombuffer(
            json.dumps(exotic).encode("utf-8"), np.uint8)
    np.savez(buf, **arrays)
    return buf.getvalue()


def load_pytree(data: bytes, like: Any) -> Any:
    """Restore into the structure of ``like`` (paths must match), leaf
    dtypes following ``like``. Exotic-dtype leaves (bfloat16, ...) are
    re-viewed from their raw-byte encoding, never upcast."""
    buf = io.BytesIO(data)
    with np.load(buf) as z:
        arrays = {k: z[k] for k in z.files}
    exotic = {}
    if _EXOTIC_META in arrays:
        exotic = json.loads(arrays.pop(_EXOTIC_META).tobytes().decode())
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       if hasattr(p, "idx") else str(p) for p in path)
        arr = arrays[key]
        if key in exotic:
            meta = exotic[key]
            arr = np.frombuffer(arr.tobytes(),
                                jax.numpy.dtype(meta["dtype"])
                                ).reshape(meta["shape"])
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class PolicyStore:
    """Versioned checkpoint store (thread-safe for the threaded runtime).

    The learner ``publish``es (version, bytes); samplers ``fetch`` the
    newest version. Old versions are pruned beyond ``keep``; fetching a
    version that was pruned degrades to the oldest retained one (counted
    in ``stale_fetches``) — a sampler behind a long WAN delay should get
    the closest surviving policy, not an exception.
    """

    def __init__(self, keep: int = 8) -> None:
        self._lock = threading.Lock()
        self._store: Dict[int, bytes] = {}
        self._published: set = set()     # every version ever published
        self._latest = -1
        self._keep = keep
        self.bytes_published = 0
        self.stale_fetches = 0

    def publish(self, version: int, data: bytes) -> None:
        with self._lock:
            self._store[version] = data
            self._published.add(version)
            self._latest = max(self._latest, version)
            self.bytes_published += len(data)
            stale = sorted(self._store)[:-self._keep]
            for v in stale:
                del self._store[v]

    def latest_version(self) -> int:
        with self._lock:
            return self._latest

    def fetch(self, version: Optional[int] = None) -> Tuple[int, bytes]:
        with self._lock:
            if not self._store:
                raise KeyError("PolicyStore is empty — nothing published")
            if version is None:
                return self._latest, self._store[self._latest]
            if version in self._store:
                return version, self._store[version]
            if version in self._published:      # published once, pruned
                self.stale_fetches += 1
                oldest = min(self._store)
                return oldest, self._store[oldest]
            raise KeyError(
                f"version {version} was never published (retained: "
                f"{sorted(self._store)}, latest: {self._latest})")
