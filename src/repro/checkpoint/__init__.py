from repro.checkpoint.store import PolicyStore, load_pytree, save_pytree

__all__ = ["PolicyStore", "save_pytree", "load_pytree"]
