"""The RL train step: forward → policy loss → grads → clipped optimizer
update. This single function is shared by

- the HeteroRL learner node (tiny models, real training on CPU),
- the production launcher (``repro.launch.train``) and the multi-pod
  dry-run, where it is lowered/compiled against the assigned architecture
  × input-shape grid with GSPMD sharding.

Batch layout (targets are tokens shifted by one):
  tokens (B, T) int32 | mask (B, T-1) f32 over target positions |
  sampler_lp (B, T-1) f32 | rewards (B,) f32, group-contiguous.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RLConfig, TrainConfig
from repro.core import group_advantages, policy_loss
from repro.core.logprob import token_logprob_from_logits
from repro.kernels.ops import fused_token_logprob
from repro.models import forward
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, warmup_schedule)

# Metrics that aggregate across grad-accum microbatches with `max` rather
# than a mean — averaging per-microbatch maxima would understate e.g. the
# worst importance weight of the step (the Fig. 4 stability signal).
MAX_METRICS = frozenset({"iw_max"})


def _token_lp_ent(logits: jax.Array, targets: jax.Array, impl: str):
    """(logp, entropy) per target token under the configured
    ``TrainConfig.logprob_impl``; entropy is None on the naive path (it
    would cost an extra full-vocab sweep there)."""
    if impl == "naive":
        return token_logprob_from_logits(logits, targets), None
    fused_impl = None if impl == "fused" else impl
    lp, ent = fused_token_logprob(logits, targets, impl=fused_impl)
    return lp, ent


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


def init_state(cfg: ModelConfig, tc: TrainConfig, params,
               optimizer: str = "adamw", plan=None) -> TrainState:
    """Fresh optimizer state; with an ``ExecutionPlan`` the whole
    ``TrainState`` is ``device_put`` onto the plan's shardings so the
    first sharded step pays no resharding copy."""
    init = adamw_init if optimizer == "adamw" else adafactor_init
    state = TrainState(params=params, opt=init(params),
                       step=jnp.zeros((), jnp.int32))
    if plan is not None:
        state = plan.device_put_state(cfg, state, optimizer)
    return state


def rl_loss_fn(cfg: ModelConfig, rl: RLConfig, params,
               batch: Dict[str, jax.Array],
               memory: Optional[jax.Array] = None,
               logprob_impl: str = "fused"
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    # modality stubs ride in the batch so they micro-batch with it
    if memory is None and "frames" in batch:
        from repro.models import encode as _encode
        memory = _encode(cfg, params, batch["frames"])
    elif memory is None and "image_embeds" in batch:
        memory = batch["image_embeds"]
    tokens = batch["tokens"]
    # named scopes thread phase names into the HLO metadata, so XLA /
    # jax.profiler traces of the jitted step carry rl/forward,
    # rl/logprob, rl/loss instead of one opaque jit_train_step blob
    with jax.named_scope("rl_forward"):
        logits, _, aux = forward(cfg, params, tokens[:, :-1], memory=memory)
    with jax.named_scope("rl_logprob"):
        learner_lp, learner_ent = _token_lp_ent(logits, tokens[:, 1:],
                                                logprob_impl)

    sampler_lp = batch["sampler_lp"]
    if not rl.recompute_sampler_logps:
        # trust engine-side logps verbatim (paper shows this is unstable)
        sampler_lp = jax.lax.stop_gradient(sampler_lp)

    with jax.named_scope("rl_loss"):
        adv = group_advantages(
            batch["rewards"], rl.group_size,
            normalize=rl.adv_normalize,
            kind=rl.loss_type if rl.loss_type in ("bnpo", "dr_grpo")
            else "grpo")
        loss, metrics = policy_loss(rl, learner_lp, sampler_lp,
                                    batch["mask"], adv, entropy=learner_ent)
    for k, v in aux.items():                      # MoE router diagnostics
        metrics[k] = v / max(cfg.num_blocks, 1)
    metrics["reward_mean"] = batch["rewards"].mean()
    return loss, metrics


def train_step(cfg: ModelConfig, rl: RLConfig, tc: TrainConfig,
               state: TrainState, batch: Dict[str, jax.Array], *,
               optimizer: str = "adamw",
               memory: Optional[jax.Array] = None,
               mb_constraint: Optional[Any] = None
               ) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One (optionally micro-batched) RL update. ``mb_constraint`` (set by
    the sharded step builder) re-pins the reshaped (accum, mb, ...) batch
    so microbatch slicing stays shard-local under GSPMD."""
    def loss_fn(params, mb):
        return rl_loss_fn(cfg, rl, params, mb, memory=memory,
                          logprob_impl=tc.logprob_impl)

    if tc.grad_accum > 1:
        def mb_grads(carry, mb):
            g_acc, m_acc = carry
            (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, mb)
            g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
            m_acc = {k: (jnp.maximum(m_acc[k], v) if k in MAX_METRICS
                         else m_acc[k] + v) for k, v in m.items()}
            return (g_acc, m_acc), None

        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((tc.grad_accum, -1) + x.shape[1:]), batch)
        if mb_constraint is not None:
            mbs = mb_constraint(mbs)
        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        # metrics pytree structure only — jax.eval_shape performs no
        # FLOPs, so the step runs exactly grad_accum loss evaluations
        m_avals = jax.eval_shape(
            lambda p, mb: loss_fn(p, mb)[1], state.params,
            jax.tree_util.tree_map(lambda x: x[0], mbs))
        m0 = {k: (jnp.full(s.shape, -jnp.inf, s.dtype) if k in MAX_METRICS
                  else jnp.zeros(s.shape, s.dtype))
              for k, s in m_avals.items()}
        (grads, msum), _ = jax.lax.scan(mb_grads, (g0, m0), mbs)
        grads = jax.tree_util.tree_map(lambda g: g / tc.grad_accum, grads)
        metrics = {k: (v if k in MAX_METRICS else v / tc.grad_accum)
                   for k, v in msum.items()}
    else:
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch)

    with jax.named_scope("optim_update"):
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        lr = warmup_schedule(tc, state.step)
        if optimizer == "adamw":
            new_params, new_opt = adamw_update(tc, grads, state.opt,
                                               state.params, lr)
        else:
            new_params, new_opt = adafactor_update(tc, grads, state.opt,
                                                   state.params, lr)
    metrics["grad_norm"] = gnorm
    metrics["lr"] = lr
    return TrainState(new_params, new_opt, state.step + 1), metrics


def jit_train_step(cfg: ModelConfig, rl: RLConfig, tc: TrainConfig,
                   optimizer: str = "adamw", plan=None):
    """Jitted train step through the unified execution layer: explicit
    in/out shardings from the ``ExecutionPlan`` (default: the 1×1 local
    plan) and a **donated** ``TrainState`` — callers must treat the input
    state as consumed (keep copies of params you hand to other nodes).
    With ``plan=None`` the ``TrainConfig.mesh`` knob decides (default the
    1×1 local plan)."""
    from repro.parallel import make_sharded_train_step, plan_from_flag
    plan = plan or plan_from_flag(tc.mesh, "train")
    return make_sharded_train_step(cfg, rl, tc, plan, optimizer=optimizer)


# --------------------------------------------------------------------------
# Supervised warm-start. The paper RL-tunes a *pretrained* model
# (Qwen3-1.7B/8B); our CPU-scale experiments mirror that by SFT-ing the
# tiny model on (prompt, answer) pairs until it emits well-formed answers,
# then handing it to RL.


def sft_loss_fn(cfg: ModelConfig, params, tokens: jax.Array,
                mask: jax.Array, logprob_impl: str = "fused") -> jax.Array:
    logits, _, _ = forward(cfg, params, tokens[:, :-1])
    lp, _ = _token_lp_ent(logits, tokens[:, 1:], logprob_impl)
    nll = -lp
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def jit_sft_step(cfg: ModelConfig, tc: TrainConfig, plan=None):
    """Jitted SFT step through the same execution layer as the RL step
    (plan shardings + donated state; ``TrainConfig.mesh`` decides when no
    plan is passed)."""
    from repro.parallel import make_sharded_sft_step, plan_from_flag
    return make_sharded_sft_step(cfg, tc, plan or plan_from_flag(tc.mesh,
                                                                 "train"))
