"""Batched KV-cache generation engine (the sampler node's workhorse).

``generate`` runs prefill + a jitted ``lax.scan`` decode loop, recording
the model log-prob of every sampled token. Per App. B.1 these engine-side
log-probs are *metadata*: the learner recomputes them with its own forward
pass by default (``RLConfig.recompute_sampler_logps``), reproducing the
paper's fix for the vLLM/FSDP log-prob mismatch.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RLConfig
from repro.data.tasks import EOS, PAD
from repro.models import decode_step, forward, init_cache
from repro.sampling.sample import sample_token


@functools.partial(jax.jit, static_argnames=("cfg", "rl", "max_new",
                                             "vocab_limit"))
def _generate_jit(cfg: ModelConfig, rl: RLConfig, params, prompts, key,
                  max_new: int, vocab_limit: int,
                  memory: Optional[jax.Array] = None):
    b, tp = prompts.shape
    cache = init_cache(cfg, params, b, tp + max_new, memory=memory)
    logits, cache, _ = forward(cfg, params, prompts, cache=cache,
                               memory=memory)
    last = logits[:, -1]

    def mask_vocab(lg):
        if vocab_limit < lg.shape[-1]:
            bad = jnp.arange(lg.shape[-1]) >= vocab_limit
            lg = jnp.where(bad, -1e30, lg)
        return lg

    def step(carry, k):
        cache, last, done, pos = carry
        lg = mask_vocab(last)
        tok, _, _ = sample_token(k, lg, temperature=rl.temperature,
                                 top_k=rl.top_k, top_p=rl.top_p)
        tok = jnp.where(done, PAD, tok)
        valid = ~done
        # report the *full-model* logp of the drawn token (what the
        # learner's teacher-forced recompute sees — vLLM convention)
        full_lp = jax.nn.log_softmax(last.astype(jnp.float32), axis=-1)
        lp_model = jnp.take_along_axis(full_lp, tok[:, None],
                                       axis=-1)[:, 0]
        lp_model = jnp.where(done, 0.0, lp_model)
        new_logits, cache = decode_step(cfg, params, cache, tok, pos,
                                        memory=memory)
        done = done | (tok == EOS)
        return (cache, new_logits, done, pos + 1), (tok, lp_model, valid)

    keys = jax.random.split(key, max_new)
    (_, _, done, _), (toks, lps, valid) = jax.lax.scan(
        step, (cache, last, jnp.zeros((b,), bool), jnp.int32(tp)), keys)
    completions = toks.T                        # (B, max_new)
    sampler_lp = lps.T
    comp_mask = valid.T.astype(jnp.float32)
    return completions, sampler_lp, comp_mask


def generate(cfg: ModelConfig, rl: RLConfig, params, prompts: jax.Array,
             key: jax.Array, *, max_new: Optional[int] = None,
             vocab_limit: Optional[int] = None,
             memory: Optional[jax.Array] = None) -> Dict[str, jax.Array]:
    """Returns a rollout dict:
    tokens (B, Tp+max_new) | completions (B, max_new) |
    sampler_lp (B, max_new) engine-side logps | comp_mask (B, max_new).
    """
    max_new = max_new or rl.max_new_tokens
    vocab_limit = vocab_limit or cfg.padded_vocab
    completions, sampler_lp, comp_mask = _generate_jit(
        cfg, rl, params, prompts, key, max_new, vocab_limit, memory)
    tokens = jnp.concatenate([prompts, completions], axis=1)
    return {"tokens": tokens, "completions": completions,
            "sampler_lp": sampler_lp, "comp_mask": comp_mask,
            "prompt_len": prompts.shape[1]}


def token_logps(cfg: ModelConfig, params, tokens: jax.Array, *,
                memory: Optional[jax.Array] = None) -> jax.Array:
    """Teacher-forced log p(tokens[t] | tokens[<t]) -> (B, T-1).

    On TPU this is served by the ``fused_logprob`` Pallas kernel (see
    repro.kernels); this is the portable jnp path.
    """
    from repro.core.logprob import token_logprob_from_logits
    logits, _, _ = forward(cfg, params, tokens[:, :-1], memory=memory)
    return token_logprob_from_logits(logits, tokens[:, 1:])
