"""Generation engines (the sampler node's workhorse).

Two engines share one contract (a rollout dict with tokens, completions,
engine-side log-probs and a completion mask):

- **static** — prefill + one jitted ``lax.scan`` decode loop over the
  whole batch. Every sequence runs the full ``max_new`` steps even after
  EOS (finished rows decode PAD into dead cache slots).
- **continuous** — a fixed pool of decode slots over a paged
  (block-table) KV cache with a request queue: finished sequences free
  their slot and pages immediately, and chunked prefill for the next
  queued prompt interleaves with the jitted decode step. Same tokens and
  log-probs as the static engine for identical seeds (RNG is folded per
  request, never per batch position), but no wasted decode steps.

Per App. B.1 the engine-side log-probs are *metadata*: the learner
recomputes them with its own forward pass by default
(``RLConfig.recompute_sampler_logps``), reproducing the paper's fix for
the vLLM/FSDP log-prob mismatch.
"""
from __future__ import annotations

import functools
import warnings
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RLConfig
from repro.data.tasks import EOS, PAD
from repro.models import decode_step, forward, init_cache
from repro.parallel import plan_for_params
from repro.sampling.paged_cache import (PageAllocator, SCRATCH_PAGE,
                                        init_paged_pool,
                                        paged_cache_supported, pages_for)
from repro.sampling.sample import sample_token_rows
from repro.sampling.scheduler import (DECODE, PREFILL, ContinuousScheduler,
                                      GenRequest)


def _mask_vocab(lg: jax.Array, vocab_limit: int) -> jax.Array:
    if vocab_limit < lg.shape[-1]:
        bad = jnp.arange(lg.shape[-1]) >= vocab_limit
        lg = jnp.where(bad, -1e30, lg)
    return lg


def _model_logp(last: jax.Array, tok: jax.Array) -> jax.Array:
    """Full-model logp of the drawn token (what the learner's
    teacher-forced recompute sees — vLLM convention)."""
    full_lp = jax.nn.log_softmax(last.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(full_lp, tok[:, None], axis=-1)[:, 0]


# --------------------------------------------------------------------------
# static engine: one lax.scan to max_new


@functools.partial(jax.jit, static_argnames=("cfg", "rl", "max_new",
                                             "vocab_limit", "plan"))
def _generate_jit(cfg: ModelConfig, rl: RLConfig, params, prompts, key,
                  max_new: int, vocab_limit: int,
                  memory: Optional[jax.Array] = None, plan=None):
    b, tp = prompts.shape
    if plan is not None:        # tensor-parallel serve: the ExecutionPlan
        params = plan.constrain_params(cfg, params)
    cache = init_cache(cfg, params, b, tp + max_new, memory=memory)
    if plan is not None:        # KV cache placed by the same cache_specs
        cache = plan.constrain_cache(cfg, cache)
    logits, cache, _ = forward(cfg, params, prompts, cache=cache,
                               memory=memory)
    last = logits[:, -1]
    # one RNG stream per request row: draw t uses fold_in(fold_in(key, r), t)
    # — identical draws no matter which engine/slot serves the request.
    row_keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(jnp.arange(b))

    def step(carry, t):
        cache, last, done, pos = carry
        lg = _mask_vocab(last, vocab_limit)
        kt = jax.vmap(jax.random.fold_in)(row_keys, jnp.full((b,), t))
        tok, _, _ = sample_token_rows(kt, lg, temperature=rl.temperature,
                                      top_k=rl.top_k, top_p=rl.top_p)
        tok = jnp.where(done, PAD, tok)
        valid = ~done
        lp_model = jnp.where(done, 0.0, _model_logp(last, tok))
        new_logits, cache = decode_step(cfg, params, cache, tok, pos,
                                        memory=memory)
        done = done | (tok == EOS)
        return (cache, new_logits, done, pos + 1), (tok, lp_model, valid)

    (_, _, done, _), (toks, lps, valid) = jax.lax.scan(
        step, (cache, last, jnp.zeros((b,), bool), jnp.int32(tp)),
        jnp.arange(max_new))
    completions = toks.T                        # (B, max_new)
    sampler_lp = lps.T
    comp_mask = valid.T.astype(jnp.float32)
    return completions, sampler_lp, comp_mask


# --------------------------------------------------------------------------
# continuous-batching engine: slot pool + paged KV cache


@functools.partial(jax.jit, static_argnames=("cfg", "plan"),
                   donate_argnums=(2,))
def _prefill_chunk_jit(cfg: ModelConfig, params, pool, page_row, tokens,
                       start, plan=None):
    """One chunk of one request's prompt: tokens (1, C) at positions
    ``start + [0, C)``, K/V scattered into the request's pages. Returns
    (logits (C, V), pool)."""
    if plan is not None:
        params = plan.constrain_params(cfg, params)
        pool = plan.constrain_cache(cfg, pool)
    c = tokens.shape[1]
    positions = start + jnp.arange(c, dtype=jnp.int32)[None, :]
    logits, pool, _ = forward(cfg, params, tokens, positions=positions,
                              cache=pool, page_table=page_row)
    return logits[0], pool


@functools.partial(jax.jit, static_argnames=("cfg", "rl", "vocab_limit",
                                             "sync_every", "plan"),
                   donate_argnums=(3,))
def _decode_chunk_jit(cfg: ModelConfig, rl: RLConfig, params, pool,
                      page_table, last, pos, active, req_keys, gen0,
                      max_new_v, vocab_limit: int, sync_every: int,
                      plan=None):
    """``sync_every`` decode steps over every slot in one executable — the
    decode horizon that amortizes host dispatch; the scheduler regains
    control (EOS recycling, admission) only between chunks.

    Slots that finish mid-chunk (EOS / token budget) keep decoding PAD at
    position 0 — within their own first page, or the scratch page for
    empty slots — so the batch shape stays fixed and no live KV is ever
    touched. Draw ``i`` of slot ``s`` uses fold_in(req_keys[s], gen0[s]+i):
    the host discards post-EOS draws, and earlier draws are bit-identical
    to the static engine's.
    """
    if plan is not None:
        params = plan.constrain_params(cfg, params)
        pool = plan.constrain_cache(cfg, pool)

    def step(carry, i):
        pool, last, done = carry
        over = (gen0 + i) >= max_new_v              # token budget exhausted
        dead = done | over
        lg = _mask_vocab(last, vocab_limit)
        kt = jax.vmap(jax.random.fold_in)(req_keys, gen0 + i)
        tok, _, _ = sample_token_rows(kt, lg, temperature=rl.temperature,
                                      top_k=rl.top_k, top_p=rl.top_p)
        lp = jnp.where(dead, 0.0, _model_logp(last, tok))
        tok = jnp.where(dead, PAD, tok)
        step_pos = jnp.where(dead, 0, pos + i)
        new_last, pool = decode_step(cfg, params, pool, tok, step_pos,
                                     page_table=page_table)
        done = done | (tok == EOS)
        return (pool, new_last, done), (tok, lp)

    (pool, last, _), (toks, lps) = jax.lax.scan(
        step, (pool, last, ~active), jnp.arange(sync_every))
    return toks, lps, last, pool                    # toks (K, num_slots)


def _live_width(need_pages: int, cap: int) -> int:
    """Block-table width actually handed to the jitted chunk fns: the
    live-page high-water mark rounded up to a power of two (so widths
    bucket into O(log) executables), capped at ``pages_per_slot``.

    Narrowing is *bit-exact*: every page dropped is provably masked in
    attention (positions >= every slot's length), and masked entries
    contribute exact zeros to the softmax — so even the default gather
    impl stops materializing (and the kernel stops iterating) the dead
    tail of the pool."""
    w = 1
    while w < need_pages:
        w *= 2
    return min(w, cap)


def generate_continuous(cfg: ModelConfig, rl: RLConfig, params,
                        prompts: jax.Array, key: jax.Array, *,
                        max_new: Optional[int] = None,
                        vocab_limit: Optional[int] = None,
                        num_slots: Optional[int] = None,
                        page_size: int = 16,
                        prefill_chunk: Optional[int] = None,
                        prompt_lens: Optional[Sequence[int]] = None,
                        sync_every: int = 8,
                        plan=None,
                        ) -> Dict[str, jax.Array]:
    """Continuous-batching generation over ``prompts`` (B, Tp).

    Drop-in for the static path: same rollout dict, same tokens/logps for
    the same ``key`` (per-request RNG streams). Extras: ``num_slots``
    decode slots are recycled as requests finish, ``prompt_lens`` admits
    per-request true prompt lengths (rows shorter than Tp),
    ``prefill_chunk`` bounds how much prompt is prefilled between decode
    chunks (defaults to the whole prompt in one chunk), and ``sync_every``
    is the decode horizon: jitted decode steps per scheduler sync (larger
    amortizes dispatch, smaller recycles slots sooner). ``plan`` (an
    ``ExecutionPlan``) makes prefill/decode run tensor-parallel: params
    and the paged KV pool are constrained by the plan's cache_specs.
    """
    if not paged_cache_supported(cfg):
        raise ValueError(f"{cfg.name}: continuous engine needs an "
                         "attention-only decode cache (no enc-dec / "
                         "ring-KV / modality memory)")
    max_new = max_new or rl.max_new_tokens
    vocab_limit = vocab_limit or cfg.padded_vocab
    prompts_np = np.asarray(prompts)
    b, tp = prompts_np.shape
    num_slots = min(b, num_slots or 8)
    prefill_chunk = min(tp, prefill_chunk or tp)

    pages_per_slot = pages_for(tp + max_new, page_size)
    num_pages = 1 + num_slots * pages_per_slot       # + scratch page 0
    pool = init_paged_pool(cfg, num_pages, page_size)
    sched = ContinuousScheduler(num_slots, pages_per_slot, page_size,
                                PageAllocator(num_pages))
    for r in range(b):
        plen = int(prompt_lens[r]) if prompt_lens is not None else tp
        if not 0 < plen <= tp:
            raise ValueError(f"prompt_lens[{r}]={plen} outside (0, {tp}]")
        sched.submit(GenRequest(rid=r,
                                prompt=prompts_np[r, :plen].astype(np.int32),
                                max_new=max_new))

    last = jnp.zeros((num_slots, cfg.padded_vocab), jnp.float32)
    pos_np = np.zeros((num_slots,), np.int32)
    active_np = np.zeros((num_slots,), bool)
    gen_np = np.zeros((num_slots,), np.int32)
    max_new_np = np.full((num_slots,), max_new, np.int32)
    req_keys_np = np.zeros((num_slots, 2), np.uint32)   # threefry key data

    while not sched.all_done:
        sched.admit()

        # chunked prefill: every prefilling slot advances one chunk per
        # iteration, interleaved with the decode chunks below
        for pref in [r for r in sched.slots
                     if r is not None and r.state == PREFILL]:
            c0 = pref.prefill_pos
            chunk = pref.prompt[c0:c0 + prefill_chunk]
            if chunk.shape[0] < prefill_chunk:          # pad to fixed shape
                chunk = np.concatenate(
                    [chunk, np.full(prefill_chunk - chunk.shape[0], PAD,
                                    np.int32)])
            # only pages reachable from this chunk's max position — the
            # gather inside the paged prefill branch scales with c0 + C,
            # not pool capacity. Padded-tail writes past the narrowed
            # width hit the same OOB-drop path as past the full width.
            width = _live_width(pages_for(c0 + prefill_chunk, page_size),
                                pages_per_slot)
            page_row = jnp.asarray(
                sched.block_table[pref.slot:pref.slot + 1, :width])
            logits_c, pool = _prefill_chunk_jit(
                cfg, params, pool, page_row, jnp.asarray(chunk[None]),
                jnp.int32(c0), plan=plan)
            sched.stats["prefill_chunks"] += 1
            pref.prefill_pos = min(pref.prompt_len, c0 + prefill_chunk)
            if pref.prefill_pos >= pref.prompt_len:     # prompt fully cached
                s = pref.slot
                last = last.at[s].set(logits_c[pref.prompt_len - 1 - c0])
                pref.state = DECODE
                active_np[s], pos_np[s], gen_np[s] = True, pref.prompt_len, 0
                max_new_np[s] = pref.max_new
                req_keys_np[s] = np.asarray(
                    jax.random.fold_in(key, pref.rid), np.uint32)

        dec = sched.decoding()
        if not dec:
            continue
        # non-decoding slots (empty, or mid-prefill) must scatter their
        # dead PAD writes into the scratch page — NOT position 0 of pages
        # a prefilling request has already filled. The table is narrowed
        # to the live high-water mark over this decode chunk (per-slot
        # ``lengths`` = the pos vector bound the page loop inside the
        # kernel; the width bounds every impl's upper shape).
        width = _live_width(
            pages_for(int(pos_np[active_np].max()) + sync_every, page_size),
            pages_per_slot)
        bt = sched.block_table[:, :width].copy()
        bt[~active_np] = SCRATCH_PAGE
        toks, lps, last, pool = _decode_chunk_jit(
            cfg, rl, params, pool, jnp.asarray(bt), last,
            jnp.asarray(pos_np), jnp.asarray(active_np),
            jnp.asarray(req_keys_np), jnp.asarray(gen_np),
            jnp.asarray(max_new_np), vocab_limit, sync_every, plan=plan)
        sched.stats["decode_steps"] += sync_every
        tok_np, lp_np = np.asarray(toks), np.asarray(lps)
        for r in dec:
            for i in range(sync_every):
                if r.gen_count >= r.max_new:
                    break
                t = int(tok_np[i, r.slot])
                r.tokens.append(t)
                r.logps.append(float(lp_np[i, r.slot]))
                sched.stats["decode_slot_steps"] += 1
                if t == EOS:
                    break
            pos_np[r.slot] = r.next_pos
            gen_np[r.slot] = r.gen_count
            if r.tokens and r.tokens[-1] == EOS:
                active_np[r.slot] = False
                sched.finish(r, "eos")
            elif r.gen_count >= r.max_new:
                active_np[r.slot] = False
                sched.finish(r, "length")

    completions = np.full((b, max_new), PAD, np.int32)
    sampler_lp = np.zeros((b, max_new), np.float32)
    comp_mask = np.zeros((b, max_new), np.float32)
    for req in sched.finished:
        n = req.gen_count
        completions[req.rid, :n] = req.tokens
        sampler_lp[req.rid, :n] = req.logps
        comp_mask[req.rid, :n] = 1.0
    tokens = np.concatenate([prompts_np, completions], axis=1)
    return {"tokens": jnp.asarray(tokens),
            "completions": jnp.asarray(completions),
            "sampler_lp": jnp.asarray(sampler_lp),
            "comp_mask": jnp.asarray(comp_mask),
            "prompt_len": tp,
            "stats": dict(sched.stats,
                          slot_utilization=sched.slot_utilization())}


# --------------------------------------------------------------------------
# dispatch


def generate(cfg: ModelConfig, rl: RLConfig, params, prompts: jax.Array,
             key: jax.Array, *, max_new: Optional[int] = None,
             vocab_limit: Optional[int] = None,
             memory: Optional[jax.Array] = None,
             engine: Optional[str] = None,
             plan=None,
             **continuous_kwargs) -> Dict[str, jax.Array]:
    """Returns a rollout dict:
    tokens (B, Tp+max_new) | completions (B, max_new) |
    sampler_lp (B, max_new) engine-side logps | comp_mask (B, max_new).

    ``engine`` (default ``rl.engine``) picks the static scan or the
    continuous-batching slot pool; architectures the paged cache can't
    serve (SSM/enc-dec/ring-KV/modality memory) fall back to static with
    a warning. Every path executes under an ``ExecutionPlan`` (``plan``;
    default: a serve-mode plan on whatever mesh ``params`` already live
    on) — on a >1-device mesh the same call runs tensor-parallel.
    """
    engine = engine or rl.engine
    plan = plan or plan_for_params(params, "serve")
    if engine not in ("static", "continuous"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "static" and continuous_kwargs:
        # don't silently ignore num_slots=… etc. on the static path
        raise TypeError("static engine takes no continuous-engine kwargs: "
                        f"{sorted(continuous_kwargs)}")
    max_new = max_new or rl.max_new_tokens
    vocab_limit = vocab_limit or cfg.padded_vocab
    if engine == "continuous":
        if memory is None and paged_cache_supported(cfg):
            return generate_continuous(cfg, rl, params, prompts, key,
                                       max_new=max_new,
                                       vocab_limit=vocab_limit,
                                       plan=plan,
                                       **continuous_kwargs)
        dropped = (f"; ignoring {sorted(continuous_kwargs)}"
                   if continuous_kwargs else "")
        warnings.warn(f"{cfg.name}: continuous engine unsupported for this "
                      f"architecture/memory setup; falling back to "
                      f"static{dropped}", stacklevel=2)
    completions, sampler_lp, comp_mask = _generate_jit(
        cfg, rl, params, prompts, key, max_new, vocab_limit, memory,
        plan=plan)
    tokens = jnp.concatenate([prompts, completions], axis=1)
    return {"tokens": tokens, "completions": completions,
            "sampler_lp": sampler_lp, "comp_mask": comp_mask,
            "prompt_len": prompts.shape[1]}


def token_logps(cfg: ModelConfig, params, tokens: jax.Array, *,
                memory: Optional[jax.Array] = None,
                logprob_impl: Optional[str] = None) -> jax.Array:
    """Teacher-forced log p(tokens[t] | tokens[<t]) -> (B, T-1).

    This is the App. B.1 untrusted-sampler recompute — the same hot path
    as the learner's loss, so it dispatches to the fused streaming
    kernel (Pallas on TPU, chunked ``lax.map`` elsewhere) instead of
    materializing a (B·T, V) f32 log-softmax. ``logprob_impl`` takes the
    ``TrainConfig.logprob_impl`` vocabulary ("pallas" | "chunked" |
    "naive" to force a backend); None or "fused" auto-dispatches.
    """
    from repro.kernels.ops import fused_token_logprob
    logits, _, _ = forward(cfg, params, tokens[:, :-1], memory=memory)
    impl = None if logprob_impl == "fused" else logprob_impl
    lp, _ = fused_token_logprob(logits, tokens[:, 1:], impl=impl)
    return lp
