"""Generation engines (the sampler node's workhorse).

Two engines share one request-level contract
(:class:`repro.serving.api.Engine`):

- **static** (:class:`StaticEngine`) — prefill + one jitted ``lax.scan``
  decode loop over the whole batch. Every sequence runs the full
  ``max_new`` steps even after EOS (finished rows decode PAD into dead
  cache slots).
- **continuous** (:class:`repro.sampling.continuous.ContinuousEngine`) —
  a fixed pool of decode slots over a paged (block-table) KV cache with
  a priority request queue: finished sequences free their slot and pages
  immediately, chunked prefill for the next queued prompt interleaves
  with the jitted decode step, and shared prompt prefixes reuse KV pages
  across requests. Same tokens and log-probs as the static engine for
  identical seeds (RNG is folded per request id, never per batch
  position), but no wasted decode steps.

``build_engine`` constructs either from a ``ServeConfig`` deployment
description. The module-level ``generate(cfg, rl, params, prompts, ...)``
is the legacy batch entry point, kept as a thin shim over the engines —
new code should build an engine once and feed it
:class:`~repro.serving.api.Request` objects (see README "Serving").

Per App. B.1 the engine-side log-probs are *metadata*: the learner
recomputes them with its own forward pass by default
(``RLConfig.recompute_sampler_logps``), reproducing the paper's fix for
the vLLM/FSDP log-prob mismatch.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RLConfig, ServeConfig
from repro.data.tasks import EOS, PAD
from repro.models import decode_step, forward, init_cache
from repro.parallel import plan_for_params
from repro.sampling.continuous import (ContinuousEngine, generate_continuous,
                                       rollout_from_results)
from repro.sampling.paged_cache import paged_cache_supported
from repro.sampling.sample import mask_vocab, model_logp, sample_token_rows
from repro.serving.api import GenerationResult, Request, SamplingParams

# --------------------------------------------------------------------------
# static engine: one lax.scan to max_new


@functools.partial(jax.jit, static_argnames=("cfg", "rl", "max_new",
                                             "vocab_limit", "plan"))
def _generate_jit(cfg: ModelConfig, rl: RLConfig, params, prompts, key,
                  max_new: int, vocab_limit: int,
                  memory: Optional[jax.Array] = None, plan=None,
                  rids: Optional[jax.Array] = None):
    b, tp = prompts.shape
    if plan is not None:        # tensor-parallel serve: the ExecutionPlan
        params = plan.constrain_params(cfg, params)
    cache = init_cache(cfg, params, b, tp + max_new, memory=memory)
    if plan is not None:        # KV cache placed by the same cache_specs
        cache = plan.constrain_cache(cfg, cache)
    logits, cache, _ = forward(cfg, params, prompts, cache=cache,
                               memory=memory)
    last = logits[:, -1]
    # one RNG stream per request id: draw t uses fold_in(fold_in(key, rid), t)
    # — identical draws no matter which engine/slot serves the request.
    # rid defaults to the batch row (the legacy batch entry point).
    if rids is None:
        rids = jnp.arange(b)
    row_keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(rids)

    def step(carry, t):
        cache, last, done, pos = carry
        lg = mask_vocab(last, vocab_limit)
        kt = jax.vmap(jax.random.fold_in)(row_keys, jnp.full((b,), t))
        tok, _, _ = sample_token_rows(kt, lg, temperature=rl.temperature,
                                      top_k=rl.top_k, top_p=rl.top_p)
        tok = jnp.where(done, PAD, tok)
        valid = ~done
        lp_model = jnp.where(done, 0.0, model_logp(last, tok))
        new_logits, cache = decode_step(cfg, params, cache, tok, pos,
                                        memory=memory)
        done = done | (tok == EOS)
        return (cache, new_logits, done, pos + 1), (tok, lp_model, valid)

    (_, _, done, _), (toks, lps, valid) = jax.lax.scan(
        step, (cache, last, jnp.zeros((b,), bool), jnp.int32(tp)),
        jnp.arange(max_new))
    completions = toks.T                        # (B, max_new)
    sampler_lp = lps.T
    comp_mask = valid.T.astype(jnp.float32)
    return completions, sampler_lp, comp_mask


class StaticEngine:
    """Request-level wrapper over the one-scan static path.

    The scan is rectangular, so a batch must share one prompt length
    (use the continuous engine for ragged/streaming workloads);
    ``max_new_tokens`` may vary per request — the scan runs to the batch
    max and each request is trimmed host-side, which is exact because
    draw ``t`` of request ``rid`` never depends on the scan length.
    """

    def __init__(self, cfg: ModelConfig, params, *, rl: RLConfig,
                 vocab_limit: Optional[int] = None,
                 memory: Optional[jax.Array] = None,
                 plan=None,
                 key: Optional[jax.Array] = None) -> None:
        self.cfg, self.rl, self.params = cfg, rl, params
        self.vocab_limit = vocab_limit or cfg.padded_vocab
        self.memory, self.plan = memory, plan
        self.key = key if key is not None else jax.random.PRNGKey(0)

    @property
    def profile(self) -> tuple:
        return (self.rl.temperature, self.rl.top_k, self.rl.top_p)

    def update_params(self, params: Any) -> None:
        self.params = params

    def generate(self, requests: Sequence[Request],
                 key: Optional[jax.Array] = None) -> List[GenerationResult]:
        if key is not None:
            self.key = key
        for req in requests:
            if req.params.profile != self.profile:
                raise ValueError(
                    f"request {req.rid}: sampling profile "
                    f"{req.params.profile} != engine profile {self.profile}")
        plens = {r.prompt_len for r in requests}
        if len(plens) > 1:
            raise ValueError(
                "static engine scans a rectangular batch: got prompt "
                f"lengths {sorted(plens)} — pad, or use the continuous "
                "engine for ragged prompts")
        t0 = time.perf_counter()
        prompts = jnp.asarray(np.stack([r.prompt for r in requests]))
        rids = jnp.asarray([r.rid for r in requests], jnp.int32)
        max_new = max(r.params.max_new_tokens for r in requests)
        completions, sampler_lp, comp_mask = _generate_jit(
            self.cfg, self.rl, self.params, prompts, self.key, max_new,
            self.vocab_limit, self.memory, plan=self.plan, rids=rids)
        elapsed = time.perf_counter() - t0
        # deliberate sync point: the static engine runs the whole batch to
        # completion in one executable, so the single batch-end transfer
        # is the design, not a stall in a loop
        comp_np = np.asarray(completions)   # noqa: RA003
        lp_np = np.asarray(sampler_lp)      # noqa: RA003
        mask_np = np.asarray(comp_mask)     # noqa: RA003
        out: List[GenerationResult] = []
        for i, req in enumerate(requests):
            budget = req.params.max_new_tokens
            n = int(mask_np[i, :budget].sum())
            toks = comp_np[i, :n]
            reason = "eos" if n and toks[-1] == EOS else "length"
            out.append(GenerationResult(
                rid=req.rid, tokens=toks.astype(np.int32),
                logps=lp_np[i, :n].astype(np.float32),
                finish_reason=reason, prompt_len=req.prompt_len,
                ttft_s=elapsed, latency_s=elapsed))
        return out


# --------------------------------------------------------------------------
# factory


def build_engine(cfg: ModelConfig, params, serve: ServeConfig, *,
                 rl: Optional[RLConfig] = None,
                 vocab_limit: Optional[int] = None,
                 memory: Optional[jax.Array] = None,
                 plan=None,
                 key: Optional[jax.Array] = None):
    """Construct the engine a ``ServeConfig`` describes.

    ``rl`` carries the deployment's sampling profile (every request must
    match it); ``plan`` is the resolved ExecutionPlan for ``serve.mesh``
    (callers that already placed ``params`` pass their plan). Falls back
    to the static engine — with a warning — for architectures the paged
    cache can't serve and for encoder/memory models.
    """
    rl = rl or RLConfig(engine=serve.engine)
    if serve.paged_attn_impl:
        cfg = dataclasses.replace(cfg, paged_attn_impl=serve.paged_attn_impl)
    if serve.engine == "continuous":
        if memory is None and paged_cache_supported(cfg):
            return ContinuousEngine(
                cfg, params, rl=rl,
                max_total_tokens=serve.max_total_tokens,
                num_slots=serve.num_slots, page_size=serve.page_size,
                sync_every=serve.sync_every,
                prefill_chunk=serve.prefill_chunk or None,
                num_pages=serve.resolved_num_pages,
                vocab_limit=vocab_limit, plan=plan,
                prefix_cache=serve.prefix_cache,
                prefix_cache_entries=serve.prefix_cache_entries,
                spec_k=serve.spec_k,
                spec_ngram_max=serve.spec_ngram_max,
                spec_ngram_min=serve.spec_ngram_min,
                spec_rescore=serve.spec_rescore, key=key)
        warnings.warn(f"{cfg.name}: continuous engine unsupported for this "
                      "architecture/memory setup; serving static",
                      stacklevel=2)
    return StaticEngine(cfg, params, rl=rl, vocab_limit=vocab_limit,
                        memory=memory, plan=plan, key=key)


# --------------------------------------------------------------------------
# legacy batch entry point (deprecated shim)


def generate(cfg: ModelConfig, rl: RLConfig, params, prompts: jax.Array,
             key: jax.Array, *, max_new: Optional[int] = None,
             vocab_limit: Optional[int] = None,
             memory: Optional[jax.Array] = None,
             engine: Optional[str] = None,
             plan=None,
             **continuous_kwargs) -> Dict[str, jax.Array]:
    """Batched generation over ``prompts`` (B, Tp). Returns a rollout dict:
    tokens (B, Tp+max_new) | completions (B, max_new) |
    sampler_lp (B, max_new) engine-side logps | comp_mask (B, max_new).

    .. deprecated::
        This is the pre-request-API surface, kept as a thin shim for the
        training loop and existing callers. New code should
        ``build_engine(cfg, params, ServeConfig(...))`` once and call
        ``engine.generate([Request(...), ...])`` — see README "Serving".

    ``engine`` (default ``rl.engine``) picks the static scan or the
    continuous-batching slot pool; architectures the paged cache can't
    serve (SSM/enc-dec/ring-KV/modality memory) fall back to static with
    a warning. Every path executes under an ``ExecutionPlan`` (``plan``;
    default: a serve-mode plan on whatever mesh ``params`` already live
    on) — on a >1-device mesh the same call runs tensor-parallel.
    """
    engine = engine or rl.engine
    plan = plan or plan_for_params(params, "serve")
    if engine not in ("static", "continuous"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "static" and continuous_kwargs:
        # don't silently ignore num_slots=… etc. on the static path
        raise TypeError("static engine takes no continuous-engine kwargs: "
                        f"{sorted(continuous_kwargs)}")
    max_new = max_new or rl.max_new_tokens
    vocab_limit = vocab_limit or cfg.padded_vocab
    if engine == "continuous":
        if memory is None and paged_cache_supported(cfg):
            return generate_continuous(cfg, rl, params, prompts, key,
                                       max_new=max_new,
                                       vocab_limit=vocab_limit,
                                       plan=plan,
                                       **continuous_kwargs)
        dropped = (f"; ignoring {sorted(continuous_kwargs)}"
                   if continuous_kwargs else "")
        warnings.warn(f"{cfg.name}: continuous engine unsupported for this "
                      f"architecture/memory setup; falling back to "
                      f"static{dropped}", stacklevel=2)
    completions, sampler_lp, comp_mask = _generate_jit(
        cfg, rl, params, prompts, key, max_new, vocab_limit, memory,
        plan=plan)
    tokens = jnp.concatenate([prompts, completions], axis=1)
    return {"tokens": tokens, "completions": completions,
            "sampler_lp": sampler_lp, "comp_mask": comp_mask,
            "prompt_len": prompts.shape[1]}


def token_logps(cfg: ModelConfig, params, tokens: jax.Array, *,
                memory: Optional[jax.Array] = None,
                logprob_impl: Optional[str] = None) -> jax.Array:
    """Teacher-forced log p(tokens[t] | tokens[<t]) -> (B, T-1).

    This is the App. B.1 untrusted-sampler recompute — the same hot path
    as the learner's loss, so it dispatches to the fused streaming
    kernel (Pallas on TPU, chunked ``lax.map`` elsewhere) instead of
    materializing a (B·T, V) f32 log-softmax. ``logprob_impl`` takes the
    ``TrainConfig.logprob_impl`` vocabulary ("pallas" | "chunked" |
    "naive" to force a backend); None or "fused" auto-dispatches.
    """
    from repro.kernels.ops import fused_token_logprob
    logits, _, _ = forward(cfg, params, tokens[:, :-1], memory=memory)
    impl = None if logprob_impl == "fused" else logprob_impl
    lp, _ = fused_token_logprob(logits, tokens[:, 1:], impl=impl)
    return lp


__all__ = ["generate", "generate_continuous", "token_logps", "build_engine",
           "StaticEngine", "ContinuousEngine", "rollout_from_results",
           "GenerationResult", "Request", "SamplingParams"]
