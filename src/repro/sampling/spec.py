"""Speculative decoding: draft proposal + k-token paged verification.

Raising decode tokens/s is GEPO's stability lever in the HeteroRL
setting: slow sampler nodes widen the latency window that inflates KL
divergence and importance-weight variance (PAPER.md §3), so a decode
speedup shrinks staleness directly. This module holds the *model-free*
half of the speculative pipeline — everything that does not need the
target model:

- :class:`DraftProposer` — the protocol the continuous engine drafts
  through; a small draft *model* can slot in later behind the same
  ``propose(history, k)`` surface.
- :class:`NGramDrafter` — prompt-lookup / n-gram drafting over the
  slot's own token history (prompt + committed completion): find the
  most recent earlier occurrence of the current n-gram suffix and
  propose its continuation. Zero extra FLOPs, surprisingly strong on
  templated / repetitive workloads, honest ~0 accept rate on
  incompressible ones.
- :func:`accept_drafts` — the in-jit acceptance rule, shared by the
  engine's verification executable and the tests.
- :func:`fused_rescore_diff` — the acceptance *rescore* through ONE
  ``paged_prefill_layers`` launch instead of L per-layer launches (the
  fused-layer kernels' first real consumer): replay every layer's
  window attention from the recorded per-layer queries against the
  freshly-scattered pools and report the max abs deviation from the
  in-forward outputs. Bit-exactness is the invariant (same operands,
  row-independent math); a nonzero value means the folded launch and
  the scan disagree — a kernel regression surfaced at serve time on a
  gauge instead of in a post-mortem.

Acceptance rule (exact replay)
------------------------------
The engine's RNG is counter-based: draw ``g`` of request ``rid`` is
``categorical(fold_in(req_key, g), filtered_logits)`` — a pure function
of (key, logits), independent of sampling history. Verification scores
the window ``[pending, d_1..d_k]`` in one prefill-shaped forward, so
row ``i-1`` holds the target logits *after* ``d_1..d_{i-1}``; replaying
the engine's draw at every row then gives the exact token the
sequential non-speculative engine would have emitted, and the accepted
prefix is the longest one where the drafts match those draws. This is
speculative rejection sampling with a point-mass proposal evaluated
against the engine's own uniform stream: the emitted tokens are
*literally* the target model's sequential samples, so the sampled
distribution is preserved exactly (not just in expectation), greedy
decoding stays bit-identical to the non-speculative path, and every
reported logp is the target model's logp of the emitted token — never
the drafter's (the GEPO importance-weight contract, App. B.1).
"""
from __future__ import annotations

from typing import Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LOCAL, ModelConfig
from repro.data.tasks import EOS, PAD
from repro.sampling.sample import mask_vocab, model_logp, sample_token_rows

_EMPTY = np.zeros((0,), np.int32)


class DraftProposer(Protocol):
    """Anything that can guess the next ``k`` tokens for one slot."""

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        """Given the slot's token history (prompt + committed completion,
        1-D int32, pending token last), return up to ``k`` proposed next
        tokens (1-D int32, possibly empty). Host-side, per slot."""
        ...


class NGramDrafter:
    """Prompt-lookup drafting: propose the continuation of the most
    recent earlier occurrence of the current suffix n-gram.

    Tries suffix lengths ``max_ngram`` down to ``min_ngram`` and takes
    the *most recent* prior match — recency beats frequency on the
    looping/templated outputs this drafter exists for. ``max_history``
    bounds the per-call scan so drafting stays O(history) cheap.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1,
                 max_history: int = 4096) -> None:
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(f"need 1 <= min_ngram <= max_ngram, got "
                             f"({min_ngram}, {max_ngram})")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.max_history = max_history

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.ascontiguousarray(
            np.asarray(history, np.int32)[-self.max_history:])
        out = self._lookup(h, k)
        # chain: a match near the end of history (short loop) yields a
        # continuation shorter than k — extend it by re-proposing over
        # history + draft-so-far, so a length-c cycle still fills all k
        # slots instead of c-1. Each iteration adds >= 1 token or stops.
        while 0 < out.shape[0] < k:
            more = self._lookup(np.concatenate([h, out]), k - out.shape[0])
            if more.shape[0] == 0:
                break
            out = np.concatenate([out, more])
        return out

    def _lookup(self, h: np.ndarray, k: int) -> np.ndarray:
        n = h.shape[0]
        if k <= 0 or n < self.min_ngram + 1:
            return _EMPTY
        # byte-level rfind (C speed — this runs per slot per verify
        # round, so the python cost of a sliding-window compare would
        # land straight on the round latency): a window starting at
        # element j0 is a match at byte offset 4*j0, so unaligned hits
        # are skipped. End bound (n-1)*4 keeps the match start strictly
        # before the suffix's own start.
        hb = h.tobytes()
        for ng in range(min(self.max_ngram, n - 1), self.min_ngram - 1, -1):
            pb = h[n - ng:].tobytes()
            j = hb.rfind(pb, 0, (n - 1) * 4)
            while j > 0 and j % 4:
                j = hb.rfind(pb, 0, j + len(pb) - 1)
            if j >= 0:
                j //= 4                             # most recent match
                return h[j + ng:j + ng + k].copy()
        return _EMPTY


def accept_drafts(logits: jax.Array, window_tokens: jax.Array,
                  draft_len: jax.Array, active: jax.Array,
                  req_keys: jax.Array, gen_base: jax.Array,
                  max_new: jax.Array, *, temperature: float, top_k: int,
                  top_p: float, vocab_limit: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Longest-valid-prefix acceptance by exact replay (module doc).

    logits (B, W, V) raw f32 from the verification forward over the
    window ``[pending, d_1..d_{draft_len}, pad...]``; row ``i-1`` is the
    target distribution for emission ``i``. ``gen_base`` (B,) is the
    pending token's generation index (-1 right after prefill, when the
    pending token is the last prompt token). Returns
    ``(toks, lps, n_emit, n_acc)``: emitted tokens/logps packed into
    (B, W) (col j = emission j+1, PAD/0 past ``n_emit``), the emitted
    count, and how many emissions were accepted drafts (telemetry).
    Emission stops at the first rejection + its replacement draw, at an
    emitted EOS, and at the per-request token budget; logps are the
    *target* model's (``model_logp`` on the raw row — the decode path's
    convention), never the drafter's.
    """
    b, w, v = logits.shape
    flat = logits.reshape(b * w, v)
    gidx = (gen_base[:, None] + 1 + jnp.arange(w)[None, :]).reshape(-1)
    keys = jax.vmap(jax.random.fold_in)(jnp.repeat(req_keys, w, axis=0),
                                        gidx)
    # the exact draw the sequential engine would make at each row —
    # same per-request counter-based stream, same filtered distribution
    that, _, _ = sample_token_rows(keys, mask_vocab(flat, vocab_limit),
                                   temperature=temperature, top_k=top_k,
                                   top_p=top_p)
    lp_hat = model_logp(flat, that).reshape(b, w)
    that = that.reshape(b, w)

    drafts = window_tokens[:, 1:]                       # (B, W-1)
    cols = jnp.arange(1, w)[None, :]
    match = (drafts == that[:, :-1]) & (cols <= draft_len[:, None])
    chain = jnp.cumprod(match.astype(jnp.int32), axis=1)  # accepted prefix
    n_acc_chain = chain.sum(axis=1)

    idx = jnp.arange(1, w + 1)[None, :]                 # emission index
    can = ((idx <= n_acc_chain[:, None] + 1)            # prefix + replay draw
           & (gen_base[:, None] + idx <= max_new[:, None] - 1)
           & active[:, None])
    eos = (that == EOS) & can
    eos_before = jnp.cumsum(eos.astype(jnp.int32), axis=1) \
        - eos.astype(jnp.int32)
    emit = can & (eos_before == 0)
    toks = jnp.where(emit, that, PAD)
    lps = jnp.where(emit, lp_hat, 0.0).astype(jnp.float32)
    n_emit = emit.astype(jnp.int32).sum(axis=1)
    n_acc = (chain.astype(bool) & emit[:, :-1]).sum(axis=1)
    return toks, lps, n_emit, n_acc


def stacked_pools(cfg: ModelConfig, pool) -> Tuple[jax.Array, jax.Array]:
    """Assemble the (L, pages, page, Hkv, D) stacked-pool layout
    ``paged_*_layers`` folds, from the engine pool's scanned-block
    layout (per-pattern-position ``layer_{i}`` leaves each stacked on
    the super-block axis). Layer order is block-major — exactly the
    order ``_run_blocks`` records its q/o tapes in."""
    period = len(cfg.block_pattern)
    kp = jnp.stack([pool[f"layer_{i}"]["self"]["kp"] for i in range(period)],
                   axis=1)
    vp = jnp.stack([pool[f"layer_{i}"]["self"]["vp"] for i in range(period)],
                   axis=1)
    return (kp.reshape((-1,) + kp.shape[2:]),
            vp.reshape((-1,) + vp.shape[2:]))


def fused_rescore_diff(cfg: ModelConfig, pool, q_tape: jax.Array,
                       o_tape: jax.Array, page_table: jax.Array,
                       positions: jax.Array) -> jax.Array:
    """Rescore every layer's window attention through ONE
    ``paged_prefill_layers`` launch per mask kind (one, for uniform
    patterns) and return max |fused − in-forward| — the fused-layer
    kernels' consumer on the verification path. Layers sharing a mask
    kind fold together; mixed ATTN/LOCAL patterns take one launch per
    kind, still O(kinds) ≪ L."""
    from repro.kernels.ops import paged_prefill_layers
    kp, vp = stacked_pools(cfg, pool)
    period = len(cfg.block_pattern)
    kinds = [cfg.block_pattern[i % period] for i in range(kp.shape[0])]
    diff = jnp.float32(0.0)
    for kind in dict.fromkeys(kinds):
        idx = jnp.asarray([i for i, k in enumerate(kinds) if k == kind],
                          jnp.int32)
        o = paged_prefill_layers(
            q_tape[idx], kp[idx], vp[idx], page_table, positions,
            kind=("local" if kind == LOCAL else "causal"),
            window=cfg.sliding_window, softcap=cfg.attn_softcap,
            impl=cfg.paged_attn_impl, attn_impl=cfg.attn_impl,
            chunk=cfg.attn_chunk)
        diff = jnp.maximum(diff, jnp.max(jnp.abs(o - o_tape[idx])))
    return diff


def verify_width_buckets(spec_k: int) -> int:
    """Distinct verification-window widths the engine can hand the
    jitted verify fn for a draft cap of ``spec_k``: widths are
    max(2, min(next_pow2(1 + k), spec_k + 1)) for k in 0..spec_k — the
    pow2 bucketing that keeps verify executables O(log spec_k). The
    floor of 2 keeps the window on the prefill-shaped (query-recording)
    attention path even when nothing was drafted."""
    widths = set()
    for k in range(spec_k + 1):
        w = 1
        while w < 1 + k:
            w *= 2
        widths.add(max(2, min(w, spec_k + 1)))
    return len(widths)


__all__ = ["DraftProposer", "NGramDrafter", "accept_drafts",
           "stacked_pools", "fused_rescore_diff", "verify_width_buckets"]
