"""Paged (block-table) KV cache for the continuous-batching engine.

The dense decode cache in ``models/model.py::init_cache`` allocates
``batch × max_len`` KV rows up front and ties a sequence to its row for
the whole generation. Here the sequence axis is instead carved into
fixed-size *pages* owned by a global pool:

- **page pools** — per attention layer, ``kp``/``vp`` of shape
  ``(num_blocks, num_pages, page_size, Hkv, head_dim)`` (stacked on the
  scanned super-block axis exactly like the dense cache, so the model's
  block scan is unchanged);
- **block table** — ``(num_slots, pages_per_slot)`` int32 mapping a decode
  slot's logical page to a physical page. Logical position ``p`` of slot
  ``s`` lives at ``pool[table[s, p // page_size], p % page_size]``;
- **allocator** — a host-side free list with a double-free guard. Page 0
  is reserved as a *scratch sink*: unassigned block-table entries point at
  it, so idle slots (and chunk padding) scatter harmlessly into garbage
  that is never causally visible.

When a sequence hits EOS its pages return to the pool immediately and the
slot can be re-admitted — the whole point of continuous batching.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.config import ATTN, LOCAL, ModelConfig

SCRATCH_PAGE = 0


def pages_for(total_len: int, page_size: int) -> int:
    """Pages needed to hold ``total_len`` tokens."""
    return -(-total_len // page_size)


class PageAllocator:
    """Refcounted free-list page allocator. Page 0 (scratch) is never
    handed out.

    ``alloc`` hands out pages at refcount 1; ``retain``/``release`` move
    the count up and down, and a page returns to the free list only when
    its count hits zero. This is what lets N requests share the KV pages
    of a common prompt prefix: each sharer (and the prefix cache itself)
    holds one reference, and the physical page outlives any individual
    request. ``free`` is the legacy single-owner spelling of ``release``
    — releasing a page that is not live still raises, preserving the old
    double-free guard.
    """

    def __init__(self, num_pages: int) -> None:
        if num_pages < 2:
            raise ValueError("need at least one scratch + one usable page")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._refs: Dict[int, int] = {}

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._refs)

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages at refcount 1, or None if the pool can't
        satisfy the request (the caller defers admission — or evicts
        prefix-cache entries — until pages free up)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for pg in pages:
            self._refs[pg] = 1
        return pages

    def retain(self, pages: List[int]) -> None:
        """Add one reference to each live page (shared-prefix admission)."""
        for pg in pages:
            if pg not in self._refs:
                raise ValueError(f"retain of dead / foreign page {pg}")
            self._refs[pg] += 1

    def release(self, pages: List[int]) -> List[int]:
        """Drop one reference per page; pages whose count hits zero go
        back on the free list (returned, in order)."""
        freed: List[int] = []
        for pg in pages:
            if pg not in self._refs:
                raise ValueError(f"double free / foreign page {pg}")
            self._refs[pg] -= 1
            if self._refs[pg] == 0:
                del self._refs[pg]
                self._free.append(pg)
                freed.append(pg)
        return freed

    # legacy single-owner alias (pre-refcount callers and tests)
    free = release


def init_paged_pool(cfg: ModelConfig, num_pages: int, page_size: int, *,
                    dtype: Optional[str] = None) -> Dict:
    """Page-pool pytree matching the model's per-block cache structure.

    Only attention-family layers are supported — SSM/cross-attention
    state is per-slot constant-size and doesn't page; the engine falls
    back to the static path for those architectures.
    """
    dt = jnp.dtype(dtype or cfg.dtype)
    nb = cfg.num_blocks
    pool: Dict[str, Dict] = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind not in (ATTN, LOCAL):
            raise ValueError(
                f"paged cache supports attention layers only, got {kind!r}")
        shape = (nb, num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
        pool[f"layer_{i}"] = {"self": {"kp": jnp.zeros(shape, dt),
                                       "vp": jnp.zeros(shape, dt)}}
    return pool


def paged_cache_supported(cfg: ModelConfig) -> bool:
    """True when the continuous engine's paged cache can serve ``cfg``."""
    return (all(k in (ATTN, LOCAL) for k in cfg.block_pattern)
            and not cfg.is_encdec
            and not cfg.local_ring_kv
            and cfg.memory_seq == 0)


def new_block_table(num_slots: int, pages_per_slot: int) -> np.ndarray:
    """Host-side block table, all entries parked on the scratch page."""
    return np.full((num_slots, pages_per_slot), SCRATCH_PAGE, np.int32)
