"""Request scheduler for the continuous-batching engine.

Lifecycle: QUEUED → PREFILL → DECODE → DONE. A fixed pool of decode
slots is recycled: admission binds a queued request to a free slot and
allocates its KV pages; finishing (EOS / token budget) releases both
immediately so the next queued prompt takes over mid-batch — no slot ever
pads out a ``lax.scan`` to the global ``max_new``.

Serving extensions (the SLO front door in ``repro.serving`` drives all
of them):

- **priority classes** — one FIFO per integer priority (0 = most
  urgent); admission always drains the most urgent non-empty class
  first, head-of-line within a class (a large head request blocks its
  class until pages free up, which prevents starvation by later small
  requests);
- **deadlines** — a queued request whose absolute ``deadline_s`` has
  passed is expired at admission time (state DONE, reason "expired")
  instead of wasting pages; requests are *never* dropped after
  admission, because their full KV page budget is reserved up front;
- **shared-prefix reuse** — with a :class:`~repro.sampling.prefix_cache.
  PrefixCache` attached, admission looks up the longest cached prefix of
  the prompt, retains its full pages in place, and only allocates the
  remainder (plus one copy-on-write page when the prefix ends mid-page —
  the engine performs the device-side copy). Pool pressure evicts LRU
  cache entries before deferring admission.

The scheduler is pure host-side bookkeeping (numpy block table, python
queues); all device work stays in the engine's jitted step functions.
Per-request engine log-probs are kept as *metadata* for the learner's
recompute path (App. B.1), mirroring the static engine's contract.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.sampling.paged_cache import (PageAllocator, SCRATCH_PAGE,
                                        new_block_table, pages_for)
from repro.sampling.prefix_cache import PrefixCache

QUEUED, PREFILL, DECODE, DONE = "queued", "prefill", "decode", "done"


@dataclasses.dataclass
class GenRequest:
    """One generation request moving through the slot pool."""
    rid: int                      # row id; also the RNG fold_in stream
    prompt: np.ndarray            # (Tp,) int32 true prompt tokens
    max_new: int
    priority: int = 1             # 0 = most urgent
    deadline_s: Optional[float] = None   # absolute clock deadline (TTFT SLO)
    arrival_s: float = 0.0
    state: str = QUEUED
    slot: int = -1
    pages: List[int] = dataclasses.field(default_factory=list)
    prefill_pos: int = 0          # prompt tokens already prefilled
    prefix_hit_tokens: int = 0    # tokens served from the prefix cache
    cow_src: int = -1             # cached page to copy-on-write from ...
    cow_dst: int = -1             # ... into this freshly allocated page
    tokens: List[int] = dataclasses.field(default_factory=list)
    logps: List[float] = dataclasses.field(default_factory=list)
    spec_ok: bool = True          # request opts in to speculative decode
    finish_reason: str = ""       # "eos" | "length" | "expired"
    t_first_token: float = -1.0   # host clock at first decoded token
    t_done: float = -1.0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def gen_count(self) -> int:
        return len(self.tokens)

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new

    @property
    def next_pos(self) -> int:
        """Next KV write position (prompt length + generated so far)."""
        return self.prompt_len + self.gen_count


class ContinuousScheduler:
    """Admission + slot/page recycling over a fixed slot pool."""

    def __init__(self, num_slots: int, pages_per_slot: int, page_size: int,
                 allocator: PageAllocator,
                 prefix_cache: Optional[PrefixCache] = None) -> None:
        self.num_slots = num_slots
        self.pages_per_slot = pages_per_slot
        self.page_size = page_size
        self.allocator = allocator
        self.prefix_cache = prefix_cache
        self.block_table = new_block_table(num_slots, pages_per_slot)
        self.slots: List[Optional[GenRequest]] = [None] * num_slots
        self.queues: Dict[int, Deque[GenRequest]] = {}
        self.finished: List[GenRequest] = []
        self._expired: List[GenRequest] = []
        self.stats: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "completed": 0, "expired": 0,
            "max_active": 0, "decode_steps": 0, "decode_slot_steps": 0,
            "prefill_chunks": 0, "prefill_tokens": 0,
            "prefix_hits": 0, "prefix_tokens_reused": 0, "cow_copies": 0,
            # speculative decode (zero when spec_k == 0)
            "spec_rounds": 0, "spec_slot_rounds": 0,
            "drafted_tokens_total": 0, "accepted_tokens_total": 0,
            "draft_hits": 0, "spec_fallback_chunks": 0,
        }

    # ------------------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def submit(self, req: GenRequest) -> None:
        assert req.state == QUEUED
        self.stats["submitted"] += 1
        self.queues.setdefault(req.priority, deque()).append(req)

    def _expire(self, req: GenRequest, now_s: float) -> None:
        req.state, req.finish_reason = DONE, "expired"
        req.t_done = now_s
        self.finished.append(req)
        self._expired.append(req)
        self.stats["expired"] += 1

    def _head(self, now_s: float) -> Optional[Deque[GenRequest]]:
        """Queue holding the most urgent admissible head request;
        expired heads are retired on the way."""
        for pr in sorted(self.queues):
            q = self.queues[pr]
            while q:
                req = q[0]
                if req.deadline_s is not None and now_s > req.deadline_s:
                    q.popleft()
                    self._expire(req, now_s)
                    continue
                return q
        return None

    def drain_expired(self) -> List[GenRequest]:
        """Requests expired since the last drain (the engine emits their
        terminal events)."""
        out, self._expired = self._expired, []
        return out

    def admit(self, now_s: float = 0.0) -> List[GenRequest]:
        """Bind queued requests to free slots while pages last — most
        urgent priority class first, FIFO within a class. A request's
        *entire* KV budget (``pages_for(total_len)`` minus shared prefix
        pages) is reserved here, so admitted requests can never be
        dropped mid-decode. Returns the newly admitted requests (state
        PREFILL)."""
        newly: List[GenRequest] = []
        for s in range(self.num_slots):
            if self.slots[s] is not None:
                continue
            q = self._head(now_s)
            if q is None:
                break
            req = q[0]
            need = pages_for(req.total_len, self.page_size)
            if need > self.pages_per_slot:
                raise ValueError(
                    f"request {req.rid}: {req.total_len} tokens need {need} "
                    f"pages > pages_per_slot={self.pages_per_slot}")
            m, shared, cow_src = 0, [], -1
            if self.prefix_cache is not None:
                m, shared, cow_src = self.prefix_cache.lookup(req.prompt)
            if shared:                    # pin before allocating the rest
                self.allocator.retain(shared)
            need_new = need - len(shared)
            pages = self.allocator.alloc(need_new)
            if pages is None and self.prefix_cache is not None:
                # pool pressure: drop cache-only references, retry
                self.prefix_cache.evict_until(need_new)
                pages = self.allocator.alloc(need_new)
            if pages is None:             # pool exhausted — wait for frees
                if shared:
                    self.allocator.release(shared)
                break
            q.popleft()
            req.state, req.slot = PREFILL, s
            req.pages = shared + pages
            req.prefill_pos = req.prefix_hit_tokens = m
            if cow_src >= 0:              # engine copies src -> dst on device
                req.cow_src, req.cow_dst = cow_src, pages[0]
            self.block_table[s, :need] = req.pages
            self.block_table[s, need:] = SCRATCH_PAGE
            self.slots[s] = req
            newly.append(req)
            self.stats["admitted"] += 1
            if m:
                self.stats["prefix_hits"] += 1
                self.stats["prefix_tokens_reused"] += m
        self.stats["max_active"] = max(self.stats["max_active"],
                                       sum(r is not None for r in self.slots))
        return newly

    def finish(self, req: GenRequest, reason: str,
               now_s: float = 0.0) -> None:
        """Release the request's references on its slot and pages; pages
        still shared (prefix cache / other requests) survive."""
        assert req.state in (PREFILL, DECODE)
        self.allocator.release(req.pages)
        req.pages = []
        self.block_table[req.slot] = SCRATCH_PAGE
        self.slots[req.slot] = None
        req.state, req.finish_reason = DONE, reason
        req.t_done = now_s
        self.finished.append(req)
        self.stats["completed"] += 1

    # ------------------------------------------------------------------
    def next_prefill(self) -> Optional[GenRequest]:
        for r in self.slots:
            if r is not None and r.state == PREFILL:
                return r
        return None

    def decoding(self) -> List[GenRequest]:
        return [r for r in self.slots if r is not None and r.state == DECODE]

    @property
    def all_done(self) -> bool:
        return self.queue_depth == 0 and all(r is None for r in self.slots)

    def slot_utilization(self) -> float:
        """Fraction of decode-step slot positions that carried a live
        request — the headline efficiency number for serving."""
        steps = self.stats["decode_steps"]
        if steps == 0:
            return 0.0
        return self.stats["decode_slot_steps"] / (steps * self.num_slots)
