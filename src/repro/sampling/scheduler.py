"""Request scheduler for the continuous-batching engine.

Lifecycle: QUEUED → PREFILL → DECODE → DONE. A fixed pool of decode
slots is recycled: admission binds a queued request to a free slot and
allocates its KV pages; finishing (EOS / token budget) frees both
immediately so the next queued prompt takes over mid-batch — no slot ever
pads out a ``lax.scan`` to the global ``max_new``.

The scheduler is pure host-side bookkeeping (numpy block table, python
queue); all device work stays in ``engine.py``'s jitted step functions.
Per-request engine log-probs are kept as *metadata* for the learner's
recompute path (App. B.1), mirroring the static engine's contract.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.sampling.paged_cache import (PageAllocator, SCRATCH_PAGE,
                                        new_block_table, pages_for)

QUEUED, PREFILL, DECODE, DONE = "queued", "prefill", "decode", "done"


@dataclasses.dataclass
class GenRequest:
    """One generation request moving through the slot pool."""
    rid: int                      # row id; also the RNG fold_in stream
    prompt: np.ndarray            # (Tp,) int32 true prompt tokens
    max_new: int
    state: str = QUEUED
    slot: int = -1
    pages: List[int] = dataclasses.field(default_factory=list)
    prefill_pos: int = 0          # prompt tokens already prefilled
    tokens: List[int] = dataclasses.field(default_factory=list)
    logps: List[float] = dataclasses.field(default_factory=list)
    finish_reason: str = ""       # "eos" | "length"

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def gen_count(self) -> int:
        return len(self.tokens)

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new

    @property
    def next_pos(self) -> int:
        """Next KV write position (prompt length + generated so far)."""
        return self.prompt_len + self.gen_count


class ContinuousScheduler:
    """Admission + slot/page recycling over a fixed slot pool."""

    def __init__(self, num_slots: int, pages_per_slot: int, page_size: int,
                 allocator: PageAllocator) -> None:
        self.num_slots = num_slots
        self.pages_per_slot = pages_per_slot
        self.page_size = page_size
        self.allocator = allocator
        self.block_table = new_block_table(num_slots, pages_per_slot)
        self.slots: List[Optional[GenRequest]] = [None] * num_slots
        self.queue: Deque[GenRequest] = deque()
        self.finished: List[GenRequest] = []
        self.stats: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "completed": 0,
            "max_active": 0, "decode_steps": 0, "decode_slot_steps": 0,
            "prefill_chunks": 0,
        }

    # ------------------------------------------------------------------
    def submit(self, req: GenRequest) -> None:
        assert req.state == QUEUED
        self.stats["submitted"] += 1
        self.queue.append(req)

    def admit(self) -> List[GenRequest]:
        """FIFO admission: bind queued requests to free slots while pages
        last. Returns the newly admitted requests (state PREFILL)."""
        newly: List[GenRequest] = []
        for s in range(self.num_slots):
            if not self.queue:
                break
            if self.slots[s] is not None:
                continue
            req = self.queue[0]
            need = pages_for(req.total_len, self.page_size)
            if need > self.pages_per_slot:
                raise ValueError(
                    f"request {req.rid}: {req.total_len} tokens need {need} "
                    f"pages > pages_per_slot={self.pages_per_slot}")
            pages = self.allocator.alloc(need)
            if pages is None:             # pool exhausted — wait for frees
                break
            self.queue.popleft()
            req.state, req.slot, req.pages = PREFILL, s, pages
            self.block_table[s, :need] = pages
            self.block_table[s, need:] = SCRATCH_PAGE
            self.slots[s] = req
            newly.append(req)
            self.stats["admitted"] += 1
        self.stats["max_active"] = max(self.stats["max_active"],
                                       sum(r is not None for r in self.slots))
        return newly

    def finish(self, req: GenRequest, reason: str) -> None:
        """Release the request's slot and pages back to the pool."""
        assert req.state in (PREFILL, DECODE)
        self.allocator.free(req.pages)
        req.pages = []
        self.block_table[req.slot] = SCRATCH_PAGE
        self.slots[req.slot] = None
        req.state, req.finish_reason = DONE, reason
        self.finished.append(req)
        self.stats["completed"] += 1

    # ------------------------------------------------------------------
    def next_prefill(self) -> Optional[GenRequest]:
        for r in self.slots:
            if r is not None and r.state == PREFILL:
                return r
        return None

    def decoding(self) -> List[GenRequest]:
        return [r for r in self.slots if r is not None and r.state == DECODE]

    @property
    def all_done(self) -> bool:
        return not self.queue and all(r is None for r in self.slots)

    def slot_utilization(self) -> float:
        """Fraction of decode-step slot positions that carried a live
        request — the headline efficiency number for serving."""
        steps = self.stats["decode_steps"]
        if steps == 0:
            return 0.0
        return self.stats["decode_slot_steps"] / (steps * self.num_slots)
