from repro.sampling.engine import generate, token_logps
from repro.sampling.sample import filter_logits, sample_token

__all__ = ["generate", "token_logps", "filter_logits", "sample_token"]
