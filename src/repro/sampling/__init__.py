from repro.sampling.continuous import (ContinuousEngine, generate_continuous,
                                       rollout_from_results)
from repro.sampling.engine import (StaticEngine, build_engine, generate,
                                   token_logps)
from repro.sampling.paged_cache import (PageAllocator, init_paged_pool,
                                        paged_cache_supported, pages_for)
from repro.sampling.prefix_cache import PrefixCache
from repro.sampling.sample import filter_logits, sample_token, sample_token_rows
from repro.sampling.scheduler import ContinuousScheduler, GenRequest
from repro.sampling.spec import DraftProposer, NGramDrafter

__all__ = ["generate", "generate_continuous", "token_logps", "filter_logits",
           "sample_token", "sample_token_rows", "PageAllocator",
           "init_paged_pool", "paged_cache_supported", "pages_for",
           "ContinuousScheduler", "GenRequest", "ContinuousEngine",
           "StaticEngine", "build_engine", "rollout_from_results",
           "PrefixCache", "DraftProposer", "NGramDrafter"]
