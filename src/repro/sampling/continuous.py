"""Continuous-batching engine: persistent slot pool over a paged KV cache.

``ContinuousEngine`` is the request-level engine behind both the batch
``generate_continuous`` wrapper (one call = submit a batch, drain it)
and the asyncio serving front door (``repro.serving.server``), which
keeps one engine alive across an open-ended request stream:

- ``submit()`` queues a :class:`repro.serving.api.Request`;
- ``step()`` runs one scheduler round — admission (priority classes,
  deadlines, shared-prefix page reuse with copy-on-write), one prefill
  chunk per prefilling slot, one jitted decode chunk over every slot —
  and returns the :class:`~repro.serving.api.TokenEvent` stream that
  round produced;
- ``generate()`` is the batch convenience: submit, step until drained,
  return per-request results.

The engine owns the device state (page pools, per-slot logits, RNG
streams); the scheduler owns the host bookkeeping (block table,
allocator, prefix cache). Tokens and log-probs are bit-identical to the
static engine for the same key because RNG folds per request id, never
per slot — and bit-identical with or without prefix reuse because
cached pages hold exactly the K/V a cold prefill would write.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.config import ModelConfig, RLConfig
from repro.data.tasks import EOS, PAD
from repro.models import decode_step, forward
from repro.sampling.paged_cache import (PageAllocator, SCRATCH_PAGE,
                                        init_paged_pool,
                                        paged_cache_supported, pages_for)
from repro.sampling.prefix_cache import PrefixCache
from repro.sampling.sample import mask_vocab, model_logp, sample_token_rows
from repro.sampling.scheduler import (DECODE, PREFILL, ContinuousScheduler,
                                      GenRequest)
from repro.sampling.spec import (DraftProposer, NGramDrafter, accept_drafts,
                                 fused_rescore_diff)
from repro.serving.api import (GenerationResult, Request, SamplingParams,
                               TokenEvent)


@functools.partial(jax.jit, static_argnames=("cfg", "plan"),
                   donate_argnums=(2,))
def _prefill_chunk_jit(cfg: ModelConfig, params, pool, page_row, tokens,
                       start, plan=None):
    """One chunk of one request's prompt: tokens (1, C) at positions
    ``start + [0, C)``, K/V scattered into the request's pages. Returns
    (logits (C, V), pool)."""
    if plan is not None:
        params = plan.constrain_params(cfg, params)
        pool = plan.constrain_cache(cfg, pool)
    c = tokens.shape[1]
    positions = start + jnp.arange(c, dtype=jnp.int32)[None, :]
    logits, pool, _ = forward(cfg, params, tokens, positions=positions,
                              cache=pool, page_table=page_row)
    return logits[0], pool


@functools.partial(jax.jit, static_argnames=("cfg", "plan"),
                   donate_argnums=(2,))
def _copy_page_jit(cfg: ModelConfig, plan, pool, src, dst):
    """Copy physical page ``src`` onto ``dst`` across every layer's K/V
    pools — the copy-on-write step of shared-prefix admission (the new
    request appends into its private copy of a cached partial tail
    page)."""
    if plan is not None:
        pool = plan.constrain_cache(cfg, pool)

    def cp(leaf):                       # (nb, pages, page, Hkv, D)
        return leaf.at[:, dst].set(leaf[:, src])

    return jax.tree_util.tree_map(cp, pool)


@functools.partial(jax.jit, static_argnames=("cfg", "rl", "vocab_limit",
                                             "sync_every", "plan"),
                   donate_argnums=(3,))
def _decode_chunk_jit(cfg: ModelConfig, rl: RLConfig, params, pool,
                      page_table, last, pos, active, req_keys, gen0,
                      max_new_v, vocab_limit: int, sync_every: int,
                      plan=None):
    """``sync_every`` decode steps over every slot in one executable — the
    decode horizon that amortizes host dispatch; the scheduler regains
    control (EOS recycling, admission) only between chunks.

    Slots that finish mid-chunk (EOS / token budget) keep decoding PAD
    at a position past the block-table width, so their K/V writes hit
    the OOB-drop path instead of any physical page — with shared-prefix
    reuse a slot's own first page may be referenced by other requests,
    so a dead slot must write *nowhere*, not "harmlessly at position 0"
    as the pre-refcount engine did. Draw ``i`` of slot ``s`` uses
    fold_in(req_keys[s], gen0[s]+i): the host discards post-EOS draws,
    and earlier draws are bit-identical to the static engine's.
    """
    if plan is not None:
        params = plan.constrain_params(cfg, params)
        pool = plan.constrain_cache(cfg, pool)
    page_size = jax.tree_util.tree_leaves(pool)[0].shape[2]
    oob_pos = jnp.int32(page_table.shape[1] * page_size)

    def step(carry, i):
        pool, last, done = carry
        over = (gen0 + i) >= max_new_v              # token budget exhausted
        dead = done | over
        lg = mask_vocab(last, vocab_limit)
        kt = jax.vmap(jax.random.fold_in)(req_keys, gen0 + i)
        tok, _, _ = sample_token_rows(kt, lg, temperature=rl.temperature,
                                      top_k=rl.top_k, top_p=rl.top_p)
        lp = jnp.where(dead, 0.0, model_logp(last, tok))
        tok = jnp.where(dead, PAD, tok)
        step_pos = jnp.where(dead, oob_pos, pos + i)
        new_last, pool = decode_step(cfg, params, pool, tok, step_pos,
                                     page_table=page_table)
        done = done | (tok == EOS)
        return (pool, new_last, done), (tok, lp)

    (pool, last, _), (toks, lps) = jax.lax.scan(
        step, (pool, last, ~active), jnp.arange(sync_every))
    return toks, lps, last, pool                    # toks (K, num_slots)


@functools.partial(jax.jit, static_argnames=("cfg", "rl", "vocab_limit",
                                             "fused", "plan"),
                   donate_argnums=(3,))
def _verify_chunk_jit(cfg: ModelConfig, rl: RLConfig, params, pool,
                      page_table, packed, req_keys, max_new_v,
                      vocab_limit: int, fused: bool, plan=None):
    """One speculative round over every slot in one executable: score the
    per-slot window ``[pending, d_1..d_k, pad]`` in ONE prefill-shaped
    target forward through the ``paged_prefill`` dispatcher (positions
    are each slot's contiguous ``pos0 + [0, W)``), then accept the
    longest draft prefix whose tokens match the engine's replayed draws
    (``repro.sampling.spec.accept_drafts`` — distribution preserved
    exactly, greedy bit-identical to the non-speculative path).

    ``packed`` (B, W+4) int32 carries everything that changes per round
    in ONE host->device transfer — columns ``[window(W), draft_len,
    gen_base, pos0, active]`` — because this dispatch sits on the decode
    critical path and a handful of small device_puts per round was
    measurably the dominant cost. ``req_keys``/``max_new_v`` change only
    at admission and ride a cached device array.

    Every window column scatters K/V at its contiguous position —
    rejected/padded columns land on the slot's own reserved-but-unread
    page slots and are overwritten before any later query can attend
    them (the append-only rollback: rewinding positions, no page
    copies). Inactive slots run at positions ``[0, W)`` (pos0 = 0)
    against the scratch page, the prefill-shaped twin of the decode
    chunk's dead slots. With ``fused`` the forward also records
    per-layer queries and attention outputs, and the acceptance rescore
    replays all layers through one ``paged_prefill_layers`` launch — the
    fused-layer kernels' consumer — returning max |fused − in-forward|
    as a bit-exactness gauge.

    Returns (iout (B, W+2) int32 = [toks(W), n_emit, n_acc],
    fout (B, W+1) f32 = [lps(W), rescore_diff], pool) — two packed
    device->host transfers on the result side for the same reason.
    """
    if plan is not None:
        params = plan.constrain_params(cfg, params)
        pool = plan.constrain_cache(cfg, pool)
    b = packed.shape[0]
    w = packed.shape[1] - 4
    window_tokens = packed[:, :w]
    draft_len, gen_base, pos0 = packed[:, w], packed[:, w + 1], \
        packed[:, w + 2]
    active = packed[:, w + 3].astype(bool)
    positions = pos0[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    logits, pool, aux = forward(cfg, params, window_tokens,
                                positions=positions, cache=pool,
                                page_table=page_table,
                                record_queries=fused)
    toks, lps, n_emit, n_acc = accept_drafts(
        logits, window_tokens, draft_len, active, req_keys, gen_base,
        max_new_v, temperature=rl.temperature, top_k=rl.top_k,
        top_p=rl.top_p, vocab_limit=vocab_limit)
    diff = jnp.float32(0.0)
    if fused:
        diff = fused_rescore_diff(cfg, pool, aux["q_tape"], aux["o_tape"],
                                  page_table, positions)
    iout = jnp.concatenate([toks, n_emit[:, None], n_acc[:, None]], axis=1)
    fout = jnp.concatenate([lps, jnp.full((b, 1), diff, jnp.float32)],
                           axis=1)
    return iout, fout, pool


@functools.partial(jax.jit, static_argnames=("cfg", "rl", "vocab_limit",
                                             "sync_every", "plan"),
                   donate_argnums=(3,))
def _spec_decode_chunk_jit(cfg: ModelConfig, rl: RLConfig, params, pool,
                           page_table, pending, pos0, active, req_keys,
                           gen_base, max_new_v, vocab_limit: int,
                           sync_every: int, plan=None):
    """Sequential decode chunk in the *pending-token* state convention —
    the spec engine's fallback when no slot drafted anything this round
    (cold history, or acceptance-gated drafting backed off on an
    incompressible stream). The decode-chunk twin of ``_decode_chunk_jit``
    shifted by one: each step scatters the carried token's K/V and draws
    the next from the resulting logits, so no ``last``-logits state is
    needed and the final draw is left pending for the next round. Draw
    ``i`` uses ``fold_in(req_keys, gen_base + 1 + i)`` — the same
    per-request counter stream as verification, so tokens stay
    bit-identical whichever path emits them.
    """
    if plan is not None:
        params = plan.constrain_params(cfg, params)
        pool = plan.constrain_cache(cfg, pool)
    page_size = jax.tree_util.tree_leaves(pool)[0].shape[2]
    oob_pos = jnp.int32(page_table.shape[1] * page_size)

    def step(carry, i):
        pool, tok, done = carry
        gi = gen_base + 1 + i                    # gen index of this draw
        dead = done | (gi >= max_new_v)
        step_pos = jnp.where(dead, oob_pos, pos0 + i)
        logits, pool = decode_step(cfg, params, pool, tok, step_pos,
                                   page_table=page_table)
        kt = jax.vmap(jax.random.fold_in)(req_keys, gi)
        nt, _, _ = sample_token_rows(kt, mask_vocab(logits, vocab_limit),
                                     temperature=rl.temperature,
                                     top_k=rl.top_k, top_p=rl.top_p)
        lp = jnp.where(dead, 0.0, model_logp(logits, nt))
        nt = jnp.where(dead, PAD, nt)
        done = done | (nt == EOS)
        return (pool, nt, done), (nt, lp)

    (pool, _, _), (toks, lps) = jax.lax.scan(
        step, (pool, pending, ~active), jnp.arange(sync_every))
    return toks, lps, pool                       # toks (K, num_slots)


# acceptance-EMA drafting gate: below _SPEC_EMA_MIN the drafter has
# demonstrably nothing to offer this request (incompressible stream) and
# proposing more drafts only pays verification width for nothing; a
# backed-off request re-probes every _SPEC_PROBE_EVERY rounds in case
# the stream turns templated (e.g. the model falls into a cycle)
_SPEC_EMA_MIN = 0.25
_SPEC_EMA_DECAY = 0.5
_SPEC_PROBE_EVERY = 4


def _live_width(need_pages: int, cap: int) -> int:
    """Block-table width actually handed to the jitted chunk fns: the
    live-page high-water mark rounded up to a power of two (so widths
    bucket into O(log) executables), capped at ``pages_per_slot``.

    Narrowing is *bit-exact*: every page dropped is provably masked in
    attention (positions >= every slot's length), and masked entries
    contribute exact zeros to the softmax — so even the default gather
    impl stops materializing (and the kernel stops iterating) the dead
    tail of the pool."""
    w = 1
    while w < need_pages:
        w *= 2
    return min(w, cap)


def clamp_prefill_chunk(prefill_chunk: Optional[int],
                        limit: int) -> Optional[int]:
    """Clamp a configured prefill chunk width to ``limit`` tokens.

    None/0 ("prefill everything in one chunk") stays None; a configured
    width never exceeds what there is to prefill. The single definition
    of a fallback that ``ContinuousEngine.step`` (per-request remaining
    tokens) and ``generate_continuous`` (prompt width) used to each
    encode on their own.
    """
    if not prefill_chunk:
        return None
    return min(prefill_chunk, limit)


class ContinuousEngine:
    """Persistent continuous-batching engine over one model + page pool.

    One engine serves one sampling *profile* (temperature/top-k/top-p —
    the jit-static triple; ``max_new_tokens`` is per-request) and one
    page-pool capacity. Capacity knobs come from ``ServeConfig`` via
    ``repro.sampling.build_engine``; this constructor takes them raw.
    """

    def __init__(self, cfg: ModelConfig, params, *, rl: RLConfig,
                 max_total_tokens: int,
                 num_slots: int = 8,
                 page_size: int = 16,
                 sync_every: int = 8,
                 prefill_chunk: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 vocab_limit: Optional[int] = None,
                 plan=None,
                 prefix_cache: bool = True,
                 prefix_cache_entries: int = 64,
                 spec_k: int = 0,
                 drafter: Optional[DraftProposer] = None,
                 spec_ngram_max: int = 3,
                 spec_ngram_min: int = 1,
                 spec_rescore: bool = True,
                 key: Optional[jax.Array] = None) -> None:
        if not paged_cache_supported(cfg):
            raise ValueError(f"{cfg.name}: continuous engine needs an "
                             "attention-only decode cache (no enc-dec / "
                             "ring-KV / modality memory)")
        self.cfg, self.rl, self.params, self.plan = cfg, rl, params, plan
        self.vocab_limit = vocab_limit or cfg.padded_vocab
        self.num_slots = num_slots
        self.page_size = page_size
        self.sync_every = sync_every
        self.prefill_chunk = prefill_chunk
        self.max_total_tokens = max_total_tokens
        self.pages_per_slot = pages_for(max_total_tokens, page_size)
        self.num_pages = num_pages or 1 + num_slots * self.pages_per_slot
        if self.num_pages < 1 + self.pages_per_slot:
            raise ValueError(
                f"num_pages={self.num_pages} cannot hold even one "
                f"max-size request ({self.pages_per_slot} pages + scratch)")
        allocator = PageAllocator(self.num_pages)
        self.prefix_cache = (PrefixCache(page_size, allocator,
                                         max_entries=prefix_cache_entries)
                             if prefix_cache else None)
        self.sched = ContinuousScheduler(num_slots, self.pages_per_slot,
                                         page_size, allocator,
                                         prefix_cache=self.prefix_cache)
        self.pool = init_paged_pool(cfg, self.num_pages, page_size)
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        self.spec_k = spec_k
        self.spec_rescore = spec_rescore
        self.drafter: Optional[DraftProposer] = drafter
        if spec_k > 0 and self.drafter is None:
            self.drafter = NGramDrafter(max_ngram=spec_ngram_max,
                                        min_ngram=spec_ngram_min)
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self._last = jnp.zeros((num_slots, cfg.padded_vocab), jnp.float32)
        self._pos = np.zeros((num_slots,), np.int32)
        self._active = np.zeros((num_slots,), bool)
        self._gen = np.zeros((num_slots,), np.int32)
        self._max_new = np.ones((num_slots,), np.int32)
        self._req_keys = np.zeros((num_slots, 2), np.uint32)  # threefry data
        self._results: Dict[int, GenerationResult] = {}
        # unified observability (repro.obs): handles bound once — each
        # use is one enabled-check when the registry is off (the
        # zero-cost contract obs_bench enforces on this hot path)
        m = obs.metrics
        self._tr = obs.trace
        self._m_prefill_chunks = m.counter(
            "engine_prefill_chunks_total", "prefill chunks executed")
        self._m_prefill_tokens = m.counter(
            "engine_prefill_tokens_total", "prompt tokens prefilled")
        self._m_decode_steps = m.counter(
            "engine_decode_steps_total", "decode steps executed")
        self._m_cow = m.counter(
            "engine_cow_copies_total", "shared-prefix copy-on-write copies")
        self._g_free_pages = m.gauge(
            "engine_free_pages", "KV pages on the free list")
        self._g_queue = m.gauge(
            "engine_queue_depth", "requests queued behind admission")
        self._g_slot_util = m.gauge(
            "engine_slot_utilization", "decode-slot occupancy (instant)")
        self._g_prefix_hits = m.gauge(
            "engine_prefix_cache_hits", "shared-prefix cache hits")
        self._g_prefix_reused = m.gauge(
            "engine_prefix_tokens_reused",
            "prompt tokens served from cached prefix pages")
        self._m_spec_rounds = m.counter(
            "engine_spec_rounds_total", "speculative verification rounds")
        self._m_spec_drafted = m.counter(
            "engine_spec_drafted_total", "draft tokens proposed")
        self._m_spec_accepted = m.counter(
            "engine_spec_accepted_total", "draft tokens accepted")
        self._g_accept_rate = m.gauge(
            "engine_spec_accept_rate",
            "accepted / drafted tokens (cumulative)")
        self._g_draft_hit = m.gauge(
            "engine_spec_draft_hit_rate",
            "slot-rounds where the drafter proposed anything (cumulative)")
        self._g_rescore_diff = m.gauge(
            "engine_spec_rescore_max_diff",
            "max |fused-layers rescore - in-forward attention| last round")
        self._rescore_max_diff = 0.0
        # per-request acceptance EMA ([ema, rounds_since_draft]) — gates
        # drafting off on incompressible streams (periodic re-probe)
        self._spec_ema: Dict[int, List[float]] = {}
        # host-compare cache of small device-resident dispatch args
        self._dev_cache: Dict[str, tuple] = {}

    # ------------------------------------------------------------------
    @property
    def profile(self) -> tuple:
        return (self.rl.temperature, self.rl.top_k, self.rl.top_p)

    @property
    def free_pages(self) -> int:
        return self.sched.allocator.available

    @property
    def evictable_pages(self) -> int:
        """Pages a prefix-cache flush could return to the free list."""
        if self.prefix_cache is None:
            return 0
        alloc = self.sched.allocator
        return sum(1 for ent in self.prefix_cache._entries.values()
                   for pg in ent.pages if alloc.refcount(pg) == 1)

    def has_work(self) -> bool:
        return (self.sched.queue_depth > 0
                or any(r is not None for r in self.sched.slots))

    def update_params(self, params: Any) -> None:
        self.params = params

    def stats(self) -> Dict[str, float]:
        out: Dict[str, float] = dict(self.sched.stats)
        out["slot_utilization"] = self.sched.slot_utilization()
        out["free_pages"] = self.free_pages
        # speculative-decode surface (flows to /metrics via stats())
        out["accept_rate"] = (out["accepted_tokens_total"]
                              / max(out["drafted_tokens_total"], 1))
        out["draft_hit_rate"] = (out["draft_hits"]
                                 / max(out["spec_slot_rounds"], 1))
        out["spec_rescore_max_diff"] = self._rescore_max_diff
        if self.prefix_cache is not None:
            for k, v in self.prefix_cache.stats.items():
                out[f"prefix_cache_{k}"] = v
        return out

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request. Raises on profile mismatch (one sampling
        profile per engine — spin up another engine for another
        profile) and on prompts that can never fit the page budget."""
        if req.params.profile != self.profile:
            raise ValueError(
                f"request {req.rid}: sampling profile {req.params.profile} "
                f"!= engine profile {self.profile} — one profile per "
                "engine (max_new_tokens may vary per request)")
        total = req.prompt_len + req.params.max_new_tokens
        if pages_for(total, self.page_size) > self.pages_per_slot:
            raise ValueError(
                f"request {req.rid}: {total} tokens exceed the engine's "
                f"max_total_tokens={self.max_total_tokens}")
        self.sched.submit(GenRequest(
            rid=req.rid, prompt=req.prompt,
            max_new=req.params.max_new_tokens, priority=req.priority,
            deadline_s=req.deadline_s, arrival_s=req.arrival_s,
            spec_ok=req.params.spec))

    def _finish_result(self, r: GenRequest) -> GenerationResult:
        res = GenerationResult(
            rid=r.rid, tokens=np.asarray(r.tokens, np.int32),
            logps=np.asarray(r.logps, np.float32),
            finish_reason=r.finish_reason, prompt_len=r.prompt_len,
            prefix_hit_tokens=r.prefix_hit_tokens,
            ttft_s=(r.t_first_token - r.arrival_s
                    if r.t_first_token >= 0 else float("nan")),
            latency_s=r.t_done - r.arrival_s)
        self._results[r.rid] = res
        return res

    def pop_result(self, rid: int) -> Optional[GenerationResult]:
        return self._results.pop(rid, None)

    def _publish_gauges(self) -> None:
        """Page-pool / queue / prefix-cache gauges, refreshed once per
        ``step`` round. Guarded as a block so the disabled path pays one
        check instead of one per gauge."""
        if not obs.metrics.enabled:
            return
        sched = self.sched
        self._g_free_pages.set(self.free_pages)
        self._g_queue.set(sched.queue_depth)
        self._g_slot_util.set(
            sum(1 for r in sched.slots if r is not None)
            / max(self.num_slots, 1))
        if self.prefix_cache is not None:
            st = self.prefix_cache.stats
            self._g_prefix_hits.set(st.get("hits", 0))
            self._g_prefix_reused.set(st.get("tokens_reused", 0))

    # ------------------------------------------------------------------
    def step(self, now_s: Optional[float] = None) -> List[TokenEvent]:
        """One scheduler round: admit → one prefill chunk per prefilling
        slot → one decode chunk. Returns this round's token events
        (streaming order: per request, in-completion order)."""
        now = time.perf_counter() if now_s is None else now_s
        events: List[TokenEvent] = []
        sched = self.sched
        newly = sched.admit(now)
        for r in sched.drain_expired():
            self._finish_result(r)
            events.append(TokenEvent(rid=r.rid, token=-1, logp=0.0, index=0,
                                     finished=True, finish_reason="expired"))
        for r in newly:
            if r.cow_src >= 0:
                self.pool = _copy_page_jit(self.cfg, self.plan, self.pool,
                                           jnp.int32(r.cow_src),
                                           jnp.int32(r.cow_dst))
                sched.stats["cow_copies"] += 1
                self._m_cow.inc()
        if not newly and sched.queue_depth > 0 \
                and all(r is None for r in sched.slots):
            raise RuntimeError(
                "admission stalled with an empty slot pool: the page pool "
                f"({self.num_pages} pages) cannot fit the head request "
                "even after prefix-cache eviction")

        # chunked prefill: every prefilling slot advances one chunk per
        # step, interleaved with the decode chunk below
        for pref in [r for r in sched.slots
                     if r is not None and r.state == PREFILL]:
            c0 = pref.prefill_pos
            remaining = pref.prompt_len - c0
            cw = clamp_prefill_chunk(self.prefill_chunk,
                                     remaining) or remaining
            chunk = pref.prompt[c0:c0 + cw]
            if chunk.shape[0] < cw:                 # pad to fixed shape
                chunk = np.concatenate(
                    [chunk, np.full(cw - chunk.shape[0], PAD, np.int32)])
            # only pages reachable from this chunk's max position — the
            # gather inside the paged prefill branch scales with c0 + C,
            # not pool capacity. Padded-tail writes past the narrowed
            # width hit the same OOB-drop path as past the full width.
            width = _live_width(pages_for(c0 + cw, self.page_size),
                                self.pages_per_slot)
            page_row = jnp.asarray(
                sched.block_table[pref.slot:pref.slot + 1, :width])
            with self._tr.span("prefill", track="engine", rid=pref.rid,
                               slot=pref.slot, start=c0, chunk=cw,
                               width=width):
                logits_c, self.pool = _prefill_chunk_jit(
                    self.cfg, self.params, self.pool, page_row,
                    jnp.asarray(chunk[None]), jnp.int32(c0), plan=self.plan)
            sched.stats["prefill_chunks"] += 1
            pref.prefill_pos = min(pref.prompt_len, c0 + cw)
            sched.stats["prefill_tokens"] += pref.prefill_pos - c0
            self._m_prefill_chunks.inc()
            self._m_prefill_tokens.inc(pref.prefill_pos - c0)
            if pref.prefill_pos >= pref.prompt_len:  # prompt fully cached
                s = pref.slot
                self._last = self._last.at[s].set(
                    logits_c[pref.prompt_len - 1 - c0])
                pref.state = DECODE
                self._active[s], self._pos[s] = True, pref.prompt_len
                self._gen[s], self._max_new[s] = 0, pref.max_new
                self._req_keys[s] = np.asarray(
                    jax.random.fold_in(self.key, pref.rid), np.uint32)
                if self.prefix_cache is not None:
                    self.prefix_cache.insert(
                        pref.prompt,
                        pref.pages[:pages_for(pref.prompt_len,
                                              self.page_size)])

        dec = sched.decoding()
        if not dec:
            self._publish_gauges()
            return events
        if self.spec_k > 0:
            self._spec_round(dec, now, events)
            self._publish_gauges()
            return events
        # non-decoding slots (empty, or mid-prefill) must scatter their
        # dead PAD writes into the scratch page — NOT position 0 of pages
        # a prefilling request has already filled. The table is narrowed
        # to the live high-water mark over this decode chunk (per-slot
        # ``lengths`` = the pos vector bound the page loop inside the
        # kernel; the width bounds every impl's upper shape).
        width = _live_width(
            pages_for(int(self._pos[self._active].max()) + self.sync_every,
                      self.page_size),
            self.pages_per_slot)
        bt = sched.block_table[:, :width].copy()
        bt[~self._active] = SCRATCH_PAGE
        with self._tr.span("decode", track="engine",
                           slots=len(dec), chunk=self.sync_every,
                           width=width):
            toks, lps, self._last, self.pool = _decode_chunk_jit(
                self.cfg, self.rl, self.params, self.pool, jnp.asarray(bt),
                self._last, jnp.asarray(self._pos),
                jnp.asarray(self._active),
                jnp.asarray(self._req_keys), jnp.asarray(self._gen),
                jnp.asarray(self._max_new), self.vocab_limit,
                self.sync_every, plan=self.plan)
        sched.stats["decode_steps"] += self.sync_every
        self._m_decode_steps.inc(self.sync_every)
        # deliberate sync point: the scheduler needs this chunk's tokens
        # on host for EOS recycling/admission — one sync per sync_every
        # decode steps, the amortization RA003 exists to protect
        tok_np, lp_np = np.asarray(toks), np.asarray(lps)  # noqa: RA003
        for r in dec:
            for i in range(self.sync_every):
                if r.gen_count >= r.max_new:
                    break
                t = int(tok_np[i, r.slot])
                r.tokens.append(t)
                r.logps.append(float(lp_np[i, r.slot]))
                sched.stats["decode_slot_steps"] += 1
                if r.gen_count == 1:
                    r.t_first_token = now
                events.append(TokenEvent(rid=r.rid, token=t,
                                         logp=r.logps[-1],
                                         index=r.gen_count - 1))
                if t == EOS:
                    break
            self._pos[r.slot] = r.next_pos
            self._gen[r.slot] = r.gen_count
            reason = ""
            if r.tokens and r.tokens[-1] == EOS:
                reason = "eos"
            elif r.gen_count >= r.max_new:
                reason = "length"
            if reason:
                self._active[r.slot] = False
                sched.finish(r, reason, now)
                self._finish_result(r)
                events.append(TokenEvent(rid=r.rid, token=-1, logp=0.0,
                                         index=r.gen_count, finished=True,
                                         finish_reason=reason))
        self._publish_gauges()
        return events

    # ------------------------------------------------------------------
    def _dev(self, name: str, arr: np.ndarray) -> jax.Array:
        """Cached device mirror of a small host array: re-upload only
        when the host copy changed. The compare costs microseconds; the
        device_puts it avoids were measurably milliseconds per verify
        round (block table, RNG keys and budgets change only at
        admission, not per round)."""
        ent = self._dev_cache.get(name)
        if ent is not None and ent[0].shape == arr.shape \
                and np.array_equal(ent[0], arr):
            return ent[1]
        dev = jnp.asarray(arr)
        self._dev_cache[name] = (arr.copy(), dev)
        return dev

    def _spec_round(self, dec: List[GenRequest], now: float,
                    events: List[TokenEvent]) -> None:
        """One speculative round replacing the decode chunk: draft on
        host (prompt-lookup over each slot's own history), verify all
        slots' windows in one prefill-shaped target forward, commit the
        accepted prefix + the replayed draw, rewind the rest by position
        (append-only pool — no page copies, no allocator traffic).

        The *pending* token (window column 0) is the last committed
        token whose K/V is not yet scattered — right after prefill that
        is the last prompt token (its rewrite is bit-identical, k/v are
        per-token functions of (token, position)), so freshly-admitted
        slots need no separate seeding dispatch and draw generation
        index 0 through the same replayed stream.

        Drafting is gated per request by an acceptance EMA: once a
        request's stream proves incompressible the drafter is switched
        off for it (with a periodic re-probe), and rounds where *no*
        slot drafts fall back to a sequential multi-step chunk
        (``_spec_fallback_chunk``) — the honest ~1x floor instead of a
        one-token-per-forward collapse.
        """
        sched = self.sched
        ns = self.num_slots
        per_slot: Dict[int, tuple] = {}
        max_k = 0
        for r in dec:
            pending = r.tokens[-1] if r.tokens else int(r.prompt[-1])
            ke = min(self.spec_k, r.max_new - r.gen_count - 1) \
                if r.spec_ok else 0
            st = self._spec_ema.setdefault(r.rid, [1.0, 0])
            if ke > 0 and st[0] < _SPEC_EMA_MIN:
                st[1] += 1
                if st[1] < _SPEC_PROBE_EVERY:
                    ke = 0                      # backed off; wait to probe
                else:
                    st[1] = 0                   # probe round: draft again
            d = np.zeros((0,), np.int32)
            if ke > 0:
                hist = np.concatenate(
                    [r.prompt, np.asarray(r.tokens, np.int32)])
                d = np.asarray(self.drafter.propose(hist, ke),
                               np.int32)[:ke]
            per_slot[r.slot] = (pending, d)
            max_k = max(max_k, len(d))
        if max_k == 0:
            # nothing drafted anywhere (cold histories, opted-out
            # requests, or EMA-gated incompressible streams): run a
            # sequential decode chunk instead of a width-2 verify that
            # would emit one token per forward
            self._spec_fallback_chunk(dec, now, events, per_slot)
            return
        # pow2-bucketed verification width (floor 2 keeps the window on
        # the prefill-shaped recording path) — O(log spec_k) executables.
        # Everything that varies per round rides ONE packed int32 array:
        # [window(W), draft_len, gen_base, pos0, active] per row.
        w = max(2, _live_width(1 + max_k, self.spec_k + 1))
        packed = np.zeros((ns, w + 4), np.int32)
        packed[:, :w] = PAD
        for r in dec:
            s = r.slot
            pending, d = per_slot[s]
            packed[s, 0] = pending
            packed[s, 1:1 + len(d)] = d
            packed[s, w] = len(d)
            packed[s, w + 1] = r.gen_count - 1           # gen_base
            packed[s, w + 2] = r.prompt_len + r.gen_count - 1   # pos0
            packed[s, w + 3] = 1                         # active
        width = _live_width(
            pages_for(int(packed[:, w + 2].max()) + w, self.page_size),
            self.pages_per_slot)
        bt = sched.block_table[:, :width].copy()
        bt[~self._active] = SCRATCH_PAGE
        with self._tr.span("verify", track="engine", slots=len(dec),
                           window=w, width=width):
            iout, fout, self.pool = _verify_chunk_jit(
                self.cfg, self.rl, self.params, self.pool,
                self._dev("bt.verify", bt), jnp.asarray(packed),
                self._dev("req_keys", self._req_keys),
                self._dev("max_new", self._max_new),
                self.vocab_limit, self.spec_rescore, plan=self.plan)
        sched.stats["decode_steps"] += 1
        sched.stats["spec_rounds"] += 1
        self._m_decode_steps.inc(1)
        # two deliberate syncs per verify round (packed int/f32 results),
        # the decode chunk's twin
        io = np.asarray(iout)                              # noqa: RA003
        fo = np.asarray(fout)                              # noqa: RA003
        tok_np, ne, na = io[:, :w], io[:, w], io[:, w + 1]
        lp_np = fo[:, :w]
        if self.spec_rescore:
            self._rescore_max_diff = max(self._rescore_max_diff,
                                         float(fo[0, w]))
        drafted = accepted = hits = 0
        for r in dec:
            s = r.slot
            dl = len(per_slot[s][1])
            drafted += dl
            accepted += int(na[s])
            hits += int(dl > 0)
            if dl > 0:
                st = self._spec_ema[r.rid]
                st[0] = (_SPEC_EMA_DECAY * st[0]
                         + (1.0 - _SPEC_EMA_DECAY) * int(na[s]) / dl)
            sched.stats["spec_slot_rounds"] += 1
            sched.stats["decode_slot_steps"] += 1
            for j in range(int(ne[s])):
                t = int(tok_np[s, j])
                r.tokens.append(t)
                r.logps.append(float(lp_np[s, j]))
                if r.gen_count == 1:
                    r.t_first_token = now
                events.append(TokenEvent(rid=r.rid, token=t,
                                         logp=r.logps[-1],
                                         index=r.gen_count - 1))
            self._pos[s] = r.next_pos
            self._gen[s] = r.gen_count
            reason = ""
            if r.tokens and r.tokens[-1] == EOS:
                reason = "eos"
            elif r.gen_count >= r.max_new:
                reason = "length"
            if reason:
                self._active[s] = False
                self._spec_ema.pop(r.rid, None)
                sched.finish(r, reason, now)
                self._finish_result(r)
                events.append(TokenEvent(rid=r.rid, token=-1, logp=0.0,
                                         index=r.gen_count, finished=True,
                                         finish_reason=reason))
        sched.stats["drafted_tokens_total"] += drafted
        sched.stats["accepted_tokens_total"] += accepted
        sched.stats["draft_hits"] += hits
        if obs.metrics.enabled:
            st = sched.stats
            self._m_spec_rounds.inc()
            self._m_spec_drafted.inc(drafted)
            self._m_spec_accepted.inc(accepted)
            self._g_accept_rate.set(st["accepted_tokens_total"]
                                    / max(st["drafted_tokens_total"], 1))
            self._g_draft_hit.set(st["draft_hits"]
                                  / max(st["spec_slot_rounds"], 1))
            self._g_rescore_diff.set(self._rescore_max_diff)

    def _spec_fallback_chunk(self, dec: List[GenRequest], now: float,
                             events: List[TokenEvent],
                             per_slot: Dict[int, tuple]) -> None:
        """Sequential multi-step chunk for no-draft rounds, in the
        pending-token convention (``_spec_decode_chunk_jit``). Tokens and
        logps are bit-identical to what the verify path would emit — the
        same per-request counter stream drives every draw and K/V lands
        at the same absolute positions — so the engine can switch between
        the two paths per round without perturbing the output stream."""
        sched = self.sched
        ns = self.num_slots
        pending = np.zeros((ns,), np.int32)
        pos0 = np.zeros((ns,), np.int32)
        gen_base = np.full((ns,), -1, np.int32)
        for r in dec:
            s = r.slot
            pending[s] = per_slot[s][0]
            pos0[s] = r.prompt_len + r.gen_count - 1
            gen_base[s] = r.gen_count - 1
        width = _live_width(
            pages_for(int(pos0.max()) + self.sync_every, self.page_size),
            self.pages_per_slot)
        bt = sched.block_table[:, :width].copy()
        bt[~self._active] = SCRATCH_PAGE
        with self._tr.span("decode", track="engine", slots=len(dec),
                           chunk=self.sync_every, width=width):
            toks, lps, self.pool = _spec_decode_chunk_jit(
                self.cfg, self.rl, self.params, self.pool,
                self._dev("bt.fallback", bt), jnp.asarray(pending),
                jnp.asarray(pos0), jnp.asarray(self._active),
                self._dev("req_keys", self._req_keys),
                jnp.asarray(gen_base), self._dev("max_new", self._max_new),
                self.vocab_limit, self.sync_every, plan=self.plan)
        sched.stats["decode_steps"] += self.sync_every
        sched.stats["spec_fallback_chunks"] += 1
        self._m_decode_steps.inc(self.sync_every)
        # one deliberate sync per chunk (the decode path's amortization)
        tok_np, lp_np = np.asarray(toks), np.asarray(lps)  # noqa: RA003
        for r in dec:
            for i in range(self.sync_every):
                if r.gen_count >= r.max_new:
                    break
                t = int(tok_np[i, r.slot])
                r.tokens.append(t)
                r.logps.append(float(lp_np[i, r.slot]))
                sched.stats["decode_slot_steps"] += 1
                if r.gen_count == 1:
                    r.t_first_token = now
                events.append(TokenEvent(rid=r.rid, token=t,
                                         logp=r.logps[-1],
                                         index=r.gen_count - 1))
                if t == EOS:
                    break
            self._pos[r.slot] = r.next_pos
            self._gen[r.slot] = r.gen_count
            reason = ""
            if r.tokens and r.tokens[-1] == EOS:
                reason = "eos"
            elif r.gen_count >= r.max_new:
                reason = "length"
            if reason:
                self._active[r.slot] = False
                self._spec_ema.pop(r.rid, None)
                sched.finish(r, reason, now)
                self._finish_result(r)
                events.append(TokenEvent(rid=r.rid, token=-1, logp=0.0,
                                         index=r.gen_count, finished=True,
                                         finish_reason=reason))

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request],
                 key: Optional[jax.Array] = None) -> List[GenerationResult]:
        """Batch convenience: submit ``requests``, step until they all
        finish, return results in request order."""
        if key is not None:
            self.key = key
        pending = set()
        for req in requests:
            self.submit(req)
            pending.add(req.rid)
        while pending - self._results.keys():
            if not self.has_work():
                missing = sorted(pending - self._results.keys())
                raise RuntimeError(f"engine drained but requests {missing} "
                                   "never finished")
            self.step()
        return [self._results.pop(r.rid) for r in requests]


# --------------------------------------------------------------------------
# batch wrapper (the pre-request-API surface, kept exactly compatible)


def rollout_from_results(prompts: np.ndarray,
                         results: Sequence[GenerationResult],
                         max_new: int) -> Dict[str, Any]:
    """Assemble the engine-agnostic rollout dict (tokens / completions /
    sampler_lp / comp_mask) from per-request results. Row ``i`` is
    ``results[i]``; expired requests contribute all-PAD rows."""
    b, tp = prompts.shape
    completions = np.full((b, max_new), PAD, np.int32)
    sampler_lp = np.zeros((b, max_new), np.float32)
    comp_mask = np.zeros((b, max_new), np.float32)
    for i, res in enumerate(results):
        n = res.gen_count
        completions[i, :n] = res.tokens
        sampler_lp[i, :n] = res.logps
        comp_mask[i, :n] = 1.0
    tokens = np.concatenate([np.asarray(prompts), completions], axis=1)
    return {"tokens": jnp.asarray(tokens),
            "completions": jnp.asarray(completions),
            "sampler_lp": jnp.asarray(sampler_lp),
            "comp_mask": jnp.asarray(comp_mask),
            "prompt_len": tp}


def generate_continuous(cfg: ModelConfig, rl: RLConfig, params,
                        prompts: jax.Array, key: jax.Array, *,
                        max_new: Optional[int] = None,
                        vocab_limit: Optional[int] = None,
                        num_slots: Optional[int] = None,
                        page_size: int = 16,
                        prefill_chunk: Optional[int] = None,
                        prompt_lens: Optional[Sequence[int]] = None,
                        sync_every: int = 8,
                        plan=None,
                        prefix_cache: bool = False,
                        ) -> Dict[str, jax.Array]:
    """Continuous-batching generation over ``prompts`` (B, Tp).

    Drop-in for the static path: same rollout dict, same tokens/logps for
    the same ``key`` (per-request RNG streams). Extras: ``num_slots``
    decode slots are recycled as requests finish, ``prompt_lens`` admits
    per-request true prompt lengths (rows shorter than Tp),
    ``prefill_chunk`` bounds how much prompt is prefilled between decode
    chunks (defaults to the whole prompt in one chunk), ``sync_every``
    is the decode horizon, and ``prefix_cache`` turns on shared-prefix
    page reuse (bit-exact; off by default here so the legacy batch path
    keeps its exact page accounting — the serving front door defaults it
    on). ``plan`` (an ``ExecutionPlan``) makes prefill/decode run
    tensor-parallel: params and the paged KV pool are constrained by the
    plan's cache_specs.
    """
    max_new = max_new or rl.max_new_tokens
    prompts_np = np.asarray(prompts)
    b, tp = prompts_np.shape
    num_slots = min(b, num_slots or 8)
    engine = ContinuousEngine(
        cfg, params, rl=rl, max_total_tokens=tp + max_new,
        num_slots=num_slots, page_size=page_size, sync_every=sync_every,
        prefill_chunk=clamp_prefill_chunk(prefill_chunk, tp),
        vocab_limit=vocab_limit, plan=plan, prefix_cache=prefix_cache,
        key=key)
    sp = SamplingParams(temperature=rl.temperature, top_k=rl.top_k,
                        top_p=rl.top_p, max_new_tokens=max_new)
    requests = []
    for r in range(b):
        plen = int(prompt_lens[r]) if prompt_lens is not None else tp
        if not 0 < plen <= tp:
            raise ValueError(f"prompt_lens[{r}]={plen} outside (0, {tp}]")
        requests.append(Request(rid=r, prompt=prompts_np[r, :plen],
                                params=sp))
    results = engine.generate(requests)
    roll = rollout_from_results(prompts_np, results, max_new)
    roll["stats"] = engine.stats()
    return roll
