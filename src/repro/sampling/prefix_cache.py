"""Shared-prefix KV page cache for the continuous-batching engine.

N requests that share a system prompt should prefill it once. The cache
maps prompt-token prefixes to the physical KV pages that already hold
their keys/values, keyed on the prompt-token hash (bucketing) with an
exact token-array compare (correctness). Because K/V at position ``j``
depend only on tokens ``[0, j]`` under causal attention, any cached
prefix whose tokens match a new request's first ``m`` tokens serves that
request's positions ``[0, m)`` verbatim — bit-exactly.

Sharing rules (enforced by the scheduler at admission):

- **full pages** of the common prefix are shared in place: the new
  request's block table points at the cached physical pages, which are
  ``retain``-ed on the refcounted :class:`~repro.sampling.paged_cache.
  PageAllocator` so they outlive any single request;
- the **partial tail page** (a prefix ending mid-page) is *copied on
  write*: the sharer gets a fresh page, the engine copies the cached
  page's contents into it device-side, and the sharer appends its own
  tokens into the copy — the cached page is never written by a sharer.
  (The original owner keeps decoding into the cached tail page, but only
  at positions ``>= m``, which the sharer either overwrites in its copy
  or masks — so the shared region ``[0, m)`` is immutable in practice.)

The cache holds its own reference on every cached page; eviction (LRU,
triggered by pool pressure or the entry cap) just drops that reference —
pages still shared by live requests survive until those finish.

Entries are whole inserted prefixes compared exactly; the hash is a
bucketing hint, not trusted. A production variant would chain per-page
hashes (vLLM-style) for O(pages) lookup; at this repo's scale a scan
over a bounded entry list is simpler and obviously correct.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.sampling.paged_cache import PageAllocator, pages_for


def _common_prefix_len(a: np.ndarray, b: np.ndarray) -> int:
    n = min(a.shape[0], b.shape[0])
    if n == 0:
        return 0
    neq = a[:n] != b[:n]
    return int(np.argmax(neq)) if neq.any() else n


@dataclasses.dataclass
class PrefixEntry:
    tokens: np.ndarray          # (L,) int32 prompt prefix held by this entry
    pages: List[int]            # pages_for(L) physical pages, cache-retained
    tick: int                   # LRU stamp


class PrefixCache:
    """LRU prompt-prefix → KV-page cache over a refcounted allocator."""

    def __init__(self, page_size: int, allocator: PageAllocator, *,
                 max_entries: int = 64) -> None:
        self.page_size = page_size
        self.allocator = allocator
        self.max_entries = max_entries
        self._entries: Dict[int, PrefixEntry] = {}   # token-hash -> entry
        self._tick = 0
        self.stats: Dict[str, int] = {
            "lookups": 0, "hits": 0, "tokens_reused": 0,
            "inserts": 0, "evictions": 0,
        }

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _key(tokens: np.ndarray) -> int:
        return hash(tokens.tobytes())

    # ------------------------------------------------------------------
    def lookup(self, prompt: np.ndarray) -> Tuple[int, List[int], int]:
        """Longest cached prefix of ``prompt``.

        Returns ``(m, shared_pages, cow_src)``: ``m`` matched tokens
        (capped at ``len(prompt) - 1`` so the final prompt token is
        always prefilled — its logits seed decoding), the cached
        physical pages for the ``m // page_size`` *full* matched pages
        (NOT yet retained — the caller retains before allocating the
        rest), and the cached page to copy-on-write for a mid-page tail
        (``-1`` when ``m`` is page-aligned). Best-match across entries;
        bumps the winner's LRU stamp.
        """
        self.stats["lookups"] += 1
        prompt = np.asarray(prompt)
        best_m, best = 0, None
        for ent in self._entries.values():
            m = _common_prefix_len(ent.tokens, prompt)
            if m > best_m:
                best_m, best = m, ent
        best_m = min(best_m, int(prompt.shape[0]) - 1)
        if best is None or best_m <= 0:
            return 0, [], -1
        self._tick += 1
        best.tick = self._tick
        full = best_m // self.page_size
        cow_src = best.pages[full] if best_m % self.page_size else -1
        self.stats["hits"] += 1
        self.stats["tokens_reused"] += best_m
        return best_m, list(best.pages[:full]), cow_src

    def peek(self, prompt: np.ndarray) -> Tuple[int, List[int], int]:
        """``lookup`` without side effects (no stats, no LRU bump) — what
        the admission controller uses to estimate how many pages a
        request would actually allocate."""
        prompt = np.asarray(prompt)
        best_m = 0
        best: Optional[PrefixEntry] = None
        for ent in self._entries.values():
            m = _common_prefix_len(ent.tokens, prompt)
            if m > best_m:
                best_m, best = m, ent
        best_m = min(best_m, int(prompt.shape[0]) - 1)
        if best is None or best_m <= 0:
            return 0, [], -1
        full = best_m // self.page_size
        cow_src = best.pages[full] if best_m % self.page_size else -1
        return best_m, list(best.pages[:full]), cow_src

    def insert(self, prompt: np.ndarray, pages: List[int]) -> bool:
        """Cache ``prompt``'s prefix pages (``pages_for(len(prompt))`` of
        ``pages``). The cache retains them; skips prompts an existing
        entry already covers in full. Returns True if inserted."""
        prompt = np.asarray(prompt, np.int32)
        n = int(prompt.shape[0])
        if n < self.page_size:            # not worth a cache slot
            return False
        for ent in self._entries.values():
            if _common_prefix_len(ent.tokens, prompt) == n:
                return False
        need = pages_for(n, self.page_size)
        held = list(pages[:need])
        self.allocator.retain(held)
        self._tick += 1
        key = self._key(prompt)
        if key in self._entries:          # same tokens re-inserted: replace
            self.allocator.release(self._entries[key].pages)
        self._entries[key] = PrefixEntry(tokens=prompt, pages=held,
                                         tick=self._tick)
        self.stats["inserts"] += 1
        while len(self._entries) > self.max_entries:
            self._evict_lru()
        return True

    # ------------------------------------------------------------------
    def _evict_lru(self) -> bool:
        if not self._entries:
            return False
        key = min(self._entries, key=lambda k: self._entries[k].tick)
        self.allocator.release(self._entries.pop(key).pages)
        self.stats["evictions"] += 1
        return True

    def evict_until(self, need_free: int) -> int:
        """Drop LRU entries until the allocator can hand out
        ``need_free`` pages (or the cache is empty). Pages still shared
        by live requests only lose the cache's reference — they free for
        real when the last request releases them. Returns entries
        evicted."""
        n = 0
        while self.allocator.available < need_free and self._evict_lru():
            n += 1
        return n

    def clear(self) -> None:
        while self._evict_lru():
            pass
