"""Temperature / top-k / top-p sampling in JAX (the paper sweeps all three,
App. B.5.2)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mask_vocab(lg: jax.Array, vocab_limit: int) -> jax.Array:
    """Mask padded-vocab tail logits (shared by both engines)."""
    if vocab_limit < lg.shape[-1]:
        bad = jnp.arange(lg.shape[-1]) >= vocab_limit
        lg = jnp.where(bad, NEG_INF, lg)
    return lg


def model_logp(last: jax.Array, tok: jax.Array) -> jax.Array:
    """Full-model logp of the drawn token (what the learner's
    teacher-forced recompute sees — vLLM convention)."""
    full_lp = jax.nn.log_softmax(last.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(full_lp, tok[:, None], axis=-1)[:, 0]


def filter_logits(logits: jax.Array, *, temperature: float = 1.0,
                  top_k: int = 0, top_p: float = 1.0) -> jax.Array:
    """Apply temperature then top-k then top-p (nucleus) filtering.
    logits (..., V) -> filtered logits (masked entries = -inf)."""
    if temperature != 1.0:
        logits = logits / jnp.maximum(temperature, 1e-6)
    v = logits.shape[-1]
    if top_k and top_k < v:
        # k-th largest via lax.top_k (O(V·k)) — the full-vocab sort this
        # replaces was O(V log V); thresholding keeps tie behavior
        # identical (everything strictly below the k-th value is masked)
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, NEG_INF, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative prob >= top_p
        keep_sorted = cum - probs < top_p
        kth = jnp.take_along_axis(
            sorted_logits, keep_sorted.sum(-1, keepdims=True) - 1, axis=-1)
        logits = jnp.where(logits < kth, NEG_INF, logits)
    return logits


def sample_token(key: jax.Array, logits: jax.Array, *,
                 temperature: float = 1.0, top_k: int = 0,
                 top_p: float = 1.0):
    """Returns (token (B,), logp_under_sampling_dist (B,),
    logp_under_model (B,)). The model logp (pre-filter, temperature-1) is
    what the learner recomputes — the filtered distribution is only used
    to draw."""
    filt = filter_logits(logits, temperature=temperature, top_k=top_k,
                         top_p=top_p)
    tok = jax.random.categorical(key, filt, axis=-1)
    model_lp = jax.nn.log_softmax(logits, axis=-1)
    lp_model = jnp.take_along_axis(model_lp, tok[..., None], axis=-1)[..., 0]
    filt_lp = jax.nn.log_softmax(filt, axis=-1)
    lp_filt = jnp.take_along_axis(filt_lp, tok[..., None], axis=-1)[..., 0]
    return tok, lp_filt, lp_model


def sample_token_rows(keys: jax.Array, logits: jax.Array, *,
                      temperature: float = 1.0, top_k: int = 0,
                      top_p: float = 1.0):
    """Row-independent sampling: row ``r`` of ``logits`` (B, V) is drawn
    with its own ``keys[r]``. Because a row's draw depends only on its own
    (key, logits) — never on where it sits in the batch — the static and
    continuous-batching engines produce identical tokens for a request
    regardless of slot placement. Returns the same triple as
    ``sample_token``, each (B,)."""
    fn = functools.partial(sample_token, temperature=temperature,
                           top_k=top_k, top_p=top_p)
    return jax.vmap(fn)(keys, logits)
