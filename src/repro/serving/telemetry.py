"""Serving telemetry: the SLO numbers the front door reports.

Collects per-request outcomes (:class:`~repro.serving.api.
GenerationResult` carries TTFT and end-to-end latency measured on the
submitter's clock) and engine counters, and reduces them to the numbers
an operator actually pages on:

- **p50/p99 TTFT** — time to first token, the interactive SLO;
- **p50/p99 latency** — end-to-end completion time;
- **tokens/s/slot** — decoded tokens per second per decode slot, the
  serving-efficiency headline (decode wall time is approximated by the
  window between the first and last recorded completion);
- admission-control outcomes (rejections by reason, expirations).

Percentiles use the nearest-rank method over everything recorded since
construction (or the last ``reset``); the benchmark keeps one collector
per load scenario. No numpy dependency on the hot path — a sorted copy
per snapshot is fine at front-door request rates.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.serving.api import GenerationResult


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of ``values``; NaN when
    empty. Deterministic and exact for the small samples serving
    benchmarks collect — no interpolation surprises across numpy
    versions."""
    vals = sorted(v for v in values if not math.isnan(v))
    if not vals:
        return float("nan")
    rank = max(1, math.ceil(q / 100.0 * len(vals)))
    return vals[min(rank, len(vals)) - 1]


class ServeTelemetry:
    """Accumulates per-request outcomes into SLO summary statistics."""

    def __init__(self, num_slots: int) -> None:
        self.num_slots = num_slots
        self.reset()

    def reset(self) -> None:
        self.ttfts: List[float] = []
        self.latencies: List[float] = []
        self.tokens_out = 0
        self.prefix_hit_tokens = 0
        self.prompt_tokens = 0
        self.completed = 0
        self.expired = 0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    def record(self, res: GenerationResult,
               done_s: Optional[float] = None) -> None:
        if res.finish_reason == "expired":
            self.expired += 1
            return
        self.completed += 1
        self.tokens_out += res.gen_count
        self.prompt_tokens += res.prompt_len
        self.prefix_hit_tokens += res.prefix_hit_tokens
        self.ttfts.append(res.ttft_s)
        self.latencies.append(res.latency_s)
        if done_s is not None:
            if self._t_first is None:
                self._t_first = done_s
            self._t_last = done_s

    @property
    def span_s(self) -> float:
        """Wall span between the first and last recorded completion."""
        if self._t_first is None or self._t_last is None \
                or self._t_last <= self._t_first:
            return float("nan")
        return self._t_last - self._t_first

    def snapshot(self) -> Dict[str, float]:
        span = self.span_s
        tput = float("nan") if math.isnan(span) else self.tokens_out / span
        return {
            "completed": self.completed,
            "expired": self.expired,
            "tokens_out": self.tokens_out,
            "prompt_tokens": self.prompt_tokens,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "ttft_p50_s": percentile(self.ttfts, 50),
            "ttft_p99_s": percentile(self.ttfts, 99),
            "latency_p50_s": percentile(self.latencies, 50),
            "latency_p99_s": percentile(self.latencies, 99),
            "tokens_per_s": tput,
            "tokens_per_s_per_slot": (tput / self.num_slots
                                      if not math.isnan(tput)
                                      else float("nan")),
        }
