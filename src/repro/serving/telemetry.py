"""Serving telemetry: the SLO numbers the front door reports.

Collects per-request outcomes (:class:`~repro.serving.api.
GenerationResult` carries TTFT and end-to-end latency measured on the
submitter's clock) and engine counters, and reduces them to the numbers
an operator actually pages on:

- **p50/p99 TTFT** — time to first token, the interactive SLO;
- **p50/p99 latency** — end-to-end completion time;
- **tokens/s/slot** — decoded tokens per second per decode slot, the
  serving-efficiency headline (decode wall time is approximated by the
  window between the first and last recorded completion);
- admission-control outcomes (rejections by reason, expirations).

``ServeTelemetry`` is a thin view over the unified metrics registry
(:mod:`repro.obs`): every ``record`` also feeds registry counters and
bounded histograms, so ``/metrics`` Prometheus scrapes and the JSON
snapshot come from one pipeline. Percentiles use the nearest-rank
method over a **bounded seeded reservoir** (uniform sample, Algorithm
R) rather than an unbounded list — a long-lived front door holds O(1)
memory per SLO series, and the seeded sampling keeps test percentiles
deterministic. No numpy dependency on the hot path.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Optional

from repro import obs
from repro.obs import MetricsRegistry, Reservoir
from repro.serving.api import GenerationResult

# Reservoir capacity per SLO series. Nearest-rank p99 over a 4096-sample
# uniform reservoir is exact until 4096 requests and a tight estimate
# after; the serving benchmarks record far fewer, so their percentiles
# are bit-identical to the unbounded-list behavior.
RESERVOIR_CAPACITY = 4096

# Latency-shaped buckets for the registry histograms (seconds).
_LAT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                0.5, 1.0, 2.5, 5.0, 15.0, 60.0)


def percentile(values: Iterable[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of ``values``; NaN when
    empty or all-NaN. Deterministic and exact for the small samples
    serving benchmarks collect — no interpolation surprises across
    numpy versions. ``q=0`` is the minimum, ``q=100`` the maximum."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q} outside [0, 100]")
    vals = sorted(v for v in values if not math.isnan(v))
    if not vals:
        return float("nan")
    rank = max(1, math.ceil(q / 100.0 * len(vals)))
    return vals[min(rank, len(vals)) - 1]


class ServeTelemetry:
    """Accumulates per-request outcomes into SLO summary statistics.

    ``registry`` defaults to the process-wide ``obs.metrics``; pass a
    private :class:`MetricsRegistry` to isolate (tests, benchmarks).
    """

    def __init__(self, num_slots: int, *,
                 registry: Optional[MetricsRegistry] = None,
                 reservoir_capacity: int = RESERVOIR_CAPACITY,
                 seed: int = 0) -> None:
        self.num_slots = num_slots
        self.reservoir_capacity = reservoir_capacity
        self.seed = seed
        reg = registry if registry is not None else obs.metrics
        self._m_completed = reg.counter(
            "serve_requests_completed_total", "requests run to completion")
        self._m_expired = reg.counter(
            "serve_requests_expired_total", "requests expired past deadline")
        self._m_tokens = reg.counter(
            "serve_tokens_out_total", "completion tokens decoded")
        self._m_prompt = reg.counter(
            "serve_prompt_tokens_total", "prompt tokens admitted")
        self._m_prefix_hit = reg.counter(
            "serve_prefix_hit_tokens_total",
            "prompt tokens served from the shared-prefix cache")
        self._h_ttft = reg.histogram(
            "serve_ttft_seconds", "time to first token",
            buckets=_LAT_BUCKETS)
        self._h_latency = reg.histogram(
            "serve_latency_seconds", "end-to-end request latency",
            buckets=_LAT_BUCKETS)
        self.reset()

    def reset(self) -> None:
        self.ttfts = Reservoir(self.reservoir_capacity, seed=self.seed)
        self.latencies = Reservoir(self.reservoir_capacity,
                                   seed=self.seed + 1)
        self.tokens_out = 0
        self.prefix_hit_tokens = 0
        self.prompt_tokens = 0
        self.completed = 0
        self.expired = 0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    def record(self, res: GenerationResult,
               done_s: Optional[float] = None) -> None:
        if res.finish_reason == "expired":
            self.expired += 1
            self._m_expired.inc()
            return
        self.completed += 1
        self.tokens_out += res.gen_count
        self.prompt_tokens += res.prompt_len
        self.prefix_hit_tokens += res.prefix_hit_tokens
        self.ttfts.append(res.ttft_s)
        self.latencies.append(res.latency_s)
        self._m_completed.inc()
        self._m_tokens.inc(res.gen_count)
        self._m_prompt.inc(res.prompt_len)
        self._m_prefix_hit.inc(res.prefix_hit_tokens)
        self._h_ttft.observe(res.ttft_s)
        self._h_latency.observe(res.latency_s)
        if done_s is not None:
            if self._t_first is None:
                self._t_first = done_s
            self._t_last = done_s

    @property
    def span_s(self) -> float:
        """Wall span between the first and last recorded completion."""
        if self._t_first is None or self._t_last is None \
                or self._t_last <= self._t_first:
            return float("nan")
        return self._t_last - self._t_first

    def snapshot(self) -> Dict[str, float]:
        span = self.span_s
        tput = float("nan") if math.isnan(span) else self.tokens_out / span
        return {
            "completed": self.completed,
            "expired": self.expired,
            "tokens_out": self.tokens_out,
            "prompt_tokens": self.prompt_tokens,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "ttft_p50_s": percentile(self.ttfts, 50),
            "ttft_p99_s": percentile(self.ttfts, 99),
            "latency_p50_s": percentile(self.latencies, 50),
            "latency_p99_s": percentile(self.latencies, 99),
            "tokens_per_s": tput,
            "tokens_per_s_per_slot": (tput / self.num_slots
                                      if not math.isnan(tput)
                                      else float("nan")),
        }
