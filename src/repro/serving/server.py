"""Asyncio serving front door: HTTP + websocket streaming over the
continuous engine.

One process, one engine, one event loop. Client requests land in an
asyncio queue; a single *pump* coroutine owns the engine — it drains the
queue through admission control, submits survivors, and runs
``engine.step()`` in a worker thread (the device work releases the GIL
there while the loop keeps accepting connections). Each step's
:class:`~repro.serving.api.TokenEvent` batch is fanned out to per-request
subscriber queues, which the connection handlers stream from.

Endpoints (deliberately tiny, stdlib-only — no web framework):

- ``POST /generate`` — body ``{"tokens": [...]}`` or ``{"text": "..."}``
  plus optional ``max_new_tokens`` / ``temperature`` / ``top_k`` /
  ``top_p`` (must match the engine profile) / ``priority`` /
  ``deadline_s`` (relative to arrival) / ``stream``. Non-streaming
  returns one JSON result; ``"stream": true`` returns chunked NDJSON —
  one line per token event, then a result line.
- ``GET /ws`` (websocket upgrade) — send the same JSON request as a text
  frame, receive one JSON event per frame; multiple requests may be in
  flight per connection (responses carry the request ``id`` echoed back).
- ``GET /healthz`` — liveness + engine stats.
- ``GET /metrics`` — SLO telemetry (p50/p99 TTFT, tokens/s/slot),
  admission rejections, prefix-cache counters.

Admission rejections map to HTTP status codes the client can act on:
400 ``infeasible`` (never retry as-is), 408 ``expired``, 429
``queue_full`` / ``overloaded`` (back off and retry).
"""
from __future__ import annotations

import asyncio
import base64
import hashlib
import json
import struct
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro import obs
from repro.analysis.sentinel import install_metrics_listener
from repro.config import ModelConfig, RLConfig, ServeConfig
from repro.sampling.continuous import ContinuousEngine
from repro.sampling.engine import build_engine
from repro.serving.admission import (EXPIRED, INFEASIBLE,
                                     AdmissionController)
from repro.serving.api import GenerationResult, Request, SamplingParams
from repro.serving.telemetry import ServeTelemetry

_WS_GUID = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11"
_REJECT_STATUS = {INFEASIBLE: 400, EXPIRED: 408}   # others -> 429


def _ws_accept(key: str) -> str:
    digest = hashlib.sha1((key + _WS_GUID).encode()).digest()
    return base64.b64encode(digest).decode()


def _ws_frame(payload: bytes, opcode: int = 0x1) -> bytes:
    """Server->client frame (FIN set, unmasked)."""
    head = bytes([0x80 | opcode])
    n = len(payload)
    if n < 126:
        head += bytes([n])
    elif n < 1 << 16:
        head += bytes([126]) + struct.pack(">H", n)
    else:
        head += bytes([127]) + struct.pack(">Q", n)
    return head + payload


async def _ws_read_frame(reader: asyncio.StreamReader
                         ) -> Tuple[int, bytes]:
    """One client frame -> (opcode, unmasked payload)."""
    b1, b2 = await reader.readexactly(2)
    opcode = b1 & 0x0F
    masked, n = b2 & 0x80, b2 & 0x7F
    if n == 126:
        n = struct.unpack(">H", await reader.readexactly(2))[0]
    elif n == 127:
        n = struct.unpack(">Q", await reader.readexactly(8))[0]
    mask = await reader.readexactly(4) if masked else b"\x00" * 4
    data = bytearray(await reader.readexactly(n))
    for i in range(len(data)):
        data[i] ^= mask[i % 4]
    return opcode, bytes(data)


class FrontDoor:
    """The serving front door: engine pump + HTTP/websocket endpoints."""

    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig, *,
                 rl: Optional[RLConfig] = None, tokenizer=None,
                 vocab_limit: Optional[int] = None, plan=None, key=None,
                 engine: Optional[ContinuousEngine] = None) -> None:
        self.serve = serve
        self.rl = rl or RLConfig(engine="continuous")
        self.tokenizer = tokenizer
        self.engine = engine if engine is not None else build_engine(
            cfg, params, serve, rl=self.rl, vocab_limit=vocab_limit,
            plan=plan, key=key)
        if not isinstance(self.engine, ContinuousEngine):
            raise ValueError("the front door streams from the continuous "
                             f"engine; ServeConfig.engine={serve.engine!r} "
                             "resolved to a non-streaming engine")
        self.admission = AdmissionController(serve, self.engine)
        self.telemetry = ServeTelemetry(serve.num_slots)
        # unified observability: compile events count into the registry
        # for this process's lifetime (steady-state recompiles are an
        # operator page, not just a test failure), and /metrics serves
        # the registry as Prometheus text when asked for text/plain
        install_metrics_listener()
        self._pending: asyncio.Queue = asyncio.Queue()
        self._subs: Dict[int, asyncio.Queue] = {}
        self._next_rid = 0
        self._server: Optional[asyncio.AbstractServer] = None
        self._pump_task: Optional[asyncio.Task] = None
        self._running = False

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> None:
        self._running = True
        self._pump_task = asyncio.ensure_future(self._pump())
        self._server = await asyncio.start_server(
            self._handle_conn, self.serve.host, self.serve.port)

    @property
    def port(self) -> int:
        assert self._server is not None, "front door not started"
        return self._server.sockets[0].getsockname()[1]

    async def close(self) -> None:
        self._running = False
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if self._pump_task is not None:
            await self._pump_task

    # -- request intake ----------------------------------------------------
    def build_request(self, payload: Dict[str, Any],
                      now_s: float) -> Request:
        """A validated Request from a client JSON payload. Raises
        ValueError on anything malformed (mapped to HTTP 400)."""
        tokens = payload.get("tokens")
        if tokens is None:
            text = payload.get("text")
            if text is None or self.tokenizer is None:
                raise ValueError('payload needs "tokens" (or "text" when '
                                 "the server has a tokenizer)")
            tokens = self.tokenizer.encode(text)
        rl = self.rl
        params = SamplingParams(
            temperature=payload.get("temperature", rl.temperature),
            top_k=payload.get("top_k", rl.top_k),
            top_p=payload.get("top_p", rl.top_p),
            max_new_tokens=payload.get("max_new_tokens",
                                       rl.max_new_tokens))
        if params.profile != self.engine.profile:
            raise ValueError(f"sampling profile {params.profile} != engine "
                             f"profile {self.engine.profile} — this "
                             "deployment serves one profile")
        deadline = None
        rel = payload.get("deadline_s", self.serve.default_deadline_s or None)
        if rel:
            deadline = now_s + float(rel)
        rid, self._next_rid = self._next_rid, self._next_rid + 1
        return Request(rid=rid, prompt=np.asarray(tokens, np.int32),
                       params=params,
                       priority=int(payload.get("priority",
                                                self.serve.default_priority)),
                       deadline_s=deadline, arrival_s=now_s)

    async def submit(self, req: Request) -> asyncio.Queue:
        """Queue a request for the pump; returns its subscriber queue.
        Items are ("reject", AdmissionDecision) | ("event", TokenEvent) |
        ("done", GenerationResult)."""
        sub: asyncio.Queue = asyncio.Queue()
        await self._pending.put((req, sub))
        return sub

    # -- the engine pump ---------------------------------------------------
    def _admit(self, req: Request, sub: asyncio.Queue) -> None:
        decision = self.admission.check(req, now_s=time.perf_counter())
        if not decision:
            sub.put_nowait(("reject", decision))
            return
        self._subs[req.rid] = sub
        self.engine.submit(req)

    async def _pump(self) -> None:
        loop = asyncio.get_event_loop()
        while self._running:
            while not self._pending.empty():
                req, sub = self._pending.get_nowait()
                self._admit(req, sub)
            if not self.engine.has_work():
                try:                     # park until work (or shutdown poll)
                    req, sub = await asyncio.wait_for(self._pending.get(),
                                                      timeout=0.05)
                    self._admit(req, sub)
                except asyncio.TimeoutError:
                    pass
                continue
            events = await loop.run_in_executor(None, self.engine.step)
            now = time.perf_counter()
            for ev in events:
                sub = self._subs.get(ev.rid)
                if sub is not None and not ev.finished:
                    sub.put_nowait(("event", ev))
                if ev.finished:
                    res = self.engine.pop_result(ev.rid)
                    self.telemetry.record(res, done_s=now)
                    if sub is not None:
                        sub.put_nowait(("done", res))
                        self._subs.pop(ev.rid, None)

    # -- HTTP plumbing -----------------------------------------------------
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            head = await reader.readline()
            if not head:
                return
            try:
                method, path, _ = head.decode("latin1").split()
            except ValueError:
                await self._respond(writer, 400, {"error": "bad request"})
                return
            path, _, query = path.partition("?")
            headers: Dict[str, str] = {}
            while True:
                line = await reader.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
                k, _, v = line.decode("latin1").partition(":")
                headers[k.strip().lower()] = v.strip()
            if headers.get("upgrade", "").lower() == "websocket":
                await self._handle_ws(reader, writer, headers)
            elif method == "GET" and path == "/healthz":
                await self._respond(writer, 200,
                                    {"ok": True, "stats": self.engine.stats()})
            elif method == "GET" and path == "/metrics":
                accept = headers.get("accept", "")
                if ("format=prometheus" in query or "text/plain" in accept
                        or "openmetrics" in accept):
                    await self._respond_text(writer,
                                             obs.metrics.prometheus_text())
                else:                       # back-compat JSON snapshot
                    await self._respond(writer, 200, self.metrics())
            elif method == "POST" and path == "/generate":
                body = await reader.readexactly(
                    int(headers.get("content-length", "0")))
                await self._handle_generate(writer, body)
            else:
                await self._respond(writer, 404, {"error": f"no {path}"})
        except (asyncio.IncompleteReadError, ConnectionResetError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    def metrics(self) -> Dict[str, Any]:
        return {"slo": self.telemetry.snapshot(),
                "rejected": dict(self.admission.rejected),
                "engine": self.engine.stats()}

    @staticmethod
    async def _respond(writer: asyncio.StreamWriter, status: int,
                       obj: Dict[str, Any]) -> None:
        body = json.dumps(obj).encode()
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  408: "Request Timeout", 429: "Too Many Requests"}.get(
                      status, "Error")
        writer.write(f"HTTP/1.1 {status} {reason}\r\n"
                     "Content-Type: application/json\r\n"
                     f"Content-Length: {len(body)}\r\n"
                     "Connection: close\r\n\r\n".encode() + body)
        await writer.drain()

    @staticmethod
    async def _respond_text(writer: asyncio.StreamWriter, text: str) -> None:
        body = text.encode()
        writer.write("HTTP/1.1 200 OK\r\n"
                     "Content-Type: text/plain; version=0.0.4; "
                     "charset=utf-8\r\n"
                     f"Content-Length: {len(body)}\r\n"
                     "Connection: close\r\n\r\n".encode() + body)
        await writer.drain()

    async def _handle_generate(self, writer: asyncio.StreamWriter,
                               body: bytes) -> None:
        try:
            payload = json.loads(body or b"{}")
            req = self.build_request(payload, time.perf_counter())
        except (ValueError, TypeError) as e:
            await self._respond(writer, 400, {"error": str(e)})
            return
        sub = await self.submit(req)
        if not payload.get("stream"):
            result = await self._collect(sub, writer)
            if result is not None:
                await self._respond(writer, 200, _result_json(result))
            return
        # chunked NDJSON streaming
        first = await sub.get()
        if first[0] == "reject":
            await self._reject_response(writer, first[1])
            return
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: application/x-ndjson\r\n"
                     b"Transfer-Encoding: chunked\r\n"
                     b"Connection: close\r\n\r\n")
        item = first
        while True:
            kind, val = item
            if kind == "event":
                line = json.dumps({"token": val.token, "logp": val.logp,
                                   "index": val.index}).encode() + b"\n"
            else:                                   # done
                line = json.dumps(_result_json(val)).encode() + b"\n"
            writer.write(f"{len(line):x}\r\n".encode() + line + b"\r\n")
            await writer.drain()
            if kind == "done":
                break
            item = await sub.get()
        writer.write(b"0\r\n\r\n")
        await writer.drain()

    async def _collect(self, sub: asyncio.Queue,
                       writer: asyncio.StreamWriter
                       ) -> Optional[GenerationResult]:
        while True:
            kind, val = await sub.get()
            if kind == "reject":
                await self._reject_response(writer, val)
                return None
            if kind == "done":
                return val

    async def _reject_response(self, writer: asyncio.StreamWriter,
                               decision) -> None:
        status = _REJECT_STATUS.get(decision.reason, 429)
        await self._respond(writer, status,
                            {"error": decision.reason,
                             "detail": decision.detail})

    # -- websocket ---------------------------------------------------------
    async def _handle_ws(self, reader: asyncio.StreamReader,
                         writer: asyncio.StreamWriter,
                         headers: Dict[str, str]) -> None:
        key = headers.get("sec-websocket-key", "")
        writer.write(("HTTP/1.1 101 Switching Protocols\r\n"
                      "Upgrade: websocket\r\nConnection: Upgrade\r\n"
                      f"Sec-WebSocket-Accept: {_ws_accept(key)}\r\n\r\n"
                      ).encode())
        await writer.drain()
        send_lock = asyncio.Lock()

        async def send_json(obj: Dict[str, Any]) -> None:
            async with send_lock:
                writer.write(_ws_frame(json.dumps(obj).encode()))
                await writer.drain()

        async def stream(sub: asyncio.Queue, client_id: Any) -> None:
            while True:
                kind, val = await sub.get()
                if kind == "reject":
                    await send_json({"id": client_id, "error": val.reason,
                                     "detail": val.detail})
                    return
                if kind == "event":
                    await send_json({"id": client_id, "token": val.token,
                                     "logp": val.logp, "index": val.index})
                else:
                    await send_json({"id": client_id,
                                     **_result_json(val)})
                    return

        tasks = []
        try:
            while True:
                opcode, data = await _ws_read_frame(reader)
                if opcode == 0x8:                   # close
                    writer.write(_ws_frame(data, opcode=0x8))
                    await writer.drain()
                    break
                if opcode == 0x9:                   # ping -> pong
                    writer.write(_ws_frame(data, opcode=0xA))
                    await writer.drain()
                    continue
                if opcode not in (0x1, 0x2):
                    continue
                try:
                    payload = json.loads(data)
                    req = self.build_request(payload, time.perf_counter())
                except (ValueError, TypeError) as e:
                    await send_json({"id": None, "error": "bad_request",
                                     "detail": str(e)})
                    continue
                sub = await self.submit(req)
                tasks.append(asyncio.ensure_future(
                    stream(sub, payload.get("id", req.rid))))
        except (asyncio.IncompleteReadError, ConnectionResetError):
            pass
        finally:
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)


def _result_json(res: GenerationResult) -> Dict[str, Any]:
    return {"tokens": [int(t) for t in res.tokens],
            "logps": [float(v) for v in res.logps],
            "finish_reason": res.finish_reason,
            "prompt_len": res.prompt_len,
            "prefix_hit_tokens": res.prefix_hit_tokens,
            "ttft_s": res.ttft_s, "latency_s": res.latency_s}


async def serve_forever(cfg: ModelConfig, params, serve: ServeConfig,
                        **kwargs) -> None:
    """Construct a FrontDoor and run until cancelled."""
    door = FrontDoor(cfg, params, serve, **kwargs)
    await door.start()
    print(f"[serving] listening on {serve.host}:{door.port} "
          f"(engine={serve.engine}, slots={serve.num_slots}, "
          f"pages={door.engine.num_pages})", flush=True)
    try:
        while True:
            await asyncio.sleep(3600)
    finally:
        await door.close()
