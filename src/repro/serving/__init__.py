"""SLO serving front door for the generation engines.

- :mod:`repro.serving.api` — the request-level vocabulary
  (``SamplingParams`` / ``Request`` / ``GenerationResult`` / ``Engine``);
- :mod:`repro.serving.admission` — admission control against the real KV
  page budget (feasibility, queue caps, deadline triage);
- :mod:`repro.serving.telemetry` — p50/p99 TTFT, per-slot throughput;
- :mod:`repro.serving.server` — the asyncio HTTP/websocket front door
  (imported lazily: it pulls in the engines, which import this package's
  ``api`` module).
"""
from repro.serving.admission import (EXPIRED, INFEASIBLE, OK, OVERLOADED,
                                     QUEUE_FULL, AdmissionController,
                                     AdmissionDecision)
from repro.serving.api import (Engine, GenerationResult, Request,
                               SamplingParams, TokenEvent)
from repro.serving.telemetry import ServeTelemetry

__all__ = ["SamplingParams", "Request", "GenerationResult", "TokenEvent",
           "Engine", "AdmissionController", "AdmissionDecision",
           "ServeTelemetry", "OK", "INFEASIBLE", "EXPIRED", "QUEUE_FULL",
           "OVERLOADED"]
