"""Admission control for the serving front door.

The scheduler already guarantees the hard invariant — a request's whole
KV page budget is reserved when it *binds to a slot*, so nothing is ever
dropped mid-decode. What it cannot do is bound how much demand piles up
in front of the slots. The front door closes that gap by refusing
requests the deployment can't credibly serve, at arrival time, with a
reason the client can act on:

- ``infeasible`` — prompt + token budget exceed ``max_total_tokens``
  (would raise at admission; reject it at the door instead);
- ``expired`` — the deadline already passed on arrival;
- ``queue_full`` — more than ``ServeConfig.max_queue`` requests waiting;
- ``overloaded`` — the *pages* promised to queued requests (net of
  shared-prefix reuse) would exceed ``queue_overcommit`` turns of the
  page pool: the queue may hold a bounded multiple of what the pool
  serves per drain, beyond that new arrivals are shed rather than
  building an unbounded TTFT tail.

Everything here is a pure read of scheduler/allocator state — the
controller holds no state of its own, so it can't drift from the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.config import ServeConfig
from repro.sampling.paged_cache import pages_for
from repro.serving.api import Request

OK = "ok"
INFEASIBLE = "infeasible"
EXPIRED = "expired"
QUEUE_FULL = "queue_full"
OVERLOADED = "overloaded"


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    admitted: bool
    reason: str                 # one of the module constants
    detail: str = ""

    def __bool__(self) -> bool:
        return self.admitted


class AdmissionController:
    """Decide, per arriving request, whether the engine should queue it."""

    def __init__(self, serve: ServeConfig, engine) -> None:
        self.serve = serve
        self.engine = engine
        self.rejected = {INFEASIBLE: 0, EXPIRED: 0, QUEUE_FULL: 0,
                         OVERLOADED: 0}

    def _queued_pages(self) -> int:
        """KV pages promised to requests still waiting in the scheduler's
        priority queues (their budgets are not yet reserved — admission
        reserves — so the controller must count them itself)."""
        sched = self.engine.sched
        return sum(pages_for(r.total_len, self.serve.page_size)
                   for q in sched.queues.values() for r in q)

    def check(self, req: Request,
              now_s: Optional[float] = None) -> AdmissionDecision:
        serve, eng = self.serve, self.engine
        need = pages_for(req.prompt_len + req.params.max_new_tokens,
                         serve.page_size)
        if need > eng.pages_per_slot:
            return self._reject(
                INFEASIBLE,
                f"{req.prompt_len}+{req.params.max_new_tokens} tokens need "
                f"{need} pages > {eng.pages_per_slot} per slot "
                f"(max_total_tokens={serve.max_total_tokens})")
        if (req.deadline_s is not None and now_s is not None
                and now_s > req.deadline_s):
            return self._reject(EXPIRED, "deadline passed before admission")
        depth = eng.sched.queue_depth
        if depth >= serve.max_queue:
            return self._reject(QUEUE_FULL,
                                f"{depth} requests queued >= max_queue="
                                f"{serve.max_queue}")
        # shed load once queued demand exceeds the overcommit budget —
        # the shared-prefix cache effectively enlarges the pool for
        # prompts it already holds, so count only the pages this request
        # would newly allocate
        if eng.prefix_cache is not None:
            m, shared, cow = eng.prefix_cache.peek(req.prompt)
            need -= len(shared)
        capacity = eng.num_pages - 1            # page 0 is scratch
        budget = capacity * serve.queue_overcommit
        promised = self._queued_pages()
        if promised + need > budget:
            return self._reject(
                OVERLOADED,
                f"{promised} pages already promised to the queue + {need} "
                f"> {serve.queue_overcommit:g}x pool capacity {capacity}")
        return AdmissionDecision(True, OK)

    def _reject(self, reason: str, detail: str) -> AdmissionDecision:
        self.rejected[reason] += 1
        return AdmissionDecision(False, reason, detail)

    @property
    def rejected_total(self) -> int:
        return sum(self.rejected.values())
