"""Request-level serving API: the one vocabulary every engine speaks.

This replaces the old ``generate(cfg, rl, params, prompts, engine=,
slots=, page_size=, sync_every=, ...)`` keyword soup with three small
types and a protocol:

- :class:`SamplingParams` — *how* to sample (temperature/top-k/top-p,
  token budget), validated at construction so meaningless combinations
  fail loudly instead of being silently dropped;
- :class:`Request` — *what* to generate (prompt tokens) plus its SLO
  envelope (priority class, absolute deadline, arrival time);
- :class:`GenerationResult` — the per-request outcome (tokens, engine
  log-probs as App. B.1 metadata, finish reason, latency telemetry);
- :class:`Engine` — the protocol both the static scan engine and the
  continuous-batching engine implement. Engine *capacity* knobs (slots,
  page size, decode horizon, pool size, mesh) live in
  ``repro.config.ServeConfig``, not here: sampling parameters describe a
  request, serve config describes a deployment.

``TokenEvent`` is the streaming unit the continuous engine emits per
scheduler sync — the asyncio front door (``repro.serving.server``) fans
these out to HTTP/websocket subscribers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.config import RLConfig


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling settings.

    Validation raises on out-of-range or conflicting values (the old
    ``generate`` dropped them on the floor): ``temperature < 0``,
    ``top_k < 0``, ``top_p`` outside ``(0, 1]``, a non-positive token
    budget, and greedy/filter conflicts (``temperature == 0`` with
    ``top_k``/``top_p`` filtering — the filters would select from a
    distribution the zero temperature then ignores).
    """
    temperature: float = 0.6
    top_k: int = 20
    top_p: float = 0.95
    max_new_tokens: int = 32
    # opt this request in to speculative decode when the engine runs
    # with spec_k > 0 (acceptance preserves the sampled distribution
    # exactly, so this is a latency knob, not a quality one; opted-out
    # requests still verify through the same executable with an empty
    # draft window). Not part of ``profile`` — spec never changes which
    # decode distribution a request samples from.
    spec: bool = True

    def __post_init__(self) -> None:
        if not math.isfinite(self.temperature) or self.temperature < 0:
            raise ValueError(f"temperature={self.temperature} must be a "
                             "finite value >= 0")
        if self.top_k < 0:
            raise ValueError(f"top_k={self.top_k} must be >= 0 (0 = off)")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p={self.top_p} outside (0, 1]")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens={self.max_new_tokens} < 1")
        if self.temperature == 0.0 and (self.top_k > 0 or self.top_p < 1.0):
            raise ValueError(
                "temperature=0 (greedy) conflicts with top_k/top_p "
                "filtering — drop the filters or use temperature > 0")

    @property
    def profile(self) -> tuple:
        """The (temperature, top_k, top_p) triple that keys a jitted
        decode executable. Requests sharing an engine step must share
        it; ``max_new_tokens`` is per-request (a traced vector)."""
        return (self.temperature, self.top_k, self.top_p)

    @classmethod
    def from_rl(cls, rl: RLConfig,
                max_new: Optional[int] = None) -> SamplingParams:
        return cls(temperature=rl.temperature, top_k=rl.top_k,
                   top_p=rl.top_p,
                   max_new_tokens=max_new or rl.max_new_tokens)

    def rl(self, base: Optional[RLConfig] = None) -> RLConfig:
        """An RLConfig carrying this profile (the engines' jit-static
        sampling argument)."""
        base = base or RLConfig()
        return dataclasses.replace(base, temperature=self.temperature,
                                   top_k=self.top_k, top_p=self.top_p,
                                   max_new_tokens=self.max_new_tokens)


@dataclasses.dataclass
class Request:
    """One generation request. ``rid`` is the identity *and* the RNG
    stream: token draws use ``fold_in(fold_in(key, rid), t)``, so the
    same (key, rid) yields the same completion on any engine, any slot.
    ``deadline_s`` is an absolute clock value (same clock as
    ``arrival_s``): a request still queued past it is expired, never
    one that is already decoding."""
    rid: int
    prompt: np.ndarray
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    priority: int = 1
    deadline_s: Optional[float] = None
    arrival_s: float = 0.0

    def __post_init__(self) -> None:
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.shape[0] < 1:
            raise ValueError("prompt must be a non-empty 1-D token array, "
                             f"got shape {self.prompt.shape}")
        if self.priority < 0:
            raise ValueError(f"priority={self.priority} must be >= 0")
        if self.deadline_s is not None and self.deadline_s <= self.arrival_s:
            raise ValueError(f"deadline_s={self.deadline_s} not after "
                             f"arrival_s={self.arrival_s}")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One streamed token (or terminal event) of a request."""
    rid: int
    token: int                   # PAD on a tokenless terminal event
    logp: float
    index: int                   # 0-based position in the completion
    finished: bool = False
    finish_reason: str = ""      # set when finished


@dataclasses.dataclass
class GenerationResult:
    """Per-request outcome. ``logps`` are engine-side *metadata* (the
    learner recomputes by default, App. B.1). ``ttft_s``/``latency_s``
    are measured against ``Request.arrival_s`` on the submitter's
    clock; ``prefix_hit_tokens`` counts prompt tokens served from the
    shared-prefix cache instead of being prefilled."""
    rid: int
    tokens: np.ndarray           # (n,) int32 completion (includes EOS)
    logps: np.ndarray            # (n,) float32
    finish_reason: str           # "eos" | "length" | "expired"
    prompt_len: int
    prefix_hit_tokens: int = 0
    ttft_s: float = float("nan")
    latency_s: float = float("nan")

    @property
    def gen_count(self) -> int:
        return int(self.tokens.shape[0])


@runtime_checkable
class Engine(Protocol):
    """What every generation engine offers the serving layer. Static and
    continuous engines both implement it; the continuous engine
    additionally offers the incremental ``submit()``/``step()`` surface
    the asyncio front door streams from."""

    def generate(self, requests: Sequence[Request],
                 key: Optional[Any] = None) -> List[GenerationResult]:
        """Run ``requests`` to completion, results in request order."""
        ...

    def update_params(self, params: Any) -> None:
        """Swap in new model parameters (sampler weight sync)."""
        ...
