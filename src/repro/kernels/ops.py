"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode for
correctness validation; on a real TPU ``interpret=False`` compiles via
Mosaic. ``use_pallas()`` gates which backend the model layer picks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_logprob import (chunked_logprob as _chunked_logprob,
                                         fused_logprob as _fused_logprob)
from repro.kernels.paged_attention import (paged_attention as _paged,
                                           paged_decode_ref as _paged_ref,
                                           paged_prefill as _paged_prefill,
                                           paged_prefill_ref as
                                           _paged_prefill_ref)
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, interpret=interp)


PAGED_IMPLS = ("auto", "pallas", "ref", "gather")


@functools.partial(jax.jit, static_argnames=("kind", "window", "softcap",
                                             "impl", "interpret"))
def paged_decode(q, kp, vp, page_table, lengths, *, kind: str = "causal",
                 window: Optional[int] = None,
                 softcap: Optional[float] = None,
                 impl: Optional[str] = None,
                 interpret: Optional[bool] = None):
    """Decode-step attention against paged KV pools — the serving hot loop.

    q (B, 1, Hq, D) one query token per slot (``decode_attention``'s
    layout); kp/vp (num_pages, page_size, Hkv, D) page pools; page_table
    (B, npages); lengths (B,) valid tokens per slot (current token's k/v
    already scattered). Returns (B, 1, Hq, D).

    ``impl`` selects the backend (``ModelConfig.paged_attn_impl``):
      - "gather" (the ModelConfig default): materialize the logical
        (B, npages·page_size, Hkv, D) view and run ``decode_attention``
        over it — bit-identical to the pre-kernel path (the static ≡
        continuous engine parity contract), O(npages) bytes/token. The
        engine narrows ``page_table`` to the live high-water mark before
        calling, so even this path stops touching the whole pool.
      - "ref": ``paged_decode_ref`` — per-page online softmax, no
        materialized view, GSPMD-native (kv-heads shard over 'model').
      - "pallas": the Mosaic kernel, pages DMA'd in place. pallas_call
        has no GSPMD partitioning rules: on a multi-device mesh call it
        under shard_map with kv-heads (and the grouped q heads) split
        over 'model' — same caveat as ``fused_token_logprob``.
      - None / "auto": pallas on TPU, ref elsewhere.

    ``kind``/``window`` follow ``decode_attention``: the sliding-window
    band applies only when kind == "local".
    """
    if impl not in PAGED_IMPLS + (None,):
        raise ValueError(f"unknown paged-attention impl {impl!r}")
    if impl in (None, "auto"):
        impl = "pallas" if on_tpu() else "ref"
    if kind not in ("causal", "local"):
        raise ValueError(f"paged decode is causal-only, got kind={kind!r}")
    eff_window = window if kind == "local" else None
    if impl == "gather":
        from repro.models.attention import decode_attention
        b = q.shape[0]
        npages, page_size = page_table.shape[1], kp.shape[1]
        lview = npages * page_size
        kv_shape = (b, lview, kp.shape[2], kp.shape[3])
        kc = kp[page_table].reshape(kv_shape)
        vc = vp[page_table].reshape(kv_shape)
        return decode_attention(q, kc, vc, pos=lengths - 1, kind=kind,
                                window=window, softcap=softcap)
    if impl == "ref":
        o = _paged_ref(q[:, 0], kp, vp, page_table, lengths,
                       window=eff_window, softcap=softcap)
    else:
        interp = (not on_tpu()) if interpret is None else interpret
        o = _paged(q[:, 0], kp, vp, page_table, lengths,
                   window=eff_window, softcap=softcap, interpret=interp)
    return o[:, None]


@functools.partial(jax.jit, static_argnames=("kind", "window", "softcap",
                                             "impl", "attn_impl", "chunk",
                                             "interpret"))
def paged_prefill(q, kp, vp, page_table, positions, *, kind: str = "causal",
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  impl: Optional[str] = None, attn_impl: str = "chunked",
                  chunk: int = 512, interpret: Optional[bool] = None):
    """Chunked-prefill attention against paged KV pools — long-prompt
    admission's hot loop.

    q (B, C, Hq, D) one C-token query chunk per slot; kp/vp
    (num_pages, page_size, Hkv, D) page pools (the chunk's k/v already
    scattered in); page_table (B, npages); positions (B, C) absolute
    query positions, ``starts[slot] + arange(C)`` — contiguous per slot.
    Returns (B, C, Hq, D).

    ``impl`` selects the backend (``ModelConfig.paged_attn_impl``):
      - "gather" (the ModelConfig default): materialize the logical
        (B, npages·page_size, Hkv, D) view and run dense ``attention``
        over it — bit-identical to the pre-kernel chunked-prefill branch
        of ``models/model.py`` (the static ≡ continuous parity
        contract), O(table width) bytes/chunk. ``attn_impl``/``chunk``
        feed through to that dense attention (the flash kernel assumes
        pos_q = arange(Sq), so "pallas" downgrades to "chunked").
      - "ref": ``paged_prefill_ref`` — per-page online softmax, no dense
        view, bytes scale with the batch-max live page count.
      - "pallas": the Mosaic kernel; unreachable pages re-point in the
        index map, so bytes scale with ``pages_for(starts + C)``. Like
        ``paged_decode``, wrap in shard_map to split kv heads on a mesh.
      - None / "auto": pallas on TPU, ref elsewhere.
    """
    if impl not in PAGED_IMPLS + (None,):
        raise ValueError(f"unknown paged-attention impl {impl!r}")
    if impl in (None, "auto"):
        impl = "pallas" if on_tpu() else "ref"
    if kind not in ("causal", "local"):
        raise ValueError(f"paged prefill is causal-only, got kind={kind!r}")
    eff_window = window if kind == "local" else None
    if impl == "gather":
        from repro.models.attention import attention
        b = q.shape[0]
        npages, page_size = page_table.shape[1], kp.shape[1]
        lview = npages * page_size
        kv_shape = (b, lview, kp.shape[2], kp.shape[3])
        kc = kp[page_table].reshape(kv_shape)             # slot's logical view
        vc = vp[page_table].reshape(kv_shape)
        pos_k = jnp.broadcast_to(jnp.arange(lview), (b, lview))
        # the Pallas flash kernel assumes pos_q = arange(Sq): chunked
        # prefill runs at an offset, so it drops to the jnp twin
        a_impl = "chunked" if attn_impl == "pallas" else attn_impl
        return attention(q, kc, vc, pos_q=positions, pos_k=pos_k,
                         kind=kind, window=window, softcap=softcap,
                         impl=a_impl, chunk=chunk)
    starts = positions[:, 0].astype(jnp.int32)
    lengths = (positions[:, -1] + 1).astype(jnp.int32)
    if impl == "ref":
        return _paged_prefill_ref(q, kp, vp, page_table, lengths, starts,
                                  window=eff_window, softcap=softcap)
    interp = (not on_tpu()) if interpret is None else interpret
    return _paged_prefill(q, kp, vp, page_table, lengths, starts,
                          window=eff_window, softcap=softcap,
                          interpret=interp)


def _fold_layers(q, kp, vp, page_table, lengths):
    """Fold a leading layer axis into the slot axis so ONE kernel launch
    serves every layer's pools.

    q (L, B, ...), kp/vp (L, P, page, Hkv, D), page_table (B, W),
    lengths (B,) → per-layer operands stacked along slots: the pools
    concatenate to (L·P, ...), and layer l's table rows offset by l·P so
    they index the l-th pool slab. Slots never mix across grid steps, so
    the folded launch is bit-exact vs L per-layer launches — it just
    amortizes one grid setup and one scalar-prefetch DMA over all
    layers instead of paying them L times.
    """
    lyr, pool_pages = q.shape[0], kp.shape[1]
    b = q.shape[1]
    kpf = kp.reshape((lyr * pool_pages,) + kp.shape[2:])
    vpf = vp.reshape((lyr * pool_pages,) + vp.shape[2:])
    offs = (jnp.arange(lyr, dtype=jnp.int32) * pool_pages)[:, None, None]
    tablef = (page_table.astype(jnp.int32)[None] + offs).reshape(lyr * b, -1)
    lengthsf = jnp.broadcast_to(lengths, (lyr,) + lengths.shape
                                ).reshape(lyr * b)
    qf = q.reshape((lyr * b,) + q.shape[2:])
    return qf, kpf, vpf, tablef, lengthsf


@functools.partial(jax.jit, static_argnames=("kind", "window", "softcap",
                                             "impl", "interpret"))
def paged_decode_layers(q, kp, vp, page_table, lengths, *,
                        kind: str = "causal", window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        impl: Optional[str] = None,
                        interpret: Optional[bool] = None):
    """``paged_decode`` over all layers' pools in ONE launch.

    q (L, B, 1, Hq, D) per-layer queries; kp/vp (L, P, page, Hkv, D)
    stacked pools (the scanned-block layout of ``init_paged_cache``);
    page_table (B, W) and lengths (B,) shared by every layer. Returns
    (L, B, 1, Hq, D), bit-exact vs L separate ``paged_decode`` calls.

    Inside the model's forward pass layer l's *query* depends on layer
    l-1's output, so the block scan cannot use this; it serves callers
    that already hold all layers' queries (speculative scoring, KV-pool
    maintenance sweeps) and pins the launch-count/bit-exactness claim
    the benchmarks measure.
    """
    lyr, b = q.shape[0], q.shape[1]
    qf, kpf, vpf, tablef, lengthsf = _fold_layers(q, kp, vp, page_table,
                                                  lengths)
    o = paged_decode(qf, kpf, vpf, tablef, lengthsf, kind=kind,
                     window=window, softcap=softcap, impl=impl,
                     interpret=interpret)
    return o.reshape((lyr, b) + o.shape[1:])


@functools.partial(jax.jit, static_argnames=("kind", "window", "softcap",
                                             "impl", "attn_impl", "chunk",
                                             "interpret"))
def paged_prefill_layers(q, kp, vp, page_table, positions, *,
                         kind: str = "causal", window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         impl: Optional[str] = None,
                         attn_impl: str = "chunked", chunk: int = 512,
                         interpret: Optional[bool] = None):
    """``paged_prefill`` over all layers' pools in ONE launch: q
    (L, B, C, Hq, D), kp/vp (L, P, page, Hkv, D), positions (B, C)
    shared across layers. Returns (L, B, C, Hq, D), bit-exact vs L
    separate calls — same layer-folding as ``paged_decode_layers``."""
    lyr, b = q.shape[0], q.shape[1]
    lengths = (positions[:, -1] + 1).astype(jnp.int32)
    qf, kpf, vpf, tablef, _ = _fold_layers(q, kp, vp, page_table, lengths)
    posf = jnp.broadcast_to(positions, (lyr,) + positions.shape
                            ).reshape((lyr * b,) + positions.shape[1:])
    o = paged_prefill(qf, kpf, vpf, tablef, posf, kind=kind, window=window,
                      softcap=softcap, impl=impl, attn_impl=attn_impl,
                      chunk=chunk, interpret=interpret)
    return o.reshape((lyr, b) + o.shape[1:])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log_neg, b, c, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _ssd_scan(x, dt, a_log_neg, b, c, chunk=chunk, interpret=interp)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v",
                                             "interpret"))
def fused_logprob(logits, targets, *, block_t: int = 256,
                  block_v: int = 2048, interpret: Optional[bool] = None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _fused_logprob(logits, targets, block_t=block_t, block_v=block_v,
                          interpret=interp)


def _largest_divisor(n: int, cap: int, mult: int) -> int:
    """Largest d ≤ cap with n % d == 0 and d % mult == 0 (0 if none) —
    picks a Pallas tile size that exactly divides real model shapes
    (padded vocabs are 256-aligned, not block_v-aligned; token counts
    are B·(S−1))."""
    for d in range(min(cap, n) - min(cap, n) % mult, 0, -mult):
        if n % d == 0:
            return d
    return 0


@functools.partial(jax.jit, static_argnames=("impl", "block_t", "block_v",
                                             "chunk", "interpret"))
def fused_token_logprob(logits, targets, *, impl: Optional[str] = None,
                        block_t: int = 256, block_v: int = 2048,
                        chunk: int = 256,
                        interpret: Optional[bool] = None):
    """Training-stack entry for memory-bounded token log-probs.

    logits (..., V) [any float dtype], targets (...,) int ->
    (logp (...,), entropy (...,)), both f32 — differentiable w.r.t.
    ``logits`` with a streaming backward (no V-sized f32 activation in
    either pass; see ``repro.kernels.fused_logprob``).

    ``impl`` selects the backend:
      - None (default): Pallas on TPU, chunked pure-JAX elsewhere;
      - "pallas" / "chunked": forced (pallas still falls back to
        chunked when T or V doesn't divide by the block sizes);
      - "naive": the materializing log-softmax reference
        (``repro.core.logprob``) — for A/B benchmarks and debugging.

    Out-of-range target ids are clamped to [0, V) (masked positions may
    carry any id — the padding contract of ``repro.core.logprob``).
    """
    from repro.core.logprob import token_logprob_and_entropy
    if impl not in (None, "pallas", "chunked", "naive"):
        raise ValueError(f"unknown logprob impl {impl!r}")
    if impl == "naive":
        return token_logprob_and_entropy(logits, targets)
    if impl is None:
        impl = "pallas" if on_tpu() else "chunked"
    if logits.ndim == 1:                       # single token, no batch dim
        lp, ent = fused_token_logprob(
            logits[None], targets.reshape((1,)), impl=impl,
            block_t=block_t, block_v=block_v, chunk=chunk,
            interpret=interpret)
        return lp.reshape(targets.shape), ent.reshape(targets.shape)
    lead, v = logits.shape[:-1], logits.shape[-1]
    if impl == "pallas":
        # the kernel takes flat (T, V); shrink the tiles to the largest
        # hardware-aligned divisors of the actual shape (t = B·(S−1) and
        # 256-aligned padded vocabs rarely divide the default blocks).
        # NOTE pallas_call has no GSPMD partitioning rules: on a
        # multi-device mesh, call this under shard_map so the kernel
        # sees per-device (T, V) shards — under plain GSPMD the flatten
        # below would merge a data-sharded batch axis into the token
        # axis and replicate the logits. The chunked branch is
        # GSPMD-native (shard-local token-axis slices) and is what the
        # CPU dry-run grid lowers.
        t = int(np.prod(lead))
        bt = _largest_divisor(t, block_t, 8) or (t if t < 8 else 0)
        bv = _largest_divisor(v, block_v, 128) or (v if v < 128 else 0)
        if bt and bv:
            interp = (not on_tpu()) if interpret is None else interpret
            lp, ent = _fused_logprob(logits.reshape((-1, v)),
                                     targets.reshape((-1,)),
                                     block_t=bt, block_v=bv,
                                     interpret=interp)
            return lp.reshape(lead), ent.reshape(lead)
    # chunked keeps the (..., T, V) layout: the token axis is chunked in
    # place so data-sharded batch axes never get flattened into the
    # sliced axis (GSPMD would otherwise replicate the whole logits)
    return _chunked_logprob(logits, targets, chunk=chunk)
