"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode for
correctness validation; on a real TPU ``interpret=False`` compiles via
Mosaic. ``use_pallas()`` gates which backend the model layer picks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_logprob import fused_logprob as _fused_logprob
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log_neg, b, c, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _ssd_scan(x, dt, a_log_neg, b, c, chunk=chunk, interpret=interp)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v",
                                             "interpret"))
def fused_logprob(logits, targets, *, block_t: int = 256,
                  block_v: int = 2048, interpret: Optional[bool] = None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _fused_logprob(logits, targets, block_t=block_t, block_v=block_v,
                          interpret=interp)
