"""Flash attention Pallas TPU kernel (forward).

Grid (B·Hq, n_q_blocks, n_kv_blocks), kv innermost so the online-softmax
accumulators (m, l, acc) live in VMEM scratch across kv iterations. GQA is
resolved in the kv BlockSpec index map (kv head = q head // rep). Causal
and sliding-window masks are applied from absolute block offsets; fully
masked kv blocks skip compute via ``pl.when``.

Block shapes default to (128, head_dim) — MXU-aligned on the 128 lane
dimension. VMEM working set per step ≈ (bq+2·bk)·D + bq·bk scores.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fit_block(n: int, cap: int) -> int:
    """Largest block ≤ cap that divides n exactly, preferring 8-aligned
    sublane counts — the fused_logprob trick, so real model shapes
    (e.g. Sq = 160 or odd tails) hit the kernel instead of asserting."""
    cap = min(cap, n)
    for b in range(cap - cap % 8, 0, -8):
        if n % b == 0:
            return b
    for b in range(cap, 0, -1):
        if n % b == 0:
            return b
    return n


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], bq: int, bk: int, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    # block-level reachability: skip kv blocks that are fully masked
    reachable = True
    if causal:
        reachable = k_start <= q_start + bq - 1
    if window is not None:
        reachable = jnp.logical_and(
            reachable, k_start + bk - 1 > q_start - window)

    @pl.when(reachable)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                               # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= rows >= cols
        if window is not None:
            mask &= rows - cols < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False
                    ) -> jax.Array:
    """q (B, Sq, Hq, D); k, v (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    bq = _fit_block(sq, block_q)
    bk = _fit_block(sk, block_k)
    nq, nk = sq // bq, sk // bk

    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)

    def kv_index(bh, iq, ik):
        batch = bh // hq
        head = bh % hq
        return (batch * hkv + head // rep, ik, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=d ** -0.5, causal=causal,
                          window=window, softcap=softcap, bq=bq, bk=bk,
                          nk=nk),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
