"""Paged-attention decode + chunked-prefill Pallas TPU kernels (+ twins).

One decode step of the continuous-batching engine attends a single query
token per slot against that slot's KV pages *in place* — the pools from
``repro.sampling.paged_cache`` are never regathered into a dense
``(B, pages_per_slot·page_size, Hkv, D)`` logical view (the legacy path's
O(pool) HBM traffic per token; see ``repro.kernels.ops.paged_decode``).

Kernel layout:

- grid ``(slot, kv_head, logical_page)`` with the page axis innermost so
  the online-softmax accumulators (m, l, acc) live in VMEM scratch across
  page iterations — the flash-attention recurrence over pages;
- the block table and per-slot ``lengths`` ride in as scalar-prefetch
  operands (``pltpu.PrefetchScalarGridSpec``), so the kv BlockSpec index
  map resolves ``table[slot, j]`` to a physical page id before each grid
  step issues its DMA;
- pages at or past ``ceil(lengths[slot]/page_size)`` are *dead*: their
  index map re-points at the slot's last live page (same block index ⇒
  Pallas skips the copy — no DMA, and ``pl.when`` skips the compute), so
  bytes and FLOPs scale with the slot's true context length, not the
  allocator's ``pages_per_slot`` capacity;
- GQA is resolved in the index maps: all ``rep = Hq // Hkv`` query heads
  of one kv head run in a single kernel instance against one page fetch;
- masking matches ``repro.models.attention.decode_attention``: key
  positions ``idx <= pos`` (with ``pos = lengths - 1``), plus the
  sliding-window band and attention-logit softcap. Masked positions are
  zeroed in ``v`` (not just NEG_INF'd in the scores) so garbage in dead
  page tails — scratch-page contents included, even NaNs — can never
  reach a live slot's output.

``paged_decode_ref`` is the jnp twin (``lax.fori_loop`` over live pages
with running (m, l, acc)): the CPU oracle and the lowering path, the same
pairing as ``chunked_attention`` ↔ ``flash_attention``. Its loop bound is
the *batch-max* live page count, so its bytes also scale with occupancy
rather than pool capacity.

``paged_prefill`` extends the same layout to a whole prefill *chunk*: a
(B, C, Hq, D) block of queries per slot starting at per-slot offset
``c0 = starts[slot]`` (query row i sits at absolute position c0 + i and
attends kv positions ≤ c0 + i). Grid ``(slot, q_tile, kv_head, page)``;
the block table plus per-slot ``lengths`` *and* ``starts`` ride in as
scalar-prefetch operands so the kv index map can clamp the logical page
to the tile's causal reach — pages past ``(c0 + tile_end) // page_size``
(and, with a sliding window, before the tile's window floor) re-point at
the nearest reachable page, so bytes/chunk scale with
``pages_for(c0 + C)`` rather than the table width the caller padded to.
``paged_prefill_ref`` is its ``fori_loop`` jnp twin, same contract.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask_scores_and_values(s, v, j, page_size, length, window):
    """Apply the decode validity band to one page block.

    s (R, page) scores, v (page, D) values; returns masked (s, v) where
    invalid key positions are NEG_INF in s and *zero* in v — the zeroing
    is what keeps NaN/garbage in unwritten page tails out of ``p @ v``.
    """
    def band(col):
        ok = col < length
        if window is not None:
            ok &= col > length - 1 - window
        return ok

    cols_s = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    cols_v = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (page_size, 1), 0)
    s = jnp.where(band(cols_s), s, NEG_INF)
    v = jnp.where(band(cols_v), v, 0.0)
    return s, v


def _kernel(table_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, window: Optional[int],
            softcap: Optional[float], page_size: int, npages: int):
    s_id = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[s_id]
    live = j * page_size < length

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)               # (rep, D)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                     # (rep, page)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s, v = _mask_scores_and_values(s, v, j, page_size, length, window)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(j == npages - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention(q: jax.Array, kp: jax.Array, vp: jax.Array,
                    page_table: jax.Array, lengths: jax.Array, *,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: bool = False) -> jax.Array:
    """Decode-step attention against paged KV pools, in place.

    q (B, Hq, D) single query token per slot; kp/vp
    (num_pages, page_size, Hkv, D) page pools; page_table (B, npages)
    int32 slot→physical-page map; lengths (B,) int32 valid tokens per
    slot (``pos + 1`` — the current token's k/v must already be
    scattered into the pools). Returns (B, Hq, D) in q.dtype.
    """
    b, hq, d = q.shape
    num_pages, page_size, hkv, dk = kp.shape
    assert d == dk and hq % hkv == 0, (q.shape, kp.shape)
    rep = hq // hkv
    npages = page_table.shape[1]
    qr = q.reshape(b, hkv, rep, d)

    def q_map(s, h, j, table_ref, lengths_ref):
        del table_ref, lengths_ref, j
        return (s, h, 0, 0)

    def kv_map(s, h, j, table_ref, lengths_ref):
        # dead pages re-point at the slot's last live page: identical
        # consecutive block indices make Pallas skip the DMA, and the
        # body's pl.when(live) skips the compute.
        length = lengths_ref[s]
        last_live = jnp.maximum(pl.cdiv(length, page_size) - 1, 0)
        jj = jnp.minimum(j, last_live)
        return (table_ref[s, jj], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, npages),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d), q_map),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=d ** -0.5, window=window,
                          softcap=softcap, page_size=page_size,
                          npages=npages),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), qr, kp, vp)
    return out.reshape(b, hq, d)


def paged_decode_ref(q: jax.Array, kp: jax.Array, vp: jax.Array,
                     page_table: jax.Array, lengths: jax.Array, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None) -> jax.Array:
    """Pure-JAX twin of ``paged_attention``: ``fori_loop`` over logical
    pages with running (m, l, acc), bounded by the batch-max live page
    count so work scales with occupancy. Same shapes/semantics as the
    kernel; this is the CPU oracle and the GSPMD-native lowering path
    (the per-page gather partitions cleanly with kv-heads on 'model')."""
    b, hq, d = q.shape
    page_size, hkv = kp.shape[1], kp.shape[2]
    rep = hq // hkv
    npages = page_table.shape[1]
    scale = d ** -0.5
    # keep every pool-sized operand in the pool dtype and upcast inside
    # the dots (preferred_element_type): an explicit kp.astype(f32) is
    # loop-invariant, so XLA hoists it and converts the *entire pool*
    # once — the O(pool) temp buffer this path exists to avoid.
    qg = q.reshape(b, hkv, rep, d).astype(kp.dtype)
    table = page_table.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    def body(j, carry):
        m_run, l_run, acc = carry
        phys = jax.lax.dynamic_slice_in_dim(table, j, 1, axis=1)[:, 0]
        k = kp[phys]                                      # (B, page, Hkv, D)
        v = vp[phys]
        s = jnp.einsum("bgrd,bpgd->bgrp", qg, k,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        idx = j * page_size + jnp.arange(page_size)
        valid = idx[None, :] < lengths[:, None]           # (B, page)
        if window is not None:
            valid &= idx[None, :] > lengths[:, None] - 1 - window
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        # zero masked values so garbage/NaN in dead tails (scratch page
        # included) can never reach a live slot through 0 * NaN
        v = jnp.where(valid[:, :, None, None], v, jnp.zeros((), v.dtype))
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgrp,bpgd->bgrd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((b, hkv, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep), jnp.float32)
    a0 = jnp.zeros((b, hkv, rep, d), jnp.float32)
    n_live = jnp.clip(-(-jnp.max(lengths) // page_size), 0, npages)
    _, l_f, acc = jax.lax.fori_loop(0, n_live, body, (m0, l0, a0))
    o = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return o.reshape(b, hq, d).astype(q.dtype)


def _prefill_kernel(table_ref, lengths_ref, starts_ref, q_ref, k_ref, v_ref,
                    o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                    window: Optional[int], softcap: Optional[float],
                    page_size: int, npages: int, bq: int, rep: int):
    s_id = pl.program_id(0)
    iq = pl.program_id(1)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[s_id]
    q0 = starts_ref[s_id] + iq * bq                  # tile row 0, absolute
    last_live = jnp.maximum(pl.cdiv(length, page_size) - 1, 0)
    last_reach = jnp.minimum(last_live, (q0 + bq - 1) // page_size)
    if window is not None:
        first_reach = jnp.maximum((q0 - window + 1) // page_size, 0)
    else:
        first_reach = 0
    live = (j >= first_reach) & (j <= last_reach)

    @pl.when(live)
    def _body():
        rows = bq * rep
        q = q_ref[0, :, 0].reshape(rows, -1).astype(jnp.float32)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                     # (rows, page)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        # row r of the flattened tile is query position q0 + r // rep
        # (the rep grouped heads of one query token are adjacent rows)
        pos_q = q0 + jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 0) // rep
        col = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 1)
        ok = col <= pos_q
        if window is not None:
            ok &= col > pos_q - window
        s = jnp.where(ok, s, NEG_INF)
        # zero v past the slot's length so NaN/garbage in the unwritten
        # tail of the last live page can never reach the output via 0·NaN
        col_v = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (page_size, 1), 0)
        v = jnp.where(col_v < length, v, 0.0)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(j == npages - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        o_ref[0, :, 0] = o.reshape(bq, rep, -1)


def paged_prefill(q: jax.Array, kp: jax.Array, vp: jax.Array,
                  page_table: jax.Array, lengths: jax.Array,
                  starts: jax.Array, *, window: Optional[int] = None,
                  softcap: Optional[float] = None, block_q: int = 128,
                  interpret: bool = False) -> jax.Array:
    """One chunked-prefill step against paged KV pools, in place.

    q (B, C, Hq, D): a C-token query chunk per slot whose row i sits at
    absolute position ``starts[slot] + i``; kp/vp
    (num_pages, page_size, Hkv, D) page pools with the chunk's k/v
    already scattered in; page_table (B, npages) int32; lengths (B,)
    int32 total valid tokens per slot (``starts + C`` for a full chunk);
    starts (B,) int32 chunk offsets. Returns (B, C, Hq, D) in q.dtype.

    Causality alone keeps padded table width harmless: every query row's
    reach is clamped to its own position, so unreachable pages re-point
    at the nearest reachable one (no DMA) and ``pl.when`` skips their
    compute — bytes scale with ``pages_for(starts + C)``.
    """
    b, c, hq, d = q.shape
    num_pages, page_size, hkv, dk = kp.shape
    assert d == dk and hq % hkv == 0, (q.shape, kp.shape)
    rep = hq // hkv
    npages = page_table.shape[1]
    from repro.kernels.flash_attention import _fit_block
    bq = _fit_block(c, block_q)
    nq = c // bq
    qr = q.reshape(b, c, hkv, rep, d)

    def q_map(s, iq, h, j, table_ref, lengths_ref, starts_ref):
        del table_ref, lengths_ref, starts_ref, j
        return (s, iq, h, 0, 0)

    def kv_map(s, iq, h, j, table_ref, lengths_ref, starts_ref):
        # clamp the logical page into the tile's causal/window reach:
        # repeated block indices ⇒ Pallas skips the DMA, pl.when skips
        # the compute, so dead/unreachable pages cost nothing.
        length = lengths_ref[s]
        q0 = starts_ref[s] + iq * bq
        last_live = jnp.maximum(pl.cdiv(length, page_size) - 1, 0)
        last = jnp.minimum(last_live, (q0 + bq - 1) // page_size)
        first = jnp.zeros((), jnp.int32)
        if window is not None:
            first = jnp.clip((q0 - window + 1) // page_size, 0, last)
        jj = jnp.clip(j, first, last)
        return (table_ref[s, jj], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, nq, hkv, npages),
        in_specs=[
            pl.BlockSpec((1, bq, 1, rep, d), q_map),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, rep, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((bq * rep,), jnp.float32),
            pltpu.VMEM((bq * rep,), jnp.float32),
            pltpu.VMEM((bq * rep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_prefill_kernel, scale=d ** -0.5, window=window,
                          softcap=softcap, page_size=page_size,
                          npages=npages, bq=bq, rep=rep),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, hkv, rep, d), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      starts.astype(jnp.int32), qr, kp, vp)
    return out.reshape(b, c, hq, d)


def paged_prefill_ref(q: jax.Array, kp: jax.Array, vp: jax.Array,
                      page_table: jax.Array, lengths: jax.Array,
                      starts: jax.Array, *, window: Optional[int] = None,
                      softcap: Optional[float] = None) -> jax.Array:
    """Pure-JAX twin of ``paged_prefill``: ``fori_loop`` over logical
    pages with running (m, l, acc) per query row, bounded by the
    batch-max live page count — no dense (B, npages·page_size, Hkv, D)
    view is ever materialized, so temp bytes scale with live pages."""
    b, c, hq, d = q.shape
    page_size, hkv = kp.shape[1], kp.shape[2]
    rep = hq // hkv
    npages = page_table.shape[1]
    scale = d ** -0.5
    # pool-dtype operands + preferred_element_type dots: an explicit
    # .astype(f32) on kp/vp would be loop-invariant and XLA would hoist
    # a full-pool f32 copy — the exact temp buffer this path avoids.
    qg = q.reshape(b, c, hkv, rep, d).astype(kp.dtype)
    table = page_table.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    pos_q = starts.astype(jnp.int32)[:, None] + jnp.arange(c)     # (B, C)

    def body(j, carry):
        m_run, l_run, acc = carry
        phys = jax.lax.dynamic_slice_in_dim(table, j, 1, axis=1)[:, 0]
        k = kp[phys]                                      # (B, page, Hkv, D)
        v = vp[phys]
        s = jnp.einsum("bcgrd,bpgd->bgrcp", qg, k,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        idx = j * page_size + jnp.arange(page_size)
        ok = idx[None, None, :] <= pos_q[:, :, None]      # (B, C, page)
        if window is not None:
            ok &= idx[None, None, :] > pos_q[:, :, None] - window
        s = jnp.where(ok[:, None, None], s, NEG_INF)      # (B,g,r,C,page)
        valid = idx[None, :] < lengths[:, None]           # (B, page)
        v = jnp.where(valid[:, :, None, None], v, jnp.zeros((), v.dtype))
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgrcp,bpgd->bgrcd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((b, hkv, rep, c), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep, c), jnp.float32)
    a0 = jnp.zeros((b, hkv, rep, c, d), jnp.float32)
    n_live = jnp.clip(-(-jnp.max(lengths) // page_size), 0, npages)
    _, l_f, acc = jax.lax.fori_loop(0, n_live, body, (m0, l0, a0))
    o = acc / jnp.maximum(l_f, 1e-30)[..., None]          # (B,g,r,C,D)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, c, hq, d).astype(q.dtype)
