"""Paged-attention decode Pallas TPU kernel (+ pure-JAX twin).

One decode step of the continuous-batching engine attends a single query
token per slot against that slot's KV pages *in place* — the pools from
``repro.sampling.paged_cache`` are never regathered into a dense
``(B, pages_per_slot·page_size, Hkv, D)`` logical view (the legacy path's
O(pool) HBM traffic per token; see ``repro.kernels.ops.paged_decode``).

Kernel layout:

- grid ``(slot, kv_head, logical_page)`` with the page axis innermost so
  the online-softmax accumulators (m, l, acc) live in VMEM scratch across
  page iterations — the flash-attention recurrence over pages;
- the block table and per-slot ``lengths`` ride in as scalar-prefetch
  operands (``pltpu.PrefetchScalarGridSpec``), so the kv BlockSpec index
  map resolves ``table[slot, j]`` to a physical page id before each grid
  step issues its DMA;
- pages at or past ``ceil(lengths[slot]/page_size)`` are *dead*: their
  index map re-points at the slot's last live page (same block index ⇒
  Pallas skips the copy — no DMA, and ``pl.when`` skips the compute), so
  bytes and FLOPs scale with the slot's true context length, not the
  allocator's ``pages_per_slot`` capacity;
- GQA is resolved in the index maps: all ``rep = Hq // Hkv`` query heads
  of one kv head run in a single kernel instance against one page fetch;
- masking matches ``repro.models.attention.decode_attention``: key
  positions ``idx <= pos`` (with ``pos = lengths - 1``), plus the
  sliding-window band and attention-logit softcap. Masked positions are
  zeroed in ``v`` (not just NEG_INF'd in the scores) so garbage in dead
  page tails — scratch-page contents included, even NaNs — can never
  reach a live slot's output.

``paged_decode_ref`` is the jnp twin (``lax.fori_loop`` over live pages
with running (m, l, acc)): the CPU oracle and the lowering path, the same
pairing as ``chunked_attention`` ↔ ``flash_attention``. Its loop bound is
the *batch-max* live page count, so its bytes also scale with occupancy
rather than pool capacity.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask_scores_and_values(s, v, j, page_size, length, window):
    """Apply the decode validity band to one page block.

    s (R, page) scores, v (page, D) values; returns masked (s, v) where
    invalid key positions are NEG_INF in s and *zero* in v — the zeroing
    is what keeps NaN/garbage in unwritten page tails out of ``p @ v``.
    """
    def band(col):
        ok = col < length
        if window is not None:
            ok &= col > length - 1 - window
        return ok

    cols_s = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    cols_v = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (page_size, 1), 0)
    s = jnp.where(band(cols_s), s, NEG_INF)
    v = jnp.where(band(cols_v), v, 0.0)
    return s, v


def _kernel(table_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, window: Optional[int],
            softcap: Optional[float], page_size: int, npages: int):
    s_id = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[s_id]
    live = j * page_size < length

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)               # (rep, D)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                     # (rep, page)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s, v = _mask_scores_and_values(s, v, j, page_size, length, window)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(j == npages - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention(q: jax.Array, kp: jax.Array, vp: jax.Array,
                    page_table: jax.Array, lengths: jax.Array, *,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: bool = False) -> jax.Array:
    """Decode-step attention against paged KV pools, in place.

    q (B, Hq, D) single query token per slot; kp/vp
    (num_pages, page_size, Hkv, D) page pools; page_table (B, npages)
    int32 slot→physical-page map; lengths (B,) int32 valid tokens per
    slot (``pos + 1`` — the current token's k/v must already be
    scattered into the pools). Returns (B, Hq, D) in q.dtype.
    """
    b, hq, d = q.shape
    num_pages, page_size, hkv, dk = kp.shape
    assert d == dk and hq % hkv == 0, (q.shape, kp.shape)
    rep = hq // hkv
    npages = page_table.shape[1]
    qr = q.reshape(b, hkv, rep, d)

    def q_map(s, h, j, table_ref, lengths_ref):
        del table_ref, lengths_ref, j
        return (s, h, 0, 0)

    def kv_map(s, h, j, table_ref, lengths_ref):
        # dead pages re-point at the slot's last live page: identical
        # consecutive block indices make Pallas skip the DMA, and the
        # body's pl.when(live) skips the compute.
        length = lengths_ref[s]
        last_live = jnp.maximum(pl.cdiv(length, page_size) - 1, 0)
        jj = jnp.minimum(j, last_live)
        return (table_ref[s, jj], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, npages),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d), q_map),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=d ** -0.5, window=window,
                          softcap=softcap, page_size=page_size,
                          npages=npages),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), qr, kp, vp)
    return out.reshape(b, hq, d)


def paged_decode_ref(q: jax.Array, kp: jax.Array, vp: jax.Array,
                     page_table: jax.Array, lengths: jax.Array, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None) -> jax.Array:
    """Pure-JAX twin of ``paged_attention``: ``fori_loop`` over logical
    pages with running (m, l, acc), bounded by the batch-max live page
    count so work scales with occupancy. Same shapes/semantics as the
    kernel; this is the CPU oracle and the GSPMD-native lowering path
    (the per-page gather partitions cleanly with kv-heads on 'model')."""
    b, hq, d = q.shape
    page_size, hkv = kp.shape[1], kp.shape[2]
    rep = hq // hkv
    npages = page_table.shape[1]
    scale = d ** -0.5
    # keep every pool-sized operand in the pool dtype and upcast inside
    # the dots (preferred_element_type): an explicit kp.astype(f32) is
    # loop-invariant, so XLA hoists it and converts the *entire pool*
    # once — the O(pool) temp buffer this path exists to avoid.
    qg = q.reshape(b, hkv, rep, d).astype(kp.dtype)
    table = page_table.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    def body(j, carry):
        m_run, l_run, acc = carry
        phys = jax.lax.dynamic_slice_in_dim(table, j, 1, axis=1)[:, 0]
        k = kp[phys]                                      # (B, page, Hkv, D)
        v = vp[phys]
        s = jnp.einsum("bgrd,bpgd->bgrp", qg, k,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        idx = j * page_size + jnp.arange(page_size)
        valid = idx[None, :] < lengths[:, None]           # (B, page)
        if window is not None:
            valid &= idx[None, :] > lengths[:, None] - 1 - window
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        # zero masked values so garbage/NaN in dead tails (scratch page
        # included) can never reach a live slot through 0 * NaN
        v = jnp.where(valid[:, :, None, None], v, jnp.zeros((), v.dtype))
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgrp,bpgd->bgrd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((b, hkv, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep), jnp.float32)
    a0 = jnp.zeros((b, hkv, rep, d), jnp.float32)
    n_live = jnp.clip(-(-jnp.max(lengths) // page_size), 0, npages)
    _, l_f, acc = jax.lax.fori_loop(0, n_live, body, (m0, l0, a0))
    o = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return o.reshape(b, hq, d).astype(q.dtype)
