"""Mamba2 SSD chunk-scan Pallas TPU kernel.

Grid (B·H, n_chunks), chunks innermost: the inter-chunk state (P, N) lives
in VMEM scratch and is carried sequentially across the chunk dimension —
the TPU-native analogue of Mamba2's SRAM-resident state passing. Within a
chunk the quadratic masked form runs on the MXU. B/C group tensors are
resolved per-head in the BlockSpec index map (no repeat materialization).

All decay exponents are ≤ 0 (log-space), so every exp() is stable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_scr, *,
            chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = a_ref[0].astype(jnp.float32)                     # scalar (per head)
    x = x_ref[0].astype(jnp.float32)                     # (L, P)
    dt = dt_ref[0].astype(jnp.float32)                   # (L,)
    b = b_ref[0].astype(jnp.float32)                     # (L, N)
    c = c_ref[0].astype(jnp.float32)                     # (L, N)

    la = a * dt                                          # (L,) <= 0
    cum = jnp.cumsum(la)                                 # inclusive
    u = x * dt[:, None]                                  # (L, P)

    # intra-chunk quadratic form
    dec = cum[:, None] - cum[None, :]                    # (L, L)
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    w = jnp.where(mask, w * jnp.exp(jnp.where(mask, dec, 0.0)), 0.0)
    y = jax.lax.dot_general(w, u, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state (P, N)
    state = state_scr[...]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update for the next chunk
    w_end = jnp.exp(cum[-1] - cum)                       # (L,)
    state_scr[...] = (state * jnp.exp(cum[-1])
                      + jax.lax.dot_general(
                          u * w_end[:, None], b, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, a_log_neg: jax.Array,
             b: jax.Array, c: jax.Array, *, chunk: int = 128,
             interpret: bool = False) -> jax.Array:
    """x (B,S,H,P); dt (B,S,H); a_log_neg (H,) [negative];
    b, c (B,S,G,N) -> y (B,S,H,P). Zero initial state."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    l = min(chunk, s)
    assert s % l == 0
    nc = s // l
    hg = h // g

    xr = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    dtr = dt.transpose(0, 2, 1).reshape(bsz * h, s)
    br = b.transpose(0, 2, 1, 3).reshape(bsz * g, s, n)
    cr = c.transpose(0, 2, 1, 3).reshape(bsz * g, s, n)
    ar = jnp.tile(a_log_neg, bsz)                        # (B*H,)

    def bc_index(bh, ic):
        batch = bh // h
        head = bh % h
        return (batch * g + head // hg, ic, 0)

    y = pl.pallas_call(
        functools.partial(_kernel, chunk=l),
        grid=(bsz * h, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, ic: (bh,)),
            pl.BlockSpec((1, l, p), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, l), lambda bh, ic: (bh, ic)),
            pl.BlockSpec((1, l, n), bc_index),
            pl.BlockSpec((1, l, n), bc_index),
        ],
        out_specs=pl.BlockSpec((1, l, p), lambda bh, ic: (bh, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(ar, xr, dtr, br, cr)
    return y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
