"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose``
targets of the kernel test sweeps)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import naive_attention
from repro.models.ssm import ssd_reference


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None):
    b, s, _, _ = q.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    kind = "causal" if causal else "bidir"
    if causal and window is not None:
        kind = "local"
    return naive_attention(q, k, v, pos_q=pos, pos_k=pos, kind=kind,
                           window=window or 0, softcap=softcap)


def ssd_scan_ref(x, dt, a_log_neg, b, c):
    y, _ = ssd_reference(x, dt, a_log_neg, b, c)
    return y.astype(x.dtype)


def fused_logprob_ref(logits: jax.Array, targets: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    from repro.core.logprob import clamp_target_ids
    lg = logits.astype(jnp.float32)
    lp = jax.nn.log_softmax(lg, axis=-1)
    # shared target-id contract: out-of-range ids (padding) clamp to [0, V)
    tgt = clamp_target_ids(targets, lg.shape[-1])
    logp = jnp.take_along_axis(lp, tgt[:, None], axis=-1)[:, 0]
    ent = -(jnp.exp(lp) * lp).sum(-1)
    return logp, ent
