"""Pallas TPU kernels for the compute hot-spots (flash attention, Mamba2
SSD chunk scan, fused RL token-logprob/entropy). Each has a pure-jnp
oracle in ``ref.py``; ``ops.py`` exposes jit'd wrappers that run
interpret-mode on CPU and Mosaic-compiled on TPU."""
from repro.kernels import ops, ref
from repro.kernels.ops import (flash_attention, fused_logprob,
                               fused_token_logprob, ssd_scan)

__all__ = ["ops", "ref", "flash_attention", "ssd_scan", "fused_logprob",
           "fused_token_logprob"]
