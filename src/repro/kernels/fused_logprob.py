"""Fused token-logprob (+ entropy) Pallas TPU kernel — the RL hot spot.

RL post-training needs log p(y_t) (and optionally the entropy) of every
sampled token, for both the learner and the recomputed sampler pass. The
naive path materializes log_softmax over the whole vocabulary —
(B·S, 152k) f32 activations (and their backward) dominate HBM traffic at
GEPO's training shapes. This kernel streams vocab tiles through VMEM with
an online logsumexp, emitting only (B·S,) outputs: O(T·V) reads, O(T)
writes, nothing materialized.

Grid (n_token_blocks, n_vocab_blocks), vocab innermost; scratch carries
running max m, normalizer l, Σp·x (entropy) and the gathered target logit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(logits_ref, tgt_ref, logp_ref, ent_ref,
            m_scr, l_scr, s1_scr, tacc_scr, *, bt: int, bv: int, nv: int):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        s1_scr[...] = jnp.zeros_like(s1_scr)
        tacc_scr[...] = jnp.zeros_like(tacc_scr)

    x = logits_ref[...].astype(jnp.float32)              # (bt, bv)
    tgt = tgt_ref[...]                                   # (bt,)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, x.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(x - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    s1_scr[...] = s1_scr[...] * alpha + (p * x).sum(axis=1)
    m_scr[...] = m_new

    cols = iv * bv + jax.lax.broadcasted_iota(jnp.int32, (bt, bv), 1)
    hit = cols == tgt[:, None]
    tacc_scr[...] += jnp.where(hit, x, 0.0).sum(axis=1)

    @pl.when(iv == nv - 1)
    def _finish():
        m = m_scr[...]
        l = jnp.maximum(l_scr[...], 1e-30)
        lse = m + jnp.log(l)
        logp_ref[...] = (tacc_scr[...] - lse).astype(logp_ref.dtype)
        # H = lse − E_p[x]
        ent_ref[...] = (lse - s1_scr[...] / l).astype(ent_ref.dtype)


def fused_logprob(logits: jax.Array, targets: jax.Array, *,
                  block_t: int = 256, block_v: int = 2048,
                  interpret: bool = False):
    """logits (T, V); targets (T,) int32 -> (logp (T,), entropy (T,)),
    both f32."""
    t, v = logits.shape
    bt = min(block_t, t)
    bv = min(block_v, v)
    assert t % bt == 0 and v % bv == 0, (t, v, bt, bv)
    nt, nv = t // bt, v // bv

    logp, ent = pl.pallas_call(
        functools.partial(_kernel, bt=bt, bv=bv, nv=nv),
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((bt, bv), lambda it, iv: (it, iv)),
            pl.BlockSpec((bt,), lambda it, iv: (it,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda it, iv: (it,)),
            pl.BlockSpec((bt,), lambda it, iv: (it,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((t,), jnp.float32),
                   jax.ShapeDtypeStruct((t,), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bt,), jnp.float32)] * 4,
        interpret=interpret,
    )(logits, targets)
    return logp, ent
