"""Fused, differentiable token-logprob (+ entropy) — the RL hot spot.

RL post-training needs log p(y_t) (and the entropy) of every sampled
token, for both the learner's loss and the App. B.1 untrusted-sampler
recompute. The naive path materializes log_softmax over the whole
vocabulary — (B·S, 152k) f32 activations (and their backward twins)
dominate HBM traffic at GEPO's training shapes. Both implementations
here stream the vocabulary instead, in the forward *and* backward pass:

- ``fused_logprob`` — Pallas TPU kernel pair under one
  ``jax.custom_vjp``. Forward: grid (n_token_blocks, n_vocab_blocks),
  vocab innermost, online logsumexp in VMEM scratch; emits (logp, ent)
  plus the O(T) residual ``lse`` (μ = E_p[x] is recovered as lse − ent,
  so the saved state per token is just two f32 scalars). Backward: a
  second kernel streams the same vocab tiles again and writes
      dlogits = g_lp·(onehot(tgt) − p) − g_ent·p·(x − μ)
  tile-by-tile (p = exp(x − lse) recomputed per tile), so neither pass
  materializes a V-sized f32 activation.

- ``chunked_logprob`` — pure-JAX fallback with the *same* custom VJP
  structure: ``lax.map`` over fixed-size token chunks, each chunk doing
  a full-vocab reduction in f32. Peak live f32 activation is
  O(chunk · V) instead of O(T · V) in both passes, works on any
  backend and any (T, V) shape (a ragged tail chunk is handled
  separately — no padded copy of the logits). Vocab reductions use the
  masked-sum gather (iota == target) so vocab-sharded logits never
  all-gather (cf. ``repro.core.logprob``).

Target-id contract (shared with ``repro.core.logprob``): ids are
clamped to [0, V) before the gather. Out-of-range ids — conventionally
parked on *masked* positions by padding — therefore return the (finite)
log-prob of a valid token instead of silently degenerating to −lse; the
loss masks them out, but diagnostics and parity tests stay finite.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Pallas forward: online logsumexp over vocab tiles


def _fwd_kernel(logits_ref, tgt_ref, logp_ref, ent_ref, lse_ref,
                m_scr, l_scr, s1_scr, tacc_scr, *, bt: int, bv: int,
                nv: int):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        s1_scr[...] = jnp.zeros_like(s1_scr)
        tacc_scr[...] = jnp.zeros_like(tacc_scr)

    x = logits_ref[...].astype(jnp.float32)              # (bt, bv)
    tgt = tgt_ref[...]                                   # (bt,)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, x.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(x - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    s1_scr[...] = s1_scr[...] * alpha + (p * x).sum(axis=1)
    m_scr[...] = m_new

    cols = iv * bv + jax.lax.broadcasted_iota(jnp.int32, (bt, bv), 1)
    hit = cols == tgt[:, None]
    tacc_scr[...] += jnp.where(hit, x, 0.0).sum(axis=1)

    @pl.when(iv == nv - 1)
    def _finish():
        m = m_scr[...]
        l = jnp.maximum(l_scr[...], 1e-30)
        lse = m + jnp.log(l)
        logp_ref[...] = (tacc_scr[...] - lse).astype(logp_ref.dtype)
        # H = lse − E_p[x]
        ent_ref[...] = (lse - s1_scr[...] / l).astype(ent_ref.dtype)
        lse_ref[...] = lse.astype(lse_ref.dtype)


def _pallas_fwd(logits, targets, block_t, block_v, interpret):
    t, v = logits.shape
    bt = min(block_t, t)
    bv = min(block_v, v)
    assert t % bt == 0 and v % bv == 0, (t, v, bt, bv)
    nt, nv = t // bt, v // bv

    return pl.pallas_call(
        functools.partial(_fwd_kernel, bt=bt, bv=bv, nv=nv),
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((bt, bv), lambda it, iv: (it, iv)),
            pl.BlockSpec((bt,), lambda it, iv: (it,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda it, iv: (it,)),
            pl.BlockSpec((bt,), lambda it, iv: (it,)),
            pl.BlockSpec((bt,), lambda it, iv: (it,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((t,), jnp.float32),
                   jax.ShapeDtypeStruct((t,), jnp.float32),
                   jax.ShapeDtypeStruct((t,), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bt,), jnp.float32)] * 4,
        interpret=interpret,
    )(logits, targets)


# --------------------------------------------------------------------------
# Pallas backward: every (token, vocab) tile is independent —
# dlogits = g_lp·(onehot − p) − g_ent·p·(x − μ) with p = exp(x − lse)


def _bwd_kernel(logits_ref, tgt_ref, lse_ref, mu_ref, glp_ref, gent_ref,
                dlogits_ref, *, bt: int, bv: int):
    iv = pl.program_id(1)
    x = logits_ref[...].astype(jnp.float32)              # (bt, bv)
    p = jnp.exp(x - lse_ref[...][:, None])
    cols = iv * bv + jax.lax.broadcasted_iota(jnp.int32, (bt, bv), 1)
    hit = (cols == tgt_ref[...][:, None]).astype(jnp.float32)
    d = (glp_ref[...][:, None] * (hit - p)
         - gent_ref[...][:, None] * p * (x - mu_ref[...][:, None]))
    dlogits_ref[...] = d.astype(dlogits_ref.dtype)


def _pallas_bwd(logits, targets, lse, mu, g_lp, g_ent, block_t, block_v,
                interpret):
    t, v = logits.shape
    bt = min(block_t, t)
    bv = min(block_v, v)
    nt, nv = t // bt, v // bv
    vec = pl.BlockSpec((bt,), lambda it, iv: (it,))
    return pl.pallas_call(
        functools.partial(_bwd_kernel, bt=bt, bv=bv),
        grid=(nt, nv),
        in_specs=[pl.BlockSpec((bt, bv), lambda it, iv: (it, iv)),
                  vec, vec, vec, vec, vec],
        out_specs=pl.BlockSpec((bt, bv), lambda it, iv: (it, iv)),
        out_shape=jax.ShapeDtypeStruct((t, v), logits.dtype),
        interpret=interpret,
    )(logits, targets, lse, mu, g_lp, g_ent)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _fused_logprob_vjp(logits, targets, block_t, block_v, interpret):
    logp, ent, _ = _pallas_fwd(logits, targets, block_t, block_v, interpret)
    return logp, ent


def _fused_fwd_rule(logits, targets, block_t, block_v, interpret):
    logp, ent, lse = _pallas_fwd(logits, targets, block_t, block_v,
                                 interpret)
    # O(T) residuals only: μ = E_p[x] = lse − H
    return (logp, ent), (logits, targets, lse, lse - ent)


def _fused_bwd_rule(block_t, block_v, interpret, res, cots):
    logits, targets, lse, mu = res
    g_lp, g_ent = cots
    dlogits = _pallas_bwd(logits, targets, lse, mu, g_lp, g_ent,
                          block_t, block_v, interpret)
    return dlogits, np.zeros(targets.shape, jax.dtypes.float0)


_fused_logprob_vjp.defvjp(_fused_fwd_rule, _fused_bwd_rule)


def fused_logprob(logits: jax.Array, targets: jax.Array, *,
                  block_t: int = 256, block_v: int = 2048,
                  interpret: bool = False
                  ) -> Tuple[jax.Array, jax.Array]:
    """logits (T, V); targets (T,) int -> (logp (T,), entropy (T,)), f32.

    Differentiable w.r.t. ``logits`` (custom VJP, backward is a second
    streaming Pallas kernel). T and V must divide by the (clipped) block
    sizes — the ``ops.fused_token_logprob`` dispatcher falls back to
    ``chunked_logprob`` for ragged shapes.
    """
    from repro.core.logprob import clamp_target_ids
    tgt = clamp_target_ids(targets, logits.shape[-1])
    return _fused_logprob_vjp(logits, tgt, block_t, block_v, interpret)


# --------------------------------------------------------------------------
# Chunked pure-JAX fallback: same VJP structure, bounded f32 live set


def _chunk_fwd(x: jax.Array, tgt: jax.Array):
    """One token chunk (..., c, V) -> (logp, ent, lse), each (..., c)
    f32. Delegates to the shared masked-sum math in repro.core.logprob
    (iota == target gather, so vocab-sharded logits never all-gather) —
    one source of truth for naive↔fused numerical parity."""
    from repro.core.logprob import token_logprob_entropy_lse
    return token_logprob_entropy_lse(x, tgt)


def _chunk_bwd(x, tgt, lse, mu, g_lp, g_ent):
    """dlogits for one token chunk, recomputing p = exp(x − lse)."""
    lg = x.astype(jnp.float32)
    p = jnp.exp(lg - lse[..., None])
    hit = (jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
           == tgt[..., None]).astype(jnp.float32)
    d = (g_lp[..., None] * (hit - p)
         - g_ent[..., None] * p * (lg - mu[..., None]))
    return d.astype(x.dtype)


def _chunked_fwd_pass(logits, targets, chunk: int):
    """Forward over the token axis (the second-to-last logits axis) in
    fixed ``chunk`` pieces. Chunking stays on that axis — never on a
    flattened (B·S,) — so under GSPMD the batch axes keep their data
    sharding and every slice is shard-local. The loop indexes into the
    *original* arrays with ``dynamic_slice`` (loop-invariant operands —
    no stacked (nc, ..., chunk, V) copy of the logits as a scan input),
    and only the O(tokens) outputs are stacked. A ragged tail chunk is
    handled by a direct call, so no padded copy either."""
    ax = logits.ndim - 2                       # token axis (== targets -1)
    t = logits.shape[ax]
    nc, rem = divmod(t, chunk)
    parts = []
    if nc == 1:
        parts.append(_chunk_fwd(
            jax.lax.slice_in_dim(logits, 0, chunk, axis=ax),
            jax.lax.slice_in_dim(targets, 0, chunk, axis=ax)))
    elif nc:
        def fwd_i(i):
            x = jax.lax.dynamic_slice_in_dim(logits, i * chunk, chunk,
                                             axis=ax)
            tg = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk,
                                              axis=ax)
            return _chunk_fwd(x, tg)

        stacked = jax.lax.map(fwd_i, jnp.arange(nc))
        # (nc, ..., chunk) -> (..., nc*chunk)
        parts.append(tuple(jnp.moveaxis(s, 0, -2).reshape(
            s.shape[1:-1] + (nc * chunk,)) for s in stacked))
    if rem:
        parts.append(_chunk_fwd(
            jax.lax.slice_in_dim(logits, nc * chunk, t, axis=ax),
            jax.lax.slice_in_dim(targets, nc * chunk, t, axis=ax)))
    if len(parts) == 1:
        return parts[0]
    return tuple(jnp.concatenate(ps, axis=-1) for ps in zip(*parts, strict=True))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _chunked_logprob_vjp(logits, targets, chunk):
    logp, ent, _ = _chunked_fwd_pass(logits, targets, chunk)
    return logp, ent


def _chunked_fwd_rule(logits, targets, chunk):
    logp, ent, lse = _chunked_fwd_pass(logits, targets, chunk)
    return (logp, ent), (logits, targets, lse, lse - ent)


def _chunked_bwd_rule(chunk, res, cots):
    logits, targets, lse, mu = res
    g_lp, g_ent = cots
    ax = logits.ndim - 2
    t = logits.shape[ax]
    nc, rem = divmod(t, chunk)

    def d_slice(start, size):
        x = jax.lax.dynamic_slice_in_dim(logits, start, size, axis=ax)
        args = [jax.lax.dynamic_slice_in_dim(a, start, size, axis=ax)
                for a in (targets, lse, mu, g_lp, g_ent)]
        return _chunk_bwd(x, *args)

    # one primal-shaped output buffer carried through the scan and
    # updated in place (XLA aliases while-loop carries) — never a
    # stacked (nc, ..., chunk, V) copy + concat
    dlogits = jnp.zeros(logits.shape, logits.dtype)
    if nc == 1:
        dlogits = jax.lax.dynamic_update_slice_in_dim(
            dlogits, d_slice(0, chunk), 0, axis=ax)
    elif nc:
        def body(dl, i):
            return jax.lax.dynamic_update_slice_in_dim(
                dl, d_slice(i * chunk, chunk), i * chunk, axis=ax), None

        dlogits, _ = jax.lax.scan(body, dlogits, jnp.arange(nc))
    if rem:
        dlogits = jax.lax.dynamic_update_slice_in_dim(
            dlogits, d_slice(nc * chunk, rem), nc * chunk, axis=ax)
    return dlogits, np.zeros(targets.shape, jax.dtypes.float0)


_chunked_logprob_vjp.defvjp(_chunked_fwd_rule, _chunked_bwd_rule)


def chunked_logprob(logits: jax.Array, targets: jax.Array, *,
                    chunk: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Portable twin of ``fused_logprob``: logits (..., T, V), targets
    (..., T) -> (logp, entropy), f32, any backend / any shape. The token
    axis is chunked in place (leading batch axes keep their sharding);
    peak live f32 is O(batch·chunk·V) in forward *and* backward (the
    custom VJP recomputes softmax per chunk from the saved O(tokens)
    ``lse`` residual)."""
    from repro.core.logprob import clamp_target_ids
    tgt = clamp_target_ids(targets, logits.shape[-1])
    return _chunked_logprob_vjp(logits, tgt,
                                min(chunk, logits.shape[-2]))
