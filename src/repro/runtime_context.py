"""Process-level runtime context: the active device mesh.

Model code is mesh-agnostic except for the explicitly ``shard_map``-ed
paths (expert-parallel MoE); those read the mesh registered here by the
launcher (jax's contextual abstract mesh is empty inside jit traces as of
jax 0.8)."""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

import jax

_MESH: Optional[jax.sharding.Mesh] = None


def set_mesh(mesh: Optional[jax.sharding.Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> jax.sharding.Mesh:
    if _MESH is None:
        raise RuntimeError("no mesh registered — launcher must call "
                           "repro.runtime_context.set_mesh(mesh)")
    return _MESH


@contextmanager
def mesh_context(mesh: jax.sharding.Mesh):
    prev = _MESH
    set_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(prev)
