"""Back-compat shim: meshes now live in ``repro.parallel.mesh`` (the
unified execution layer owns placement for train, sample, and dry-run)."""
from repro.parallel.mesh import (HBM_BW, ICI_BW, PEAK_BF16_FLOPS,  # noqa: F401
                                 data_axes, local_mesh, make_debug_mesh,
                                 make_production_mesh, mesh_from_flag)

__all__ = ["make_production_mesh", "make_debug_mesh", "local_mesh",
           "mesh_from_flag", "data_axes", "PEAK_BF16_FLOPS", "HBM_BW",
           "ICI_BW"]
