"""Production meshes for the TPU v5e target.

Single pod: 256 chips as (data=16, model=16).
Multi-pod: 2 pods × 256 chips as (pod=2, data=16, model=16) — the ``pod``
axis is the slow inter-pod (DCN/WAN) dimension; HeteroRL's design keeps
cross-pod traffic to checkpoint broadcast + rollout streaming, but the
dry-run also proves the *learner step itself* shards across pods.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

from typing import Tuple

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_BF16_FLOPS = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *,
                    multi_pod: bool = False) -> jax.sharding.Mesh:
    """Small mesh for CI-scale dry-run tests (requires
    --xla_force_host_platform_device_count >= product)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))
