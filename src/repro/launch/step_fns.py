"""Step functions + abstract inputs for the production launcher and the
multi-pod dry-run. Everything here works on ``ShapeDtypeStruct``s — no
real allocation happens for the full-size configs.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import (ATTN, CROSS, LOCAL, MAMBA, ModelConfig, RLConfig,
                          ShapeConfig, TrainConfig)
from repro.models import abstract_params, decode_step, encode, forward, init_cache
from repro.optim import AdafactorState, AdamWState
from repro.training import TrainState, train_step

# Architectures whose optimizer state cannot be full-precision Adam within
# 16 GB/chip at single-pod scale — production choice is Adafactor
# (factored second moment), exactly as MaxText defaults for very large
# models.
ADAFACTOR_ARCHS = ("jamba-1.5-large-398b", "llama4-maverick-400b-a17b",
                   "llama4-scout-17b-a16e")


def optimizer_for(cfg: ModelConfig) -> str:
    return "adafactor" if cfg.name in ADAFACTOR_ARCHS else "adamw"


def grad_accum_for(cfg: ModelConfig) -> int:
    """Micro-batching keeps per-device live activations bounded (65k
    tokens/chip at train_4k is far above what fits without it). Chosen per
    architecture from the dry-run memory sweeps."""
    n = cfg.param_count()
    if n > 50e9:
        return 16
    if n > 8e9:
        return 8
    if n > 3e9:
        return 4
    return 1


# --------------------------------------------------------------------------
# step functions


def make_train_fn(cfg: ModelConfig, rl: RLConfig, tc: TrainConfig,
                  plan=None):
    """RL train step for the launcher/dry-run grid. The learner-side
    token-logprob backend follows ``tc.logprob_impl`` (default "fused":
    the streaming ``repro.kernels.ops.fused_token_logprob`` dispatch —
    Pallas on TPU, chunked ``lax.map`` on the CPU dry-run — so the
    lowered step never materializes a (B·T, V) f32 log-softmax). With an
    ``ExecutionPlan``, grad-accum microbatch slicing is pinned
    shard-local (``constrain_microbatches``)."""
    opt = optimizer_for(cfg)
    mb_con = (plan.microbatch_constraint(cfg, tc.grad_accum)
              if plan is not None else None)

    def step(state: TrainState, batch: Dict[str, jax.Array]):
        # frames / image_embeds ride in the batch so grad-accum
        # micro-batching slices them together with the tokens.
        return train_step(cfg, rl, tc, state, batch, optimizer=opt,
                          mb_constraint=mb_con)
    return step


def make_prefill_fn(cfg: ModelConfig, max_len: int):
    def step(params, batch: Dict[str, jax.Array]):
        tokens = batch["tokens"]
        memory = None
        if cfg.is_encdec:
            memory = encode(cfg, params, batch["frames"])
        elif cfg.memory_seq:
            memory = batch["image_embeds"]
        cache = init_cache(cfg, params, tokens.shape[0], max_len,
                           memory=memory)
        logits, cache, _ = forward(cfg, params, tokens, cache=cache,
                                   memory=memory)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return step


def make_decode_fn(cfg: ModelConfig):
    def step(params, cache, token, pos):
        logits, new_cache = decode_step(cfg, params, cache, token, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return step


# --------------------------------------------------------------------------
# abstract inputs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((b, s), jnp.int32),
           "mask": _sds((b, s - 1), jnp.float32),
           "sampler_lp": _sds((b, s - 1), jnp.float32),
           "rewards": _sds((b,), jnp.float32)}
    if cfg.is_encdec:
        out["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    elif cfg.memory_seq:
        out["image_embeds"] = _sds((b, cfg.memory_seq, cfg.d_model),
                                   cfg.dtype)
    return out


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """ShapeDtypeStruct twin of ``models.init_cache``."""
    dt = jnp.dtype(cfg.dtype)
    nb = cfg.num_blocks
    mem_len = cfg.encoder_seq if cfg.is_encdec else cfg.memory_seq
    cache: Dict[str, Any] = {}
    for i, kind in enumerate(cfg.block_pattern):
        lc: Dict[str, Any] = {}
        if kind in (ATTN, LOCAL):
            ml = max_len
            if cfg.local_ring_kv and kind == LOCAL:
                ml = min(max_len, cfg.sliding_window)
            lc["self"] = {
                "k": _sds((nb, batch, ml, cfg.num_kv_heads,
                           cfg.head_dim), dt),
                "v": _sds((nb, batch, ml, cfg.num_kv_heads,
                           cfg.head_dim), dt)}
            if cfg.is_encdec:
                lc["mem"] = {
                    "k_mem": _sds((nb, batch, mem_len, cfg.num_kv_heads,
                                   cfg.head_dim), dt),
                    "v_mem": _sds((nb, batch, mem_len, cfg.num_kv_heads,
                                   cfg.head_dim), dt)}
        elif kind == CROSS:
            lc["mem"] = {
                "k_mem": _sds((nb, batch, mem_len, cfg.num_kv_heads,
                               cfg.head_dim), dt),
                "v_mem": _sds((nb, batch, mem_len, cfg.num_kv_heads,
                               cfg.head_dim), dt)}
        elif kind == MAMBA:
            conv_ch = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
            lc["ssm_c"] = {
                "conv": _sds((nb, batch, cfg.ssm_conv - 1, conv_ch), dt),
                "ssm": _sds((nb, batch, cfg.ssm_heads, cfg.ssm_headdim,
                             cfg.ssm_state), jnp.float32)}
        cache[f"layer_{i}"] = lc
    return cache


def abstract_opt_state(cfg: ModelConfig, optimizer: str):
    params = abstract_params(cfg)
    if optimizer == "adamw":
        f32 = lambda p: _sds(p.shape, jnp.float32)
        return AdamWState(step=_sds((), jnp.int32),
                          m=jax.tree_util.tree_map(f32, params),
                          v=jax.tree_util.tree_map(f32, params))

    def row(p):
        return _sds(p.shape[:-1] if p.ndim >= 2 else p.shape, jnp.float32)

    def col(p):
        return _sds(p.shape[:-2] + p.shape[-1:] if p.ndim >= 2 else (1,),
                    jnp.float32)

    return AdafactorState(step=_sds((), jnp.int32),
                          vr=jax.tree_util.tree_map(row, params),
                          vc=jax.tree_util.tree_map(col, params))


def abstract_state(cfg: ModelConfig) -> TrainState:
    return TrainState(params=abstract_params(cfg),
                      opt=abstract_opt_state(cfg, optimizer_for(cfg)),
                      step=_sds((), jnp.int32))
