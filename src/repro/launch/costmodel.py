"""Analytic per-step cost model (napkin math, §Perf methodology).

``cost_analysis()`` on an XLA executable counts each ``while`` body ONCE —
our scan-over-blocks models would be undercounted by ~num_blocks×. The
compute and memory roofline terms therefore come from this analytic model
(the same arithmetic a performance engineer would do by hand); the
collective term comes from a loop-aware parse of the compiled HLO
(``roofline.parse_collectives_loop_aware``). Raw cost_analysis numbers are
recorded alongside for transparency.

Conventions:
- FLOPs are *as-compiled*: the chunked attention path computes the full
  (masked) Sq×Sk rectangle, so causal attention costs 2× the ideal — the
  ideal is also reported (``attn_waste``).
- Train steps: matmul FLOPs ×4 (fwd + recompute-under-remat + 2×bwd);
  inference ×1.
"""
from __future__ import annotations

from typing import Dict

from repro.config import ATTN, CROSS, LOCAL, MAMBA, MLP, MOE, ModelConfig, ShapeConfig

BF16 = 2
F32 = 4


def _attn_flops_per_token(cfg: ModelConfig, kv_len: float, ideal: bool,
                          kind: str, seq_len: int) -> float:
    """Score+value matmul FLOPs for one query token against kv_len keys."""
    if ideal:
        if kind == ATTN:
            kv_eff = (kv_len + 1) / 2          # causal average
        elif kind == LOCAL:
            kv_eff = min(cfg.sliding_window, kv_len / 2)
        else:
            kv_eff = kv_len
    else:
        # chunked impl computes the full rectangle then masks
        kv_eff = kv_len
    return 4.0 * cfg.num_heads * cfg.head_dim * kv_eff


def _layer_matmul_params(cfg: ModelConfig, kind: str, ffn_kind: str) -> int:
    """Active matmul params for one layer (used at 2 FLOPs/param/token)."""
    d, h = cfg.d_model, cfg.head_dim
    n = 0
    if kind in (ATTN, LOCAL, CROSS):
        n += d * (cfg.num_heads * h) + 2 * d * (cfg.num_kv_heads * h) \
            + (cfg.num_heads * h) * d
    elif kind == MAMBA:
        di, N, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
        n += d * (2 * di + 2 * G * N + cfg.ssm_heads) + di * d
    if ffn_kind == MLP:
        n += 3 * d * cfg.d_ff
    elif ffn_kind == MOE:
        n += 3 * d * cfg.d_ff * cfg.experts_per_token + d * cfg.num_experts
        if cfg.shared_expert:
            n += 3 * d * cfg.d_ff
    return n


def _ssd_flops_per_token(cfg: ModelConfig) -> float:
    """Chunked SSD: intra-chunk quadratic + state update, per token."""
    if not cfg.ssm_state:
        return 0.0
    l = cfg.ssm_chunk
    h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    # per chunk: CBᵀ (L²N), y_intra (L²P) per head; states/in/out (L·N·P ×2)
    per_chunk = 2.0 * h * (l * l * n + l * l * p + 2 * l * n * p)
    return per_chunk / l


def flops_estimate(cfg: ModelConfig, shape: ShapeConfig, *,
                   ideal: bool = False) -> float:
    """Global FLOPs per step (whole mesh)."""
    if shape.kind == "decode":
        tokens = shape.global_batch
        kv_len = shape.seq_len
        mult = 1.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        kv_len = shape.seq_len
        mult = 1.0
    else:
        tokens = shape.global_batch * shape.seq_len
        kv_len = shape.seq_len
        mult = 4.0                              # fwd + remat + 2·bwd

    total = 0.0
    for li in range(cfg.num_layers):
        kind = cfg.block_pattern[li % cfg.period]
        ffn_kind = cfg.ffn_kind(li % cfg.period)
        total += 2.0 * _layer_matmul_params(cfg, kind, ffn_kind) * tokens
        if kind in (ATTN, LOCAL):
            if shape.kind == "decode":
                kv_eff = (min(cfg.sliding_window, kv_len)
                          if kind == LOCAL else kv_len)
                total += 4.0 * cfg.num_heads * cfg.head_dim * kv_eff * tokens
            else:
                total += _attn_flops_per_token(cfg, kv_len, ideal, kind,
                                               shape.seq_len) * tokens
        elif kind == CROSS:
            total += 4.0 * cfg.num_heads * cfg.head_dim * cfg.memory_seq \
                * tokens
        elif kind == MAMBA:
            if shape.kind == "decode":
                total += 2.0 * cfg.ssm_heads * cfg.ssm_headdim \
                    * cfg.ssm_state * 2 * tokens
            else:
                total += _ssd_flops_per_token(cfg) * tokens
    # encoder (runs once per step on encoder_seq frames)
    if cfg.encoder_layers:
        enc_tokens = shape.global_batch * cfg.encoder_seq
        if shape.kind == "decode":
            enc_tokens = 0                      # encoder output cached
        per = (4 * cfg.d_model * cfg.num_heads * cfg.head_dim
               + 3 * cfg.d_model * cfg.d_ff)
        total += 2.0 * per * cfg.encoder_layers * enc_tokens
        total += 4.0 * cfg.num_heads * cfg.head_dim * cfg.encoder_seq \
            * enc_tokens
        # decoder cross-attention to the 1500-frame memory
        total += 4.0 * cfg.num_heads * cfg.head_dim * cfg.encoder_seq \
            * tokens
    # lm head + embedding
    total += 2.0 * cfg.d_model * cfg.padded_vocab * tokens
    return total * mult


def bytes_estimate(cfg: ModelConfig, shape: ShapeConfig, n_dev: int,
                   optimizer: str = "adamw") -> Dict[str, float]:
    """Per-device HBM bytes per step (read+write), by component."""
    n_params = cfg.param_count()
    p_dev = n_params * BF16 / n_dev             # params fully sharded
    if shape.kind == "train":
        tokens_dev = shape.global_batch * shape.seq_len / n_dev
        opt_mult = (4 * F32 if optimizer == "adamw" else 2 * BF16)
        # fwd read + bwd read + grad write (f32) + opt read/write
        weights = p_dev * (2 + 2) + n_params * F32 / n_dev \
            + n_params * opt_mult / n_dev
        # saved residual per block: write in fwd, read in bwd
        resid = 2 * cfg.num_blocks * tokens_dev * cfg.d_model * BF16
        logits = 3 * tokens_dev * cfg.padded_vocab * F32 / \
            (16 if n_dev >= 16 else 1)          # vocab-sharded logits r/w
        act = 6 * cfg.num_layers * tokens_dev * cfg.d_model * BF16
        return {"weights": weights, "residuals": resid, "logits": logits,
                "activations": act,
                "total": weights + resid + logits + act}
    if shape.kind == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / n_dev * \
            (16 if n_dev >= 16 else 1)          # batch only over dp
        p_serve = n_params * BF16 / min(n_dev, 16)   # TP-16 weights
        act = 4 * cfg.num_layers * tokens_dev * cfg.d_model * BF16
        cache_w = _cache_bytes(cfg, shape, n_dev)
        return {"weights": p_serve, "activations": act, "cache": cache_w,
                "total": p_serve + act + cache_w}
    # decode: one token — read all params + whole KV cache
    p_serve = n_params * BF16 / min(n_dev, 16 if shape.name != "long_500k"
                                    else n_dev)
    cache = _cache_bytes(cfg, shape, n_dev)
    return {"weights": p_serve, "cache": cache, "total": p_serve + cache}


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig, n_dev: int) -> float:
    """Per-device KV/SSM cache bytes."""
    total = 0.0
    for li in range(cfg.num_layers):
        kind = cfg.block_pattern[li % cfg.period]
        if kind in (ATTN, LOCAL):
            total += (2 * shape.global_batch * shape.seq_len
                      * cfg.num_kv_heads * cfg.head_dim * BF16)
        elif kind == CROSS:
            total += (2 * shape.global_batch * cfg.memory_seq
                      * cfg.num_kv_heads * cfg.head_dim * BF16)
        elif kind == MAMBA:
            total += (shape.global_batch * cfg.ssm_heads * cfg.ssm_headdim
                      * cfg.ssm_state * F32)
    if cfg.encoder_layers:
        total += (2 * cfg.num_layers * shape.global_batch * cfg.encoder_seq
                  * cfg.num_kv_heads * cfg.head_dim * BF16)
    return total / n_dev
