"""Serving driver: what a HeteroRL *sampler node* runs. CPU-scale by
default (smoke config); the full-size serving path is exercised
shape-exactly by ``dryrun.py`` (prefill_32k / decode_32k / long_500k).

All deployment knobs live in one ``ServeConfig`` (engine kind, slots,
page size, decode horizon, pool size, mesh, admission limits) — the
flags below map 1:1 onto its fields and the same object drives the
request-level engine API, the asyncio front door, and HeteroRL sampler
nodes.

Batch mode (default) runs ``--rounds`` batches through the engine:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
      --batch 16 --max-new 24 --engine continuous --slots 8

Front-door mode serves HTTP + websocket with admission control and SLO
telemetry (POST /generate, GET /ws, /healthz, /metrics):
  PYTHONPATH=src python -m repro.launch.serve --listen --port 8100

Tensor-parallel serving runs through the same ExecutionPlan as training
(on CPU export the host-device override first):
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.serve --mesh 1x4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.config import RLConfig, ServeConfig
from repro.configs import smoke
from repro.data import ArithmeticTask, Tokenizer, encode_prompts
from repro.models import encode, init_params
from repro.parallel import plan_from_flag
from repro.sampling import build_engine
from repro.serving.api import Request, SamplingParams


def parse_serve_config(args: argparse.Namespace) -> ServeConfig:
    """The single deployment object the loose flags collapse into."""
    return ServeConfig(
        engine=args.engine, num_slots=args.slots, page_size=args.page_size,
        prefill_chunk=args.prefill_chunk, sync_every=args.sync_every,
        max_total_tokens=args.max_total_tokens
        or args.prompt_width + args.max_new,
        num_pages=args.num_pages, prefix_cache=not args.no_prefix_cache,
        mesh=args.mesh, paged_attn_impl=args.paged_attn_impl,
        host=args.host, port=args.port, max_queue=args.max_queue,
        default_deadline_s=args.deadline_s, seed=args.seed,
        spec_k=args.spec_k, spec_ngram_max=args.spec_ngram,
        spec_rescore=not args.no_spec_rescore)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=3)
    # ServeConfig fields ---------------------------------------------------
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prompt tokens prefilled per engine iteration "
                         "(0 = whole prompt in one chunk)")
    ap.add_argument("--sync-every", type=int, default=8)
    ap.add_argument("--max-total-tokens", type=int, default=0,
                    help="per-request prompt+completion cap "
                         "(0 = prompt width + --max-new)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV page-pool override (0 = full budget per slot)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable shared-prefix KV page reuse")
    ap.add_argument("--mesh", default="1x1",
                    help="serve mesh DxM (batch over data × tensor "
                         "parallel over model)")
    ap.add_argument("--paged-attn-impl", default=None,
                    choices=("auto", "pallas", "ref", "gather"))
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: drafts per verification "
                         "round (0 = off; continuous engine only)")
    ap.add_argument("--spec-ngram", type=int, default=3,
                    help="longest suffix n-gram the prompt-lookup "
                         "drafter matches")
    ap.add_argument("--no-spec-rescore", action="store_true",
                    help="skip the fused-layers acceptance rescore "
                         "(drops the drift gauge, saves one launch/round)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8100)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="default TTFT deadline applied to front-door "
                         "requests (0 = none)")
    # sampling profile -----------------------------------------------------
    ap.add_argument("--temperature", type=float, default=0.6)
    ap.add_argument("--top-k", type=int, default=20)
    ap.add_argument("--top-p", type=float, default=0.95)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--listen", action="store_true",
                    help="run the HTTP/websocket front door instead of "
                         "batch rounds")
    # observability --------------------------------------------------------
    ap.add_argument("--obs", action="store_true",
                    help="enable the unified metrics registry + span "
                         "tracer (off by default: zero-cost)")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome-trace/Perfetto JSON here on exit "
                         "(implies --obs)")
    args = ap.parse_args()
    args.prompt_width = 8            # ArithmeticTask prompt width below

    if args.obs or args.trace_out:
        obs.configure(True)

    cfg = smoke(args.arch)
    serve = parse_serve_config(args)
    rl = RLConfig(temperature=args.temperature, top_k=args.top_k,
                  top_p=args.top_p, max_new_tokens=args.max_new,
                  engine=serve.engine)
    tok = Tokenizer()
    task = ArithmeticTask(max_operand=99, ops="+-", prompt_width=8,
                          seed=serve.seed)
    plan = plan_from_flag(serve.mesh, "serve")
    print(f"[serve] {plan.describe()}")
    key = jax.random.PRNGKey(serve.seed)
    params = plan.device_put_params(cfg, init_params(cfg, key))

    memory = None
    if cfg.is_encdec:
        frames = jax.random.normal(key, (args.batch, cfg.encoder_seq,
                                         cfg.d_model), jnp.float32)
        memory = encode(cfg, params, frames.astype(cfg.dtype))
    elif cfg.memory_seq:
        memory = 0.02 * jax.random.normal(
            key, (args.batch, cfg.memory_seq, cfg.d_model)
        ).astype(cfg.dtype)

    if args.listen:
        import asyncio

        from repro.serving.server import serve_forever
        if memory is not None:
            raise SystemExit("--listen serves decoder-only KV-cache "
                             "architectures (continuous engine)")
        try:
            asyncio.run(serve_forever(cfg, params, serve, rl=rl,
                                      tokenizer=tok,
                                      vocab_limit=tok.vocab_size, plan=plan,
                                      key=key))
        finally:
            if args.trace_out:
                n = obs.export_chrome_trace(args.trace_out)
                print(f"[serve] wrote {n} trace events -> {args.trace_out}")
        return

    engine = build_engine(cfg, params, serve, rl=rl,
                          vocab_limit=tok.vocab_size, memory=memory,
                          plan=plan, key=key)
    sp = SamplingParams.from_rl(rl)
    total_tok, rid = 0, 0
    t0 = time.time()
    for r in range(args.rounds):
        probs = task.sample_batch(args.batch)
        prompts = encode_prompts(tok, probs)
        key, k = jax.random.split(key)
        reqs = []
        for row in prompts:
            reqs.append(Request(rid=rid, prompt=row, params=sp))
            rid += 1
        t1 = time.time()
        results = engine.generate(reqs, key=k)
        dt = time.time() - t1
        n_tok = sum(res.gen_count for res in results)
        total_tok += n_tok
        outs = [tok.decode(res.tokens) for res in results]
        util = ""
        if hasattr(engine, "stats"):
            st = engine.stats()
            util = (f" | slot-util {st['slot_utilization']:.2f}"
                    f" ({st['decode_steps']} decode steps)")
        print(f"[serve] round {r}: {n_tok} tokens in {dt:.2f}s "
              f"({n_tok/dt:.1f} tok/s){util} | sample: "
              f"{probs[0].prompt.strip()!r} -> {outs[0]!r}")
    print(f"[serve] arch={cfg.name} engine={serve.engine} "
          f"batch={args.batch} total {total_tok} tokens, "
          f"{total_tok/(time.time()-t0):.1f} tok/s incl. compile")
    if args.trace_out:
        n = obs.export_chrome_trace(args.trace_out)
        print(f"[serve] wrote {n} trace events -> {args.trace_out}")


if __name__ == "__main__":
    main()
