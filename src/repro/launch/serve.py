"""Serving driver: batched generation with the KV-cache engine — what a
HeteroRL *sampler node* runs. CPU-scale by default (smoke config); the
full-size serving path is exercised shape-exactly by ``dryrun.py``
(prefill_32k / decode_32k / long_500k).

Two engines (``--engine``):
  static      one lax.scan to --max-new for the whole batch
  continuous  slot pool + paged KV cache; EOS frees the slot for the
              next queued prompt (see repro/sampling/scheduler.py)

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
      --batch 16 --max-new 24 --engine continuous --slots 8

Tensor-parallel serving runs through the same ExecutionPlan as training
(on CPU export the host-device override first):
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.serve --mesh 1x4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RLConfig
from repro.configs import smoke
from repro.data import ArithmeticTask, Tokenizer, encode_prompts
from repro.models import encode, init_params
from repro.parallel import plan_from_flag
from repro.sampling import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--slots", type=int, default=8,
                    help="decode slots (continuous engine)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size in tokens (continuous engine)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prompt tokens prefilled per engine iteration "
                         "(0 = whole prompt in one chunk)")
    ap.add_argument("--sync-every", type=int, default=8,
                    help="decode horizon: jitted decode steps per "
                         "scheduler sync (continuous engine)")
    ap.add_argument("--mesh", default="1x1",
                    help="serve mesh DxM (batch over data × tensor "
                         "parallel over model)")
    ap.add_argument("--paged-attn-impl", default=None,
                    choices=("auto", "pallas", "ref", "gather"),
                    help="paged-decode backend for the continuous "
                         "engine (default: the arch's "
                         "ModelConfig.paged_attn_impl — 'gather', the "
                         "bit-exact legacy view; 'auto' = in-place "
                         "Pallas kernel on TPU / jnp ref elsewhere)")
    ap.add_argument("--temperature", type=float, default=0.6)
    ap.add_argument("--top-k", type=int, default=20)
    ap.add_argument("--top-p", type=float, default=0.95)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke(args.arch)
    if args.paged_attn_impl:
        import dataclasses
        cfg = dataclasses.replace(cfg, paged_attn_impl=args.paged_attn_impl)
    rl = RLConfig(temperature=args.temperature, top_k=args.top_k,
                  top_p=args.top_p, max_new_tokens=args.max_new,
                  engine=args.engine)
    tok = Tokenizer()
    task = ArithmeticTask(max_operand=99, ops="+-", prompt_width=8,
                          seed=args.seed)
    plan = plan_from_flag(args.mesh, "serve")
    print(f"[serve] {plan.describe()}")
    key = jax.random.PRNGKey(args.seed)
    params = plan.device_put_params(cfg, init_params(cfg, key))

    memory = None
    if cfg.is_encdec:
        frames = jax.random.normal(key, (args.batch, cfg.encoder_seq,
                                         cfg.d_model), jnp.float32)
        memory = encode(cfg, params, frames.astype(cfg.dtype))
    elif cfg.memory_seq:
        memory = 0.02 * jax.random.normal(
            key, (args.batch, cfg.memory_seq, cfg.d_model)
        ).astype(cfg.dtype)

    gen_kwargs = {}
    if args.engine == "continuous":
        gen_kwargs = {"num_slots": args.slots, "page_size": args.page_size,
                      "sync_every": args.sync_every}
        if args.prefill_chunk:
            gen_kwargs["prefill_chunk"] = args.prefill_chunk

    total_tok = 0
    t0 = time.time()
    for r in range(args.rounds):
        probs = task.sample_batch(args.batch)
        prompts = jnp.asarray(encode_prompts(tok, probs))
        key, k = jax.random.split(key)
        t1 = time.time()
        roll = generate(cfg, rl, params, prompts, k, max_new=args.max_new,
                        vocab_limit=tok.vocab_size, memory=memory,
                        plan=plan, **gen_kwargs)
        dt = time.time() - t1
        n_tok = int(np.asarray(roll["comp_mask"]).sum())
        total_tok += n_tok
        outs = [tok.decode(row) for row in np.asarray(roll["completions"])]
        util = ""
        if "stats" in roll:
            util = (f" | slot-util {roll['stats']['slot_utilization']:.2f}"
                    f" ({roll['stats']['decode_steps']} decode steps)")
        print(f"[serve] round {r}: {n_tok} tokens in {dt:.2f}s "
              f"({n_tok/dt:.1f} tok/s){util} | sample: "
              f"{probs[0].prompt.strip()!r} -> {outs[0]!r}")
    print(f"[serve] arch={cfg.name} engine={args.engine} "
          f"batch={args.batch} total {total_tok} tokens, "
          f"{total_tok/(time.time()-t0):.1f} tok/s incl. compile")


if __name__ == "__main__":
    main()
