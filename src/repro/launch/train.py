"""Production training driver.

Runs real RL training end-to-end: at CPU scale with a reduced (smoke)
config by default, or lowering the full config on the production mesh when
``--dryrun`` (see ``dryrun.py`` for the full sweep). This is example (b)'s
"end-to-end driver": it trains a small model for a few hundred steps with
any of the paper's loss types, online or heterogeneous.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --loss gepo --steps 200 --mode hetero --max-delay 64

Multi-device (one unified ExecutionPlan drives SFT, RL learner and
samplers; on CPU export the host-device override first):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --mesh 4x2 --sampler-mesh 1x2
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import HeteroConfig, RLConfig, TrainConfig
from repro.configs import smoke
from repro.core.diagnostics import best_last_gap
from repro.data import ArithmeticTask, Tokenizer
from repro.data.tasks import EOS
from repro.hetero import HeteroRuntime, run_online
from repro.models import init_params
from repro.parallel import plan_from_flag
from repro.training import init_state, jit_sft_step


def make_eval_fn(cfg, rl, task, tok, n_prompts=32, seed=1234):
    """Pass@1-style eval on held-out problems (greedy-ish sampling)."""
    from repro.data import score_rollouts
    from repro.sampling import generate
    eval_task = ArithmeticTask(max_operand=task.max_operand, ops=task.ops,
                               prompt_width=task.prompt_width, seed=seed)
    probs = eval_task.sample_batch(n_prompts)
    from repro.data.tasks import encode_prompts
    prompts = jnp.asarray(np.repeat(encode_prompts(tok, probs), 2, axis=0))
    key = jax.random.PRNGKey(seed)

    def eval_fn(params) -> float:
        roll = generate(cfg, rl, params, prompts, key,
                        vocab_limit=tok.vocab_size)
        rewards = score_rollouts(eval_task, tok, probs,
                                 np.asarray(roll["completions"]), 2)
        return float(rewards.mean())
    return eval_fn


def sft_warmstart(cfg, tc, task, tok, state, steps=400, batch=64, seed=0):
    """Supervised warm start (the paper RL-tunes a pretrained model)."""
    rng = np.random.default_rng(seed)
    step_fn = jit_sft_step(cfg, tc)
    width = task.prompt_width + 8
    for _ in range(steps):
        probs = task.sample_batch(batch)
        rows, masks = [], []
        for p in probs:
            ids = tok.encode(p.prompt) + tok.encode(p.answer) + [EOS]
            m = ([0.0] * (len(tok.encode(p.prompt)) - 1)
                 + [1.0] * (len(tok.encode(p.answer)) + 1))
            ids += [0] * (width - len(ids))
            m += [0.0] * (width - 1 - len(m))
            rows.append(ids[:width])
            masks.append(m[:width - 1])
        state, loss = step_fn(state, jnp.asarray(rows, jnp.int32),
                              jnp.asarray(masks, jnp.float32))
    return state, float(loss)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--loss", default="gepo")
    ap.add_argument("--mode", default="online",
                    choices=["online", "hetero"])
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--sft-steps", type=int, default=400)
    ap.add_argument("--group-size", type=int, default=8)
    ap.add_argument("--prompts", type=int, default=8)
    ap.add_argument("--max-delay", type=int, default=64)
    ap.add_argument("--delay-dist", default="lognormal")
    ap.add_argument("--num-samplers", type=int, default=4)
    ap.add_argument("--beta-kl", type=float, default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--logprob-impl", default="fused",
                    choices=["fused", "pallas", "chunked", "naive"],
                    help="learner token-logprob backend (see "
                         "TrainConfig.logprob_impl)")
    ap.add_argument("--mesh", default="1x1",
                    help="learner mesh DxM (data×model), e.g. 2x2; needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         " on CPU")
    ap.add_argument("--sampler-mesh", default="1x1",
                    help="sampler-node mesh DxM (serve-mode tensor "
                         "parallel)")
    ap.add_argument("--paged-attn-impl", default=None,
                    choices=["auto", "pallas", "ref", "gather"],
                    help="sampler paged-decode backend for hetero A/B "
                         "sweeps (HeteroConfig.paged_attn_impl; default "
                         "keeps the arch's ModelConfig knob)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = smoke(args.arch)
    beta = args.beta_kl if args.beta_kl is not None else (
        0.0 if args.mode == "online" else 0.005)   # paper §4.1
    rl = RLConfig(loss_type=args.loss, group_size=args.group_size,
                  beta_kl=beta, max_new_tokens=6, temperature=1.0,
                  top_k=0, top_p=1.0)
    tok = Tokenizer()
    task = ArithmeticTask(max_operand=20, ops="+", prompt_width=6,
                          seed=args.seed)

    # one ExecutionPlan per role; the same plan drives SFT warm start,
    # the RL learner step and (via HeteroConfig) every sampler node
    learner_plan = plan_from_flag(args.mesh, "train")
    sampler_plan = plan_from_flag(args.sampler_mesh, "serve")
    print(f"[train] learner {learner_plan.describe()} | "
          f"samplers {sampler_plan.describe()}")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    tc_sft = TrainConfig(learning_rate=1e-2, total_steps=args.sft_steps,
                         logprob_impl=args.logprob_impl, mesh=args.mesh)
    state = init_state(cfg, tc_sft, params, plan=learner_plan)
    t0 = time.time()
    state, sft_loss = sft_warmstart(cfg, tc_sft, task, tok, state,
                                    steps=args.sft_steps, seed=args.seed)
    print(f"[train] SFT warm start done: loss={sft_loss:.3f} "
          f"({time.time()-t0:.0f}s)")

    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     logprob_impl=args.logprob_impl, mesh=args.mesh)
    state = state._replace(step=jnp.zeros((), jnp.int32))
    eval_fn = make_eval_fn(cfg, rl, task, tok)

    if args.mode == "online":
        hist, evals, learner = run_online(
            cfg, rl, tc, task, tok, state, num_steps=args.steps,
            prompts_per_batch=args.prompts, seed=args.seed,
            eval_fn=eval_fn, eval_every=args.eval_every,
            learner_plan=learner_plan, sampler_plan=sampler_plan)
    else:
        hcfg = HeteroConfig(num_samplers=args.num_samplers,
                            max_delay_steps=args.max_delay,
                            delay_distribution=args.delay_dist,
                            delay_median_s=300.0, seed=args.seed,
                            sampler_mesh=args.sampler_mesh,
                            paged_attn_impl=args.paged_attn_impl)
        rt = HeteroRuntime(cfg, rl, tc, hcfg, task, tok, state,
                           prompts_per_batch=args.prompts,
                           eval_fn=eval_fn, eval_every=args.eval_every)
        hist = rt.run(args.steps)
        evals = rt.eval_scores
        learner = rt.learner

    best, last, gap = best_last_gap(evals)
    summary = {
        "arch": args.arch, "loss": args.loss, "mode": args.mode,
        "steps": learner.step,
        "reward_mean_last20": float(np.mean(hist.get("reward_mean")[-20:])),
        "iw_var_mean": float(np.nanmean(hist.get("iw_var"))),
        "kl_mean": float(np.nanmean(hist.get("kl"))),
        "eval_best": best, "eval_last": last, "best_to_last_gap": gap,
        "staleness_mean": float(np.nanmean(hist.get("staleness"))),
        "wall_s": round(time.time() - t0, 1),
    }
    print("[train] " + json.dumps(summary, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=1)


if __name__ == "__main__":
    main()
