"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step
(per-device program):

  compute    = HLO_FLOPs / peak_bf16_flops
  memory     = HLO_bytes_accessed / HBM_bw
  collective = Σ collective output bytes / ICI_bw

Collective bytes are parsed from the post-SPMD optimized HLO
(``compiled.as_text()``) — they are not part of ``cost_analysis``.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

from repro.config import ModelConfig, ShapeConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_BF16_FLOPS

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def normalize_cost_analysis(cost) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns a per-device list on
    jax<=0.4.x and a flat dict on newer releases; normalize to a dict.
    (Lives here, not in dryrun.py — importing dryrun mutates XLA_FLAGS.)"""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Map computation name -> body text from an HLO dump."""
    comps: Dict[str, str] = {}
    cur_name = None
    cur_lines = []
    for line in hlo_text.splitlines():
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*"
                     r"\([^)]*\)? ?.*-> .*\{\s*$", line)
        if not line.startswith(" ") and "{" in line and "->" in line:
            m2 = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line)
            if m2:
                if cur_name is not None:
                    comps[cur_name] = "\n".join(cur_lines)
                cur_name = m2.group(1)
                cur_lines = []
                if "ENTRY" in line:
                    comps["__entry__"] = cur_name
                continue
        if cur_name is not None:
            if line.startswith("}"):
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
                cur_lines = []
            else:
                cur_lines.append(line)
    if cur_name is not None:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:call|conditional)\([^)]*\).*?"
                      r"(?:to_apply|branch_computations)=\{?%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _trip_count(cond_text: str) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def parse_collectives_loop_aware(hlo_text: str) -> Dict[str, int]:
    """Collective result bytes, multiplying ops inside ``while`` bodies by
    their trip count (scan-over-blocks would otherwise be counted once).
    Trip counts are read from the loop-condition constant."""
    comps = _split_computations(hlo_text)
    entry = comps.get("__entry__")
    memo: Dict[str, Dict[str, int]] = {}

    def direct(text: str) -> Dict[str, int]:
        out = {k: 0 for k in COLLECTIVES}
        for m in _OP_RE.finditer(text):
            if "-done(" in m.group(0):
                continue
            out[m.group(2)] += _shape_bytes(m.group(1))
        return out

    def total(name: str, seen=()) -> Dict[str, int]:
        if name in memo:
            return memo[name]
        if name in seen or name not in comps or name == "__entry__":
            return {k: 0 for k in COLLECTIVES}
        text = comps[name]
        out = direct(text)
        for wm in _WHILE_RE.finditer(text):
            cond, body = wm.group(1), wm.group(2)
            trips = _trip_count(comps.get(cond, ""))
            sub = total(body, seen + (name,))
            for k in out:
                out[k] += trips * sub[k]
        for cm in _CALL_RE.finditer(text):
            sub = total(cm.group(1), seen + (name,))
            for k in out:
                out[k] += sub[k]
        memo[name] = out
        return out

    if entry is None:
        return direct(hlo_text)
    return total(entry)


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of result bytes per collective kind (``-start`` ops only are
    counted once; ``-done`` carries no new transfer)."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        if "-done(" in m.group(0):
            continue
        out[kind] += _shape_bytes(type_str)
    # avoid double counting async pairs: the regex above already skips
    # -done; -start results include both operand+result aliased buffers,
    # which we accept as the transfer upper bound.
    return out


def entry_io_bytes(hlo_text: str) -> Tuple[int, int]:
    """Per-device (argument, result) bytes from the SPMD ENTRY signature —
    the authoritative post-partitioning shapes."""
    m = re.search(r"ENTRY %?[\w.\-]+ \((.*?)\) -> (.+?) \{", hlo_text, re.S)
    if not m:
        return 0, 0
    return _shape_bytes(m.group(1)), _shape_bytes(m.group(2))


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Ideal algorithmic FLOPs per step, global: 6·N·D (train, fwd+bwd) or
    2·N·D (inference fwd), N = *active* params."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch                # one token per sequence
    return 2.0 * n_active * tokens


def roofline(flops_per_dev: float, bytes_per_dev: float,
             coll_bytes_per_dev: float) -> Dict[str, float]:
    t_c = flops_per_dev / PEAK_BF16_FLOPS
    t_m = bytes_per_dev / HBM_BW
    t_n = coll_bytes_per_dev / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "bottleneck": dom[1]}


def fmt_row(name: str, terms: Dict[str, float]) -> str:
    return (f"{name:55s} comp={terms['compute_s']*1e3:9.3f}ms "
            f"mem={terms['memory_s']*1e3:9.3f}ms "
            f"coll={terms['collective_s']*1e3:9.3f}ms "
            f"-> {terms['bottleneck']}")
