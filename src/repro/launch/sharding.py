"""Back-compat shim: logical-axis → mesh-axis resolution now lives in
``repro.parallel.axes``; running code should consume it through
``repro.parallel.ExecutionPlan`` rather than resolving specs by hand."""
from repro.parallel.axes import (MODES, act_sharding_for, batch_specs,  # noqa: F401
                                 cache_specs, fit_spec, opt_specs,
                                 param_specs, resolve_spec, to_named,
                                 to_named_fit)

__all__ = ["MODES", "resolve_spec", "param_specs", "opt_specs",
           "batch_specs", "cache_specs", "act_sharding_for", "to_named",
           "fit_spec", "to_named_fit"]
