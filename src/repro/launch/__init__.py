from repro.parallel.mesh import (HBM_BW, ICI_BW, PEAK_BF16_FLOPS,
                                 make_debug_mesh, make_production_mesh)

__all__ = ["make_production_mesh", "make_debug_mesh", "PEAK_BF16_FLOPS",
           "HBM_BW", "ICI_BW"]
