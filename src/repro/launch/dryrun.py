import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers + compiles with coherent sharding, and extract the
memory/cost/collective numbers feeding EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      [--arch all|<id>] [--shape all|<name>] [--mesh single|multi|both] \
      [--out results/dryrun] [--list]

One real CPU device backs 512 placeholder devices (the XLA_FLAGS line
above MUST precede any jax import — device count locks on first init).
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (INPUT_SHAPES, RLConfig, SHAPES_BY_NAME,
                          ShapeConfig, TrainConfig)
from repro.configs import ALL, ARCHS, get_config, supports_shape
from repro.launch import step_fns as sf
from repro.launch.costmodel import bytes_estimate, flops_estimate
from repro.parallel import ExecutionPlan, data_axes, make_production_mesh
from repro.parallel.axes import act_sharding_for
from repro.launch.roofline import (entry_io_bytes, model_flops,
                                   normalize_cost_analysis,
                                   parse_collective_bytes,
                                   parse_collectives_loop_aware, roofline)


def _mode_for(shape: ShapeConfig) -> str:
    if shape.kind == "train":
        return "train"
    return "long" if shape.name == "long_500k" else "serve"


def _tree_bytes(tree: Any) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


def lower_combo(arch: str, shape_name: str, mesh, *,
                rl: Optional[RLConfig] = None,
                optimized: bool = False,
                verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one (arch, shape) on a mesh; return the §Dry-run /
    §Roofline record. ``optimized`` applies the beyond-baseline §Perf
    configuration (shard_map expert-parallel MoE)."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mode = _mode_for(shape)
    n_dev = mesh.devices.size
    rl = rl or RLConfig(group_size=8)
    dp_prod = 1
    for ax in data_axes(mesh):
        dp_prod *= mesh.shape[ax]
    # micro-batches must still cover the data axes
    accum = max(1, min(sf.grad_accum_for(cfg),
                       shape.global_batch // dp_prod))
    tc = TrainConfig(grad_accum=accum)

    pmode = mode                    # parameter-sharding mode
    if optimized and mode == "train" and not cfg.num_experts:
        pmode = "train_fsdp"        # §Perf H-A3: pure ZeRO-3, no TP
        tc = TrainConfig(grad_accum=1)
    # the same ExecutionPlan type the runtime executes with — the dry-run
    # only *lowers* against its sharding trees instead of re-deriving them
    plan = ExecutionPlan(mesh=mesh, mode=pmode)
    act = act_sharding_for(pmode, mesh)
    cfg = dataclasses.replace(cfg, act_sharding=act)
    if optimized and shape.kind == "decode" and "local" in cfg.block_pattern:
        # §Perf H-G1: ring-buffer KV for sliding-window layers
        cfg = dataclasses.replace(cfg, local_ring_kv=True)
    if optimized and cfg.num_experts and shape.kind in ("train", "prefill"):
        # EP MoE only where the token count is large; decode steps route
        # B tokens — the GSPMD path is already cheap there (measured:
        # EP at long_500k replicates the 500k-token dispatch, 18 GiB).
        cfg = dataclasses.replace(
            cfg, moe_ep=("train" if mode == "train" else "serve"),
            ep_dp_axes=data_axes(mesh))

    t0 = time.time()
    from repro.runtime_context import mesh_context
    with mesh_context(mesh):
        if mode == "train":
            step = sf.make_train_fn(cfg, rl, tc, plan=plan)
            state = sf.abstract_state(cfg)
            batch = sf.abstract_batch(cfg, shape)
            in_sh = (plan.state_shardings(cfg, sf.optimizer_for(cfg)),
                     plan.batch_shardings(cfg, batch))
            out_sh = (in_sh[0], None)
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(state, batch)
        elif mode in ("serve", "long") and shape.kind == "prefill":
            step = sf.make_prefill_fn(cfg, shape.seq_len)
            params = sf.abstract_params(cfg)
            batch = {k: v for k, v in sf.abstract_batch(cfg, shape).items()
                     if k in ("tokens", "frames", "image_embeds")}
            cache = sf.abstract_cache(cfg, shape.global_batch,
                                      shape.seq_len)
            dp = data_axes(mesh)
            in_sh = (plan.param_shardings(cfg),
                     plan.batch_shardings(cfg, batch))
            out_sh = (NamedSharding(mesh, P(dp)),
                      plan.cache_shardings(cfg, cache))
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(params, batch)
        else:                                        # decode
            step = sf.make_decode_fn(cfg)
            params = sf.abstract_params(cfg)
            cache = sf.abstract_cache(cfg, shape.global_batch,
                                      shape.seq_len)
            token = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            dp = data_axes(mesh)
            tok_spec = P() if mode == "long" else P(dp)
            csh = plan.cache_shardings(cfg, cache)
            in_sh = (plan.param_shardings(cfg), csh,
                     NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()))
            out_sh = (NamedSharding(mesh, tok_spec), csh)
            lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=(1,)).lower(params, cache,
                                                         token, pos)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = normalize_cost_analysis(compiled.cost_analysis())
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            mem_rec[attr] = int(getattr(mem, attr, 0) or 0)
    hlo = compiled.as_text()
    coll = parse_collectives_loop_aware(hlo)
    coll_once = parse_collective_bytes(hlo)
    coll_total = float(sum(coll.values()))
    arg_b, out_b = entry_io_bytes(hlo)

    # compute/memory terms from the analytic cost model (cost_analysis
    # counts while bodies once — see costmodel.py docstring); collective
    # term from the loop-aware HLO parse.
    flops_impl = flops_estimate(cfg, shape) / n_dev
    flops_ideal = flops_estimate(cfg, shape, ideal=True) / n_dev
    byt = bytes_estimate(cfg, shape, n_dev,
                         optimizer=sf.optimizer_for(cfg))
    terms = roofline(flops_impl, byt["total"], coll_total)
    mflops = model_flops(cfg, shape)
    record = {
        "arch": arch, "shape": shape_name, "mode": mode,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "devices": int(n_dev),
        "flops_per_dev": flops_impl,
        "flops_per_dev_ideal": flops_ideal,
        "bytes_per_dev": byt["total"],
        "bytes_breakdown": {k: v for k, v in byt.items() if k != "total"},
        "collective_bytes_per_dev": coll_total,
        "collectives": {k: int(v) for k, v in coll.items()},
        "collectives_body_once": {k: int(v) for k, v in coll_once.items()},
        "raw_cost_analysis": {"flops": raw_flops, "bytes": raw_bytes},
        "memory_analysis": mem_rec,
        "roofline": terms,
        "model_flops_global": mflops,
        "model_flops_per_dev": mflops / n_dev,
        "useful_flops_frac": (mflops / n_dev) / flops_impl
        if flops_impl else None,
        "entry_arg_bytes_per_dev": arg_b,
        "entry_out_bytes_per_dev": out_b,
        "hbm_fit_16g": (arg_b + mem_rec.get("temp_size_in_bytes", 0)
                        ) / 2**30 < 16.0 if mem_rec else None,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    if verbose:
        ma = mem_rec.get("argument_size_in_bytes", 0)
        mt = mem_rec.get("temp_size_in_bytes", 0)
        print(f"[dryrun] {arch} × {shape_name} × {record['mesh']}: "
              f"COMPILED in {t_compile:.1f}s | "
              f"args={ma/2**30:.2f}GiB temp={mt/2**30:.2f}GiB "
              f"fit16G={record['hbm_fit_16g']} | "
              f"flops/dev={flops_impl:.3e} bytes/dev={byt['total']:.3e} "
              f"coll/dev={coll_total:.3e} -> {terms['bottleneck']}",
              flush=True)
        print(f"         memory_analysis: {mem_rec}")
        print(f"         cost_analysis(raw): flops={raw_flops:.4e} "
              f"bytes={raw_bytes:.4e} | useful_frac="
              f"{record['useful_flops_frac']:.3f}")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--include-paper-archs", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply §Perf beyond-baseline config (EP MoE)")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    archs = (list(ALL) if args.include_paper_archs else list(ARCHS)) \
        if args.arch == "all" else [args.arch]
    shapes = [s.name for s in INPUT_SHAPES] if args.shape == "all" \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    combos = [(a, s, m) for a in archs for s in shapes for m in meshes]
    if args.list:
        for a, s, m in combos:
            sup = supports_shape(a, s)
            print(f"{a} × {s} × {'2x16x16' if m else '16x16'}"
                  f"{'' if sup else '   [SKIP: sub-quadratic gate]'}")
        return

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for a, s, m in combos:
        mesh_name = "2x16x16" if m else "16x16"
        if not supports_shape(a, s):
            print(f"[dryrun] {a} × {s} × {mesh_name}: SKIP "
                  f"(full-attention arch, no sub-quadratic variant — "
                  f"see DESIGN.md §Arch-applicability)", flush=True)
            n_skip += 1
            continue
        mesh = make_production_mesh(multi_pod=m)
        try:
            rec = lower_combo(a, s, mesh, optimized=args.optimized)
            suffix = "__opt" if args.optimized else ""
            fn = os.path.join(args.out,
                              f"{a}__{s}__{mesh_name}{suffix}.json")
            with open(fn, "w") as f:
                json.dump(rec, f, indent=1)
            n_ok += 1
        except Exception:
            print(f"[dryrun] {a} × {s} × {mesh_name}: FAILED", flush=True)
            traceback.print_exc()
            n_fail += 1
    print(f"[dryrun] done: {n_ok} compiled, {n_skip} skipped, "
          f"{n_fail} failed", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
