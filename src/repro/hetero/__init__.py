from repro.hetero.events import EventSim, Transport
from repro.hetero.latency import DISTRIBUTIONS, sample_delay, sync_delay_s
from repro.hetero.nodes import LearnerNode, RolloutBatch, SamplerNode
from repro.hetero.runtime import HeteroRuntime, run_online
from repro.hetero.threads import ThreadedHeteroRuntime

__all__ = ["EventSim", "Transport", "sample_delay", "sync_delay_s",
           "DISTRIBUTIONS", "LearnerNode", "SamplerNode", "RolloutBatch",
           "HeteroRuntime", "run_online", "ThreadedHeteroRuntime"]
