"""HeteroRL orchestration.

``HeteroRuntime`` wires one learner + N samplers (star topology) into the
discrete-event simulation: samplers generate continuously and sync models
after WAN delays D_M ~ P_d; the learner trains on arriving batches inside
its staleness window. ``run_online`` is the synchronous (delay-0) control
used for Table 1.

Time model (defaults follow the paper's scale): one learner step costs
``learner_step_s`` simulated seconds; the paper's 1800 s max delay then
corresponds to 1800/28.125 = 64 learner steps — the "Max Tolerable
Delay 64" setting of Table 2.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from repro import obs
from repro.checkpoint import PolicyStore
from repro.config import HeteroConfig, ModelConfig, RLConfig, TrainConfig
from repro.core.diagnostics import MetricsHistory
from repro.data import ArithmeticTask, PromptPipeline, Tokenizer
from repro.hetero.events import EventSim, Transport
from repro.hetero.nodes import (LearnerNode, RolloutBatch, SamplerNode,
                                link_telemetry)
from repro.parallel import ExecutionPlan
from repro.training import TrainState


class HeteroRuntime:
    def __init__(self, cfg: ModelConfig, rl: RLConfig, tc: TrainConfig,
                 hcfg: HeteroConfig, task: ArithmeticTask, tok: Tokenizer,
                 state: TrainState, *, prompts_per_batch: int = 8,
                 learner_step_s: float = 28.125,
                 sampler_gen_s: Optional[float] = None,
                 eval_fn: Optional[Callable[[Any], float]] = None,
                 eval_every: int = 10,
                 learner_plan: Optional[ExecutionPlan] = None,
                 sampler_plan: Optional[ExecutionPlan] = None) -> None:
        self.cfg, self.rl, self.tc, self.hcfg = cfg, rl, tc, hcfg
        self.task, self.tok = task, tok
        self.learner_step_s = learner_step_s
        # keep producer/consumer rates balanced by default
        self.sampler_gen_s = (sampler_gen_s if sampler_gen_s is not None
                              else learner_step_s * hcfg.num_samplers)
        self.eval_fn, self.eval_every = eval_fn, eval_every
        self.eval_scores: List[float] = []

        self.sim = EventSim()
        # observability rides the virtual clock: spans recorded during
        # this run carry simulated seconds, so an EventSim trace loads in
        # Perfetto exactly like a live one (enable obs before building
        # the runtime, or re-point the clock later via obs.configure)
        if obs.trace.enabled:
            obs.trace.use_sim(self.sim)
        self.transport = Transport(self.sim)
        self.store = PolicyStore()
        self.learner = LearnerNode(cfg, rl, tc, hcfg, state, self.store,
                                   plan=learner_plan)
        self.samplers = [
            SamplerNode(i, cfg, rl,
                        PromptPipeline(task, tok, prompts_per_batch,
                                       rl.group_size),
                        task, tok, self.learner.state.params, self.store,
                        hcfg, seed=hcfg.seed * 1000 + i,
                        logprob_impl=tc.logprob_impl, plan=sampler_plan)
            for i in range(hcfg.num_samplers)
        ]
        self._learner_busy = False
        self._target_steps = 0

    # ---- event handlers --------------------------------------------------
    def _sampler_gen_done(self, s: SamplerNode) -> None:
        batch = s.generate_batch(self.sim.now)
        # the generation occupied the simulated window ending now — an
        # explicitly-timed span, since sim.now doesn't advance inside
        # the handler (the node's own spans are zero-width markers here)
        obs.trace.complete("gen_window",
                           max(self.sim.now - self.sampler_gen_s, 0.0),
                           self.sim.now, track=f"sampler-{s.sid}",
                           version=batch.version)
        # data transfer is folded into the model-sync delay (App. E.1)
        self.transport.send(0.0,
                            lambda b=batch: self._deliver(b),
                            nbytes=batch.nbytes())
        self.sim.schedule(self.sampler_gen_s,
                          lambda s=s: self._sampler_gen_done(s))

    def _sampler_sync(self, s: SamplerNode) -> None:
        # payload-aware D_M: the bytes this sync moved (manifest + missing
        # chunks) charge serialization time on the *next* sync gap — with
        # HeteroConfig.bandwidth_mbps=inf this is exactly the legacy delay
        moved = s.sync()
        self.sim.schedule(s.next_delay(moved),
                          lambda s=s: self._sampler_sync(s))

    def _deliver(self, batch: RolloutBatch) -> None:
        self.learner.receive(self.sim.now, batch)
        self._maybe_start_step()

    def _maybe_start_step(self) -> None:
        if self._learner_busy or self.learner.step >= self._target_steps:
            return
        batch = self.learner.pop_eligible(self.sim.now)
        if batch is None:
            return
        self._learner_busy = True
        self.sim.schedule(self.learner_step_s,
                          lambda b=batch: self._finish_step(b))

    def _finish_step(self, batch: RolloutBatch) -> None:
        self.learner.train_on(batch)
        # the step occupied the simulated window [now - step_s, now]
        obs.trace.complete("step_window",
                           max(self.sim.now - self.learner_step_s, 0.0),
                           self.sim.now, track="learner",
                           step=self.learner.step,
                           staleness=self.learner.step - 1 - batch.version)
        self._learner_busy = False
        if (self.eval_fn is not None
                and self.learner.step % self.eval_every == 0):
            score = self.eval_fn(self.learner.state.params)
            self.eval_scores.append(score)
            self.learner.history.append(self.learner.step,
                                        {"eval_score": score})
        self._maybe_start_step()

    def sync_telemetry(self) -> List[Dict[str, float]]:
        """Per-sampler weight-transport telemetry (bytes on wire, dedup
        ratio, simulated sync seconds) plus the learner's publish-side
        stream accounting."""
        return link_telemetry(self.samplers, self.learner)

    # ---- drivers ----------------------------------------------------------
    def run(self, num_learner_steps: int) -> MetricsHistory:
        self._target_steps = num_learner_steps
        for s in self.samplers:
            self.sim.schedule(self.sampler_gen_s / max(len(self.samplers), 1)
                              * s.sid, lambda s=s: self._sampler_gen_done(s))
            self.sim.schedule(s.next_delay(),
                              lambda s=s: self._sampler_sync(s))
        self.sim.run_until(stop=lambda: self.learner.step
                           >= num_learner_steps)
        return self.learner.history


def run_online(cfg: ModelConfig, rl: RLConfig, tc: TrainConfig,
               task: ArithmeticTask, tok: Tokenizer, state: TrainState, *,
               num_steps: int, prompts_per_batch: int = 8, seed: int = 0,
               eval_fn: Optional[Callable[[Any], float]] = None,
               eval_every: int = 10,
               learner_plan: Optional[ExecutionPlan] = None,
               sampler_plan: Optional[ExecutionPlan] = None):
    """Synchronous on-policy RL (Max Tolerable Delay 0, Table 1): the
    sampler always holds the learner's current parameters. Plans default
    to the ``TrainConfig.mesh`` knob (learner) / 1×1 (sampler)."""
    hcfg = HeteroConfig(num_samplers=1, max_delay_steps=0,
                        delay_distribution="constant", delay_min_s=0.0,
                        delay_median_s=0.0, seed=seed)
    store = PolicyStore()
    learner = LearnerNode(cfg, rl, tc, hcfg, state, store,
                          plan=learner_plan)
    pipeline = PromptPipeline(task, tok, prompts_per_batch, rl.group_size)
    sampler = SamplerNode(0, cfg, rl, pipeline, task, tok,
                          learner.state.params, store, hcfg, seed=seed,
                          logprob_impl=tc.logprob_impl, plan=sampler_plan)
    eval_scores: List[float] = []
    for step in range(num_steps):
        # strict synchrony: re-placed from the learner every step (the
        # learner's sharded step donates the previous buffers right after)
        sampler.params = sampler.plan.device_put_params(
            cfg, learner.state.params)
        sampler.version = learner.step
        batch = sampler.generate_batch(float(step))
        learner.receive(float(step), batch)
        b = learner.pop_eligible(float(step))
        learner.train_on(b)
        if eval_fn is not None and learner.step % eval_every == 0:
            score = eval_fn(learner.state.params)
            eval_scores.append(score)
            learner.history.append(learner.step, {"eval_score": score})
    return learner.history, eval_scores, learner
