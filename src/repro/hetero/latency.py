"""Network-latency models (App. E.1).

The paper simulates WAN delays with log-normal (default), Weibull and
exponential distributions, bounded to [60 s, 1800 s]; the default median
delay is 60 s. Weibull is reported as the most challenging (Table 7).
"""
from __future__ import annotations

import numpy as np

from repro.config import HeteroConfig

DISTRIBUTIONS = ("lognormal", "weibull", "exponential", "constant")


def sample_delay(rng: np.random.Generator, hcfg: HeteroConfig) -> float:
    """One model-sync delay D_M in (simulated) seconds."""
    med = hcfg.delay_median_s
    dist = hcfg.delay_distribution
    if dist == "lognormal":
        # sigma chosen so the 99.5% CI spans ~[lo, hi] around the median
        sigma = float(np.log(hcfg.delay_max_s / max(med, 1e-9))) / 2.807
        d = rng.lognormal(mean=np.log(med), sigma=max(sigma, 1e-3))
    elif dist == "weibull":
        k = 1.2                                    # heavy-ish tail
        lam = med / np.log(2.0) ** (1.0 / k)       # median-matched scale
        d = lam * rng.weibull(k)
    elif dist == "exponential":
        d = rng.exponential(med / np.log(2.0))     # median-matched
    elif dist == "constant":
        d = med
    else:
        raise ValueError(f"unknown delay distribution {dist!r}")
    return float(np.clip(d, hcfg.delay_min_s, hcfg.delay_max_s))


def sync_delay_s(rng: np.random.Generator, hcfg: HeteroConfig,
                 payload_bytes: int = 0) -> float:
    """Payload-aware model-sync delay: sampled propagation (D_M as above,
    clipped) plus ``payload_bytes / bandwidth`` serialization time. With
    ``bandwidth_mbps=inf`` (the default) or zero payload this is exactly
    ``sample_delay`` — same rng draw, bit-compatible with the legacy
    payload-blind model, so the ``constant`` distribution and existing
    table benchmarks reproduce unchanged."""
    from repro.transport.link import serialization_seconds
    base = sample_delay(rng, hcfg)
    if payload_bytes <= 0:
        return base
    return base + serialization_seconds(
        payload_bytes, getattr(hcfg, "bandwidth_mbps", float("inf")))
