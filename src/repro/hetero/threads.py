"""Real-async HeteroRL runtime: learner and sampler nodes as OS threads
with wall-clock delays — the in-process analogue of the paper's ZeroMQ
deployment (App. E.2). The event-sim runtime (`runtime.py`) is the
deterministic default; this backend demonstrates that the node interfaces
(PolicyStore / queue transport / version-stamped batches) carry over to
true asynchrony unchanged.

Delays are scaled: 1 simulated second = ``time_scale`` wall seconds, so a
1800 s WAN delay runs in ~0.18 s by default.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Optional

from repro import obs
from repro.checkpoint import PolicyStore
from repro.config import HeteroConfig, ModelConfig, RLConfig, TrainConfig
from repro.core.diagnostics import MetricsHistory
from repro.data import ArithmeticTask, PromptPipeline, Tokenizer
from repro.hetero.nodes import (LearnerNode, RolloutBatch, SamplerNode,
                                link_telemetry)
from repro.parallel import ExecutionPlan
from repro.training import TrainState


class ThreadedHeteroRuntime:
    def __init__(self, cfg: ModelConfig, rl: RLConfig, tc: TrainConfig,
                 hcfg: HeteroConfig, task: ArithmeticTask, tok: Tokenizer,
                 state: TrainState, *, prompts_per_batch: int = 4,
                 time_scale: float = 1e-4,
                 queue_size: int = 16,
                 learner_plan: Optional[ExecutionPlan] = None,
                 sampler_plan: Optional[ExecutionPlan] = None) -> None:
        self.hcfg = hcfg
        self.time_scale = time_scale
        self.store = PolicyStore()
        self.learner = LearnerNode(cfg, rl, tc, hcfg, state, self.store,
                                   plan=learner_plan)
        self.queue: queue.Queue[RolloutBatch] = queue.Queue(queue_size)
        # each sampler owns a plan-placed *copy* of the params (SamplerNode
        # ctor) — the learner thread's donated step never touches them
        self.samplers = [
            SamplerNode(i, cfg, rl,
                        PromptPipeline(task, tok, prompts_per_batch,
                                       rl.group_size),
                        task, tok, self.learner.state.params, self.store,
                        hcfg, seed=hcfg.seed * 1000 + i,
                        logprob_impl=tc.logprob_impl, plan=sampler_plan)
            for i in range(hcfg.num_samplers)
        ]
        self._stop = threading.Event()
        self._t0 = time.monotonic()

    # wall-clock stands in for the virtual clock
    def _now_s(self) -> float:
        return (time.monotonic() - self._t0) / self.time_scale

    def _sampler_loop(self, s: SamplerNode) -> None:
        # pin this worker thread's trace track so wall-clock spans land
        # on the same named timeline the EventSim runtime uses
        obs.trace.set_track(f"sampler-{s.sid}")
        next_sync = self._now_s() + s.next_delay()
        while not self._stop.is_set():
            batch = s.generate_batch(self._now_s())
            try:
                self.queue.put(batch, timeout=1.0)
            except queue.Full:
                pass                      # drop under backpressure
            if self._now_s() >= next_sync:
                # chunked delta sync; the bytes moved charge serialization
                # time on the next sync gap (no-op at bandwidth inf)
                try:
                    moved = s.sync()
                except KeyError:
                    # lost the publisher prune race even after retries:
                    # skip this round rather than killing the daemon
                    # thread — the next interval syncs a newer version
                    moved = 0
                next_sync = self._now_s() + s.next_delay(moved)

    def sync_telemetry(self):
        """Per-sampler link telemetry + learner publish accounting (same
        shape as HeteroRuntime.sync_telemetry)."""
        return link_telemetry(self.samplers, self.learner)

    def run(self, num_learner_steps: int) -> MetricsHistory:
        obs.trace.set_track("learner")
        threads = [threading.Thread(target=self._sampler_loop, args=(s,),
                                    daemon=True) for s in self.samplers]
        for t in threads:
            t.start()
        try:
            while self.learner.step < num_learner_steps:
                try:
                    batch = self.queue.get(timeout=30.0)
                except queue.Empty as e:
                    raise RuntimeError(
                        "samplers starved the learner") from e
                self.learner.receive(self._now_s(), batch)
                b = self.learner.pop_eligible(self._now_s())
                if b is not None:
                    self.learner.train_on(b)
        finally:
            self._stop.set()
            for t in threads:
                t.join(timeout=10.0)
        return self.learner.history
