"""Deterministic discrete-event simulation kernel.

HeteroRL's decentralized star topology runs as a virtual-clock simulation:
every node action (generate a batch, take a learner step, deliver a
checkpoint) is an event with a simulated duration. This makes multi-node
asynchrony — including the latency→staleness→KL causal chain of Fig. 5 —
fully reproducible on one host. The node interfaces (``Transport``,
``PolicyStore``) match what a real ZeroMQ deployment (App. E.2) would
implement.
"""
from __future__ import annotations

import heapq
import itertools
import threading
from typing import Callable, List, Optional, Tuple


class EventSim:
    """Event queue + virtual clock.

    The queue is lock-guarded so event handlers may be scheduled from
    helper threads (the threaded hetero runtime shares stores with the
    sim-driven one); handlers themselves always run on whichever thread
    drives :meth:`step`, *outside* the lock, so they can reschedule
    reentrantly."""

    def __init__(self) -> None:
        self.now = 0.0
        self._q: List[Tuple[float, int, Callable[[], None]]] = []
        self._counter = itertools.count()
        self._lock = threading.Lock()

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        assert delay >= 0.0, delay
        with self._lock:
            heapq.heappush(self._q,
                           (self.now + delay, next(self._counter), fn))

    def step(self) -> bool:
        with self._lock:
            if not self._q:
                return False
            t, _, fn = heapq.heappop(self._q)
            self.now = t
        fn()
        return True

    def run_until(self, t_end: float = float("inf"),
                  stop: Optional[Callable[[], bool]] = None) -> None:
        while self._q and self.now <= t_end:
            if stop is not None and stop():
                return
            self.step()


class Transport:
    """Star-topology message passing with per-message delay."""

    def __init__(self, sim: EventSim) -> None:
        self.sim = sim
        self.messages_sent = 0
        self.bytes_sent = 0
        self._lock = threading.Lock()

    def send(self, delay_s: float, deliver: Callable[[], None],
             nbytes: int = 0) -> None:
        with self._lock:
            self.messages_sent += 1
            self.bytes_sent += nbytes
        self.sim.schedule(delay_s, deliver)
