"""Learner and Sampler nodes of the HeteroRL star topology (§4.1, Fig. 3).

- Sampler nodes continuously generate rollout groups with their (stale)
  policy copy, score them locally (App. F localized rewards — group
  statistics never cross the network), and stream version-stamped batches
  to the learner.
- The learner consumes batches in arrival order inside a fixed
  time-window / staleness-window, updates parameters, and periodically
  publishes checkpoints to the ``PolicyStore``; samplers pull the latest
  version only after their simulated WAN delay D_M.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import PolicyStore, load_pytree, save_pytree
from repro.config import HeteroConfig, ModelConfig, RLConfig, TrainConfig
from repro.core.diagnostics import MetricsHistory
from repro.data import PromptPipeline, score_rollouts
from repro.data.tasks import ArithmeticTask, Tokenizer
from repro.hetero.events import EventSim, Transport
from repro.hetero.latency import sample_delay
from repro.parallel import ExecutionPlan, plan_from_flag
from repro.sampling import generate, token_logps
from repro.training import TrainState, jit_train_step


@dataclasses.dataclass
class RolloutBatch:
    tokens: np.ndarray          # (B, T)
    mask: np.ndarray            # (B, T-1) target-position mask
    sampler_lp: np.ndarray      # (B, T-1)
    rewards: np.ndarray         # (B,) group-contiguous
    version: int                # policy version that generated it
    created_s: float
    sampler_id: int

    def nbytes(self) -> int:
        return (self.tokens.nbytes + self.mask.nbytes
                + self.sampler_lp.nbytes + self.rewards.nbytes)


class SamplerNode:
    """Generates rollouts with a possibly-stale policy copy."""

    def __init__(self, sid: int, cfg: ModelConfig, rl: RLConfig,
                 pipeline: PromptPipeline, task: ArithmeticTask,
                 tok: Tokenizer, params: Any, store: PolicyStore,
                 hcfg: HeteroConfig, seed: int,
                 engine: Optional[str] = None,
                 logprob_impl: str = "fused",
                 plan: Optional[ExecutionPlan] = None) -> None:
        self.sid = sid
        self.cfg, self.rl = cfg, rl
        self.pipeline, self.task, self.tok = pipeline, task, tok
        # serve-mode execution plan of this node (defaults to the
        # HeteroConfig.sampler_mesh knob). The node owns a *copy* of the
        # params placed on its plan: the learner's sharded step donates
        # its buffers, so a by-reference alias would die under it.
        self.plan = plan or plan_from_flag(hcfg.sampler_mesh, "serve")
        self.params = self.plan.device_put_params(cfg, params, copy=True)
        self.store = store
        self.hcfg = hcfg
        self.engine = engine or rl.engine
        # backend of the App. B.1 recompute — follows the learner's
        # TrainConfig.logprob_impl so A/B runs switch both halves
        self.logprob_impl = logprob_impl
        self.version = 0
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)
        self.batches_generated = 0
        self.syncs = 0
        # operator telemetry: generation rate of this node (the service
        # rate of the rollout queue in the HeteroRL picture) plus the
        # last rollout's engine stats, exposed via tokens_per_s below.
        # The first generate call pays jit compilation; it is accounted
        # separately (warmup_*) so tokens_per_s reports the steady-state
        # rate — the same convention as benchmarks/serve_throughput.py,
        # which warms executables outside the timed region.
        self.tokens_generated = 0
        self.gen_seconds = 0.0
        self.warmup_tokens = 0
        self.warmup_seconds = 0.0
        self.engine_stats: Dict[str, float] = {}

    @property
    def tokens_per_s(self) -> float:
        """Steady-state generation rate (first-call compile excluded);
        falls back to the warmup-inclusive rate until a second batch has
        been generated."""
        if self.gen_seconds:
            return self.tokens_generated / self.gen_seconds
        if self.warmup_seconds:
            return self.warmup_tokens / self.warmup_seconds
        return 0.0

    def generate_batch(self, now_s: float) -> RolloutBatch:
        req = self.pipeline.next_batch()
        prompts = jnp.asarray(req.prompts)
        self.key, k = jax.random.split(self.key)
        t0 = time.perf_counter()
        roll = generate(self.cfg, self.rl, self.params, prompts, k,
                        vocab_limit=self.tok.vocab_size, engine=self.engine,
                        plan=self.plan)
        ntok = int(np.asarray(roll["comp_mask"]).sum())
        dt = time.perf_counter() - t0
        if self.batches_generated == 0:         # jit compile folded in
            self.warmup_tokens += ntok
            self.warmup_seconds += dt
        else:
            self.tokens_generated += ntok
            self.gen_seconds += dt
        if "stats" in roll:
            self.engine_stats = dict(roll["stats"])
        rewards = score_rollouts(self.task, self.tok, req.problems,
                                 np.asarray(roll["completions"]),
                                 req.group_size)
        b, tp = prompts.shape
        if self.rl.recompute_sampler_logps:
            # App. B.1: engine logps are untrusted; do a dedicated
            # forward pass under the *sampler's own* parameters.
            lp = token_logps(self.cfg, self.params, roll["tokens"],
                             logprob_impl=self.logprob_impl)
            comp_lp = lp[:, tp - 1:]
        else:
            comp_lp = roll["sampler_lp"]
        zeros = np.zeros((b, tp - 1), np.float32)
        mask = np.concatenate([zeros, np.asarray(roll["comp_mask"])], axis=1)
        sampler_lp = np.concatenate([zeros, np.asarray(comp_lp)], axis=1)
        self.batches_generated += 1
        return RolloutBatch(tokens=np.asarray(roll["tokens"]), mask=mask,
                            sampler_lp=sampler_lp, rewards=rewards,
                            version=self.version, created_s=now_s,
                            sampler_id=self.sid)

    def sync(self) -> None:
        """Load the latest published checkpoint (post-delay) and place it
        onto this node's execution plan."""
        v, data = self.store.fetch()
        if v > self.version:
            self.params = self.plan.device_put_params(
                self.cfg, load_pytree(data, self.params))
            self.version = v
            self.syncs += 1

    def next_delay(self) -> float:
        return sample_delay(self.rng, self.hcfg)


class LearnerNode:
    """Consumes rollout batches in arrival order within the staleness
    window; publishes checkpoints."""

    def __init__(self, cfg: ModelConfig, rl: RLConfig, tc: TrainConfig,
                 hcfg: HeteroConfig, state: TrainState,
                 store: PolicyStore,
                 plan: Optional[ExecutionPlan] = None) -> None:
        self.cfg, self.rl, self.tc, self.hcfg = cfg, rl, tc, hcfg
        # learner execution plan (defaults to the TrainConfig.mesh knob).
        # The sharded step donates the TrainState, so the node takes a
        # plan-placed *copy*: the caller's state (often a warm start
        # shared across runs) stays alive.
        self.plan = plan or plan_from_flag(tc.mesh, "train")
        self.state = self.plan.device_put_state(cfg, state, "adamw",
                                                copy=True)
        self.store = store
        self.step_fn = jit_train_step(cfg, rl, tc, plan=self.plan)
        self.buffer: List[Tuple[float, RolloutBatch]] = []
        self.step = 0
        self.discarded = 0
        self.history = MetricsHistory()
        self._publish()

    def _publish(self) -> None:
        self.store.publish(self.step, save_pytree(
            self.plan.host_gather(self.state.params)))

    def receive(self, now_s: float, batch: RolloutBatch) -> None:
        self.buffer.append((now_s, batch))

    def pop_eligible(self, now_s: float) -> Optional[RolloutBatch]:
        """Oldest-arrival batch satisfying window + staleness limits."""
        while self.buffer:
            arrival, batch = self.buffer[0]
            window_ok = (now_s - batch.created_s) <= self.hcfg.window_s
            stale_ok = (self.step - batch.version) <= self.hcfg.max_delay_steps
            if window_ok and stale_ok:
                self.buffer.pop(0)
                return batch
            self.buffer.pop(0)
            self.discarded += 1
        return None

    def train_on(self, batch: RolloutBatch) -> Dict[str, float]:
        jb = self.plan.device_put_batch(self.cfg, {
            "tokens": jnp.asarray(batch.tokens),
            "mask": jnp.asarray(batch.mask),
            "sampler_lp": jnp.asarray(batch.sampler_lp),
            "rewards": jnp.asarray(batch.rewards)})
        self.state, metrics = self.step_fn(self.state, jb)
        self.step += 1
        out = {k: float(v) for k, v in metrics.items()}
        out["staleness"] = float(self.step - 1 - batch.version)
        out["buffer_len"] = float(len(self.buffer))
        self.history.append(self.step, out)
        if self.step % self.hcfg.sync_interval_steps == 0:
            self._publish()
        return out
