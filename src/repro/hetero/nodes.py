"""Learner and Sampler nodes of the HeteroRL star topology (§4.1, Fig. 3).

- Sampler nodes continuously generate rollout groups with their (stale)
  policy copy, score them locally (App. F localized rewards — group
  statistics never cross the network), and stream version-stamped batches
  to the learner.
- The learner consumes batches in arrival order inside a fixed
  time-window / staleness-window, updates parameters, and periodically
  publishes checkpoints to the ``PolicyStore``; samplers pull the latest
  version only after their simulated WAN delay D_M.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint import PolicyStore
from repro.config import (HeteroConfig, ModelConfig, RLConfig, ServeConfig,
                          TrainConfig)
from repro.core.diagnostics import MetricsHistory
from repro.data import PromptPipeline, score_rollouts
from repro.data.tasks import ArithmeticTask, Tokenizer
from repro.hetero.latency import sync_delay_s
from repro.parallel import ExecutionPlan, plan_from_flag
from repro.sampling import (ContinuousEngine, build_engine,
                            rollout_from_results, token_logps)
from repro.serving.api import Request, SamplingParams
from repro.training import TrainState, jit_train_step
from repro.transport import ChunkSubscriber, SimulatedLink, publish_params


@dataclasses.dataclass
class RolloutBatch:
    tokens: np.ndarray          # (B, T)
    mask: np.ndarray            # (B, T-1) target-position mask
    sampler_lp: np.ndarray      # (B, T-1)
    rewards: np.ndarray         # (B,) group-contiguous
    version: int                # policy version that generated it
    created_s: float
    sampler_id: int

    def nbytes(self) -> int:
        return (self.tokens.nbytes + self.mask.nbytes
                + self.sampler_lp.nbytes + self.rewards.nbytes)


class SamplerNode:
    """Generates rollouts with a possibly-stale policy copy."""

    def __init__(self, sid: int, cfg: ModelConfig, rl: RLConfig,
                 pipeline: PromptPipeline, task: ArithmeticTask,
                 tok: Tokenizer, params: Any, store: PolicyStore,
                 hcfg: HeteroConfig, seed: int,
                 engine: Optional[str] = None,
                 logprob_impl: str = "fused",
                 paged_attn_impl: Optional[str] = None,
                 plan: Optional[ExecutionPlan] = None,
                 serve: Optional[ServeConfig] = None,
                 spec_k: Optional[int] = None) -> None:
        self.sid = sid
        # sampler-side paged-decode backend (explicit arg beats the
        # HeteroConfig knob beats the arch default) — the A/B lever for
        # hetero sweeps: a different impl is a different jit key, so the
        # replaced config keeps executables per-backend.
        pa = paged_attn_impl or hcfg.paged_attn_impl
        if pa is not None:
            cfg = dataclasses.replace(cfg, paged_attn_impl=pa)
        self.cfg, self.rl = cfg, rl
        self.pipeline, self.task, self.tok = pipeline, task, tok
        # serve-mode execution plan of this node (defaults to the
        # HeteroConfig.sampler_mesh knob). The node owns a *copy* of the
        # params placed on its plan: the learner's sharded step donates
        # its buffers, so a by-reference alias would die under it.
        self.plan = plan or plan_from_flag(hcfg.sampler_mesh, "serve")
        self.params = self.plan.device_put_params(cfg, params, copy=True)
        self.store = store
        self.hcfg = hcfg
        # shard-streamed checkpoint client: chunk cache + WAN link of this
        # node (repro.transport) — syncs move only the chunks this node's
        # plan needs whose content changed since the last sync
        self.link = SimulatedLink(
            bandwidth_mbps=getattr(hcfg, "bandwidth_mbps", float("inf")))
        self.subscriber = ChunkSubscriber(store, self.link)
        self.engine = engine or rl.engine
        # sampler nodes serve through the same request-level Engine API
        # as the front door: one engine instance per node, built lazily
        # at the first batch (its KV budget needs the prompt width) from
        # a ServeConfig — an explicit one, or a default sized to the
        # pipeline's rollout shape
        self.serve_cfg = serve
        # speculative decoding opt-in (explicit arg beats the HeteroConfig
        # knob): hetero samplers are exactly the GEPO setting spec decode
        # targets — tokens drafted against a stale policy are verified by
        # the *current* local policy, so accepted tokens carry its logps
        # and the importance-weight contract is untouched. Applied to the
        # default ServeConfig below; an explicit `serve` keeps its own.
        self.spec_k = hcfg.spec_k if spec_k is None else spec_k
        self._gen_engine = None
        self._engine_tp = -1
        # backend of the App. B.1 recompute — follows the learner's
        # TrainConfig.logprob_impl so A/B runs switch both halves
        self.logprob_impl = logprob_impl
        self.version = 0
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)
        # instances cross threads in the threaded runtime: the node's
        # sampler thread mutates generation/sync state while the main
        # thread reads telemetry and drives elastic re-fits (RA005)
        self._lock = threading.Lock()
        self.batches_generated = 0
        self.syncs = 0
        # operator telemetry: generation rate of this node (the service
        # rate of the rollout queue in the HeteroRL picture) plus the
        # last rollout's engine stats, exposed via tokens_per_s below.
        # The first generate call pays jit compilation; it is accounted
        # separately (warmup_*) so tokens_per_s reports the steady-state
        # rate — the same convention as benchmarks/serve_throughput.py,
        # which warms executables outside the timed region.
        self.tokens_generated = 0
        self.gen_seconds = 0.0
        self.warmup_tokens = 0
        self.warmup_seconds = 0.0
        self.engine_stats: Dict[str, float] = {}
        # unified observability: this node's trace track + per-sampler
        # metric handles (Fig. 4/5 live quantities land here too, set by
        # the learner when it trains on this node's batches)
        self._track = f"sampler-{sid}"
        m = obs.metrics
        self._m_batches = m.counter(
            "sampler_batches_total", "rollout batches generated",
            sampler=sid)
        self._m_gen_tokens = m.counter(
            "sampler_gen_tokens_total", "completion tokens generated",
            sampler=sid)
        self._m_syncs = m.counter(
            "sampler_syncs_total", "weight syncs applied", sampler=sid)
        self._m_sync_bytes = m.counter(
            "sampler_sync_bytes_total", "weight-sync bytes on the wire",
            sampler=sid)
        self._g_version = m.gauge(
            "sampler_policy_version", "policy version this node holds",
            sampler=sid)
        self._g_accept = m.gauge(
            "sampler_accept_rate",
            "speculative-decode draft acceptance rate of this node",
            sampler=sid)
        self._m_drafted = m.counter(
            "sampler_drafted_tokens_total",
            "draft tokens proposed by this node's engine", sampler=sid)
        self._drafted_seen = 0   # engine stats are cumulative; counter
        #                          ingests per-batch deltas

    @property
    def tokens_per_s(self) -> float:
        """Steady-state generation rate (first-call compile excluded);
        falls back to the warmup-inclusive rate until a second batch has
        been generated."""
        if self.gen_seconds:
            return self.tokens_generated / self.gen_seconds
        if self.warmup_seconds:
            return self.warmup_tokens / self.warmup_seconds
        return 0.0

    def _engine_for(self, tp: int, b: int):
        """The node's engine, built on first use (the paged pool's budget
        needs the prompt width). Rebuilt only if the rollout shape
        changes."""
        with self._lock:
            if self._gen_engine is None or self._engine_tp != tp:
                serve = self.serve_cfg or ServeConfig(
                    engine=self.engine,
                    max_total_tokens=tp + self.rl.max_new_tokens,
                    num_slots=min(b, 8), spec_k=self.spec_k)
                if serve.max_total_tokens < tp + self.rl.max_new_tokens:
                    raise ValueError(
                        f"ServeConfig.max_total_tokens="
                        f"{serve.max_total_tokens} < prompt width {tp} "
                        f"+ max_new {self.rl.max_new_tokens}")
                self._gen_engine = build_engine(
                    self.cfg, self.params, serve, rl=self.rl,
                    vocab_limit=self.tok.vocab_size, plan=self.plan,
                    key=self.key)
                self._engine_tp = tp
            return self._gen_engine

    def generate_batch(self, now_s: float) -> RolloutBatch:
        req = self.pipeline.next_batch()
        prompts_np = np.asarray(req.prompts)
        prompts = jnp.asarray(prompts_np)
        b, tp = prompts_np.shape
        engine = self._engine_for(tp, b)
        with self._lock:
            self.key, k = jax.random.split(self.key)
        t0 = time.perf_counter()
        # rid = batch row, fresh key per batch: draws are bit-identical to
        # the legacy generate() path on either engine
        sp = SamplingParams.from_rl(self.rl)
        with obs.trace.span("sampler_generate", track=self._track,
                            sampler=self.sid, version=self.version,
                            batch=b):
            results = engine.generate(
                [Request(rid=r, prompt=prompts_np[r], params=sp)
                 for r in range(b)], key=k)
        roll = rollout_from_results(prompts_np, results,
                                    self.rl.max_new_tokens)
        if isinstance(engine, ContinuousEngine):
            roll["stats"] = engine.stats()
        ntok = int(np.asarray(roll["comp_mask"]).sum())
        dt = time.perf_counter() - t0
        with self._lock:
            if self.batches_generated == 0:     # jit compile folded in
                self.warmup_tokens += ntok
                self.warmup_seconds += dt
            else:
                self.tokens_generated += ntok
                self.gen_seconds += dt
            if "stats" in roll:
                self.engine_stats = dict(roll["stats"])
            if self.spec_k > 0 and self.engine_stats:
                self._g_accept.set(
                    self.engine_stats.get("accept_rate", 0.0))
                drafted = int(
                    self.engine_stats.get("drafted_tokens_total", 0))
                self._m_drafted.inc(drafted - self._drafted_seen)
                self._drafted_seen = drafted
        rewards = score_rollouts(self.task, self.tok, req.problems,
                                 np.asarray(roll["completions"]),
                                 req.group_size)
        b, tp = prompts.shape
        if self.rl.recompute_sampler_logps:
            # App. B.1: engine logps are untrusted; do a dedicated
            # forward pass under the *sampler's own* parameters.
            lp = token_logps(self.cfg, self.params, roll["tokens"],
                             logprob_impl=self.logprob_impl)
            comp_lp = lp[:, tp - 1:]
        else:
            comp_lp = roll["sampler_lp"]
        zeros = np.zeros((b, tp - 1), np.float32)
        mask = np.concatenate([zeros, np.asarray(roll["comp_mask"])], axis=1)
        sampler_lp = np.concatenate([zeros, np.asarray(comp_lp)], axis=1)
        with self._lock:
            self.batches_generated += 1
        self._m_batches.inc()
        self._m_gen_tokens.inc(ntok)
        return RolloutBatch(tokens=np.asarray(roll["tokens"]), mask=mask,
                            sampler_lp=sampler_lp, rewards=rewards,
                            version=self.version, created_s=now_s,
                            sampler_id=self.sid)

    def sync(self, plan: Optional[ExecutionPlan] = None) -> int:
        """Fetch the newest published checkpoint through the chunk
        transport (delta-synced against this node's local cache) and
        place it onto this node's execution plan. Returns the simulated
        bytes that moved on the wire (manifest + missing chunks), which
        feeds the payload-aware delay of the *next* sync.

        ``plan`` re-fits onto a changed ``ExecutionPlan`` (elastic sampler
        mesh: device loss/gain mid-run) — cached chunks are re-assembled
        and placed on the new shard grid, so an unchanged version re-fits
        without moving chunk bytes."""
        refit = plan is not None and plan != self.plan
        latest = self.store.latest_version()
        if latest < 0 or (latest <= self.version and not refit):
            if refit:
                # nothing (newer) published: re-place the live params so
                # plan and placement never disagree
                with self._lock:
                    self.plan = plan
                    self.params = self.plan.device_put_params(
                        self.cfg, self.params, copy=True)
                    self._push_params_locked()
            return 0
        # fetch against the *target* plan but commit it to self only
        # after the transport succeeds: if every retry raises, plan and
        # param placement must both stay on the old mesh (a half-applied
        # refit would make the next sync's refit check a false negative)
        target = plan if refit else self.plan
        for attempt in range(3):
            try:
                with obs.trace.span("weight_sync", track=self._track,
                                    sampler=self.sid, refit=refit):
                    v, host_tree, stats = self.subscriber.sync(
                        self.params, cfg=self.cfg, plan=target)
                break
            except KeyError:
                # threaded runtime race: the publisher pruned the fetched
                # manifest's chunks between fetch and snapshot — retry
                # against the newest version (bounded; chunks of a
                # retained manifest are pinned against GC)
                if attempt == 2:
                    raise
        with self._lock:
            if refit:
                self.plan = target
            if v > self.version or refit:
                self.params = self.plan.device_put_params(self.cfg,
                                                          host_tree)
                self._push_params_locked()
                if v > self.version:
                    self.version = v
                    self.syncs += 1
        self._m_syncs.inc()
        self._m_sync_bytes.inc(stats.bytes_on_wire)
        self._g_version.set(self.version)
        return stats.bytes_on_wire

    def _push_params_locked(self) -> None:
        """Keep the node's engine serving the freshly synced weights —
        the sampler-side half of the weight-sync contract. Caller holds
        ``self._lock``."""
        if self._gen_engine is not None:
            self._gen_engine.update_params(self.params)
            # elastic refit: the engine's jitted steps take the plan as a
            # static argument, so it must track the node's current plan
            self._gen_engine.plan = self.plan

    def next_delay(self, payload_bytes: int = 0) -> float:
        return sync_delay_s(self.rng, self.hcfg, payload_bytes)

    def link_stats(self) -> Dict[str, float]:
        """Per-node link telemetry: bytes on wire, dedup ratio (needed
        refs served from cache), simulated serialization seconds."""
        sub = self.subscriber
        total = sub.chunks_fetched + sub.chunk_hits
        row = {"sampler": float(self.sid), "syncs": float(self.syncs),
               "bytes_on_wire": float(self.link.bytes_on_wire),
               "sync_seconds": float(self.link.seconds),
               "chunks_fetched": float(sub.chunks_fetched),
               "chunk_hits": float(sub.chunk_hits),
               "dedup_ratio": sub.chunk_hits / total if total else 0.0}
        # thin view over the registry: the same row lands as per-sampler
        # link_* gauges so /metrics and sync_telemetry never disagree
        if obs.metrics.enabled:
            obs.metrics.set_many(
                "link", {k: v for k, v in row.items() if k != "sampler"},
                sampler=self.sid)
        return row


def link_telemetry(samplers: List[SamplerNode],
                   learner: LearnerNode) -> List[Dict[str, float]]:
    """Per-sampler weight-transport telemetry (bytes on wire, dedup
    ratio, simulated sync seconds) plus the learner's publish-side stream
    accounting as a pseudo-row (sampler=-1) — the one construction site
    both hetero runtimes report from."""
    rows = [s.link_stats() for s in samplers]
    rows.append({"sampler": -1.0,
                 "syncs": float(learner.step),
                 "bytes_on_wire": float(learner.bytes_streamed),
                 "sync_seconds": 0.0,
                 "chunks_fetched": float(learner.chunks_streamed),
                 "chunk_hits": 0.0, "dedup_ratio": 0.0})
    return rows


class LearnerNode:
    """Consumes rollout batches in arrival order within the staleness
    window; publishes checkpoints."""

    def __init__(self, cfg: ModelConfig, rl: RLConfig, tc: TrainConfig,
                 hcfg: HeteroConfig, state: TrainState,
                 store: PolicyStore,
                 plan: Optional[ExecutionPlan] = None) -> None:
        self.cfg, self.rl, self.tc, self.hcfg = cfg, rl, tc, hcfg
        # learner execution plan (defaults to the TrainConfig.mesh knob).
        # The sharded step donates the TrainState, so the node takes a
        # plan-placed *copy*: the caller's state (often a warm start
        # shared across runs) stays alive.
        self.plan = plan or plan_from_flag(tc.mesh, "train")
        self.state = self.plan.device_put_state(cfg, state, "adamw",
                                                copy=True)
        self.store = store
        self.step_fn = jit_train_step(cfg, rl, tc, plan=self.plan)
        self.buffer: List[Tuple[float, RolloutBatch]] = []
        self.step = 0
        self.discarded = 0
        self.history = MetricsHistory()
        # cumulative publish telemetry (net-new bytes/chunks streamed)
        self.bytes_streamed = 0
        self.chunks_streamed = 0
        self.publish_stats = None
        self._publish()

    def _publish(self) -> None:
        """Stream this step's params into the store as per-shard,
        content-addressed chunks (repro.transport) — each shard's host
        view is pulled device-locally, no full host-gather — plus the
        version manifest. Unchanged chunks cost nothing."""
        self.publish_stats = publish_params(
            self.store, self.step, self.plan, self.cfg, self.state.params)
        self.bytes_streamed += self.publish_stats.bytes_new
        self.chunks_streamed += self.publish_stats.chunks_new

    def receive(self, now_s: float, batch: RolloutBatch) -> None:
        self.buffer.append((now_s, batch))

    def pop_eligible(self, now_s: float) -> Optional[RolloutBatch]:
        """Oldest-arrival batch satisfying window + staleness limits."""
        while self.buffer:
            arrival, batch = self.buffer[0]
            window_ok = (now_s - batch.created_s) <= self.hcfg.window_s
            stale_ok = (self.step - batch.version) <= self.hcfg.max_delay_steps
            if window_ok and stale_ok:
                self.buffer.pop(0)
                return batch
            self.buffer.pop(0)
            self.discarded += 1
        return None

    def train_on(self, batch: RolloutBatch) -> Dict[str, float]:
        with obs.trace.span("learner_step", track="learner",
                            step=self.step, version=batch.version,
                            sampler=batch.sampler_id):
            jb = self.plan.device_put_batch(self.cfg, {
                "tokens": jnp.asarray(batch.tokens),
                "mask": jnp.asarray(batch.mask),
                "sampler_lp": jnp.asarray(batch.sampler_lp),
                "rewards": jnp.asarray(batch.rewards)})
            self.state, metrics = self.step_fn(self.state, jb)
            self.step += 1
            out = {k: float(v) for k, v in metrics.items()}
        out["staleness"] = float(self.step - 1 - batch.version)
        out["buffer_len"] = float(len(self.buffer))
        self.history.append(self.step, out)
        # per-step fan-in to the unified registry: every scalar becomes a
        # learner_* gauge, and the paper's Fig. 4/5 stability quantities
        # additionally land as per-sampler gauges (the sampler whose
        # batch this step consumed) — live staleness / KL / IW-variance
        if obs.metrics.enabled:
            obs.metrics.set_many("learner", out)
            obs.metrics.gauge("learner_steps_total").set(self.step)
            for k in ("staleness", "kl", "iw_var"):
                if k in out:
                    obs.metrics.gauge(
                        f"sampler_{k}",
                        f"{k} of the last batch trained from this sampler",
                        sampler=batch.sampler_id).set(out[k])
        if self.step % self.hcfg.sync_interval_steps == 0:
            with obs.trace.span("publish_checkpoint", track="learner",
                                step=self.step):
                self._publish()
        return out
