"""Simulated WAN link: bytes finally cost time.

A transfer charges ``payload_bytes / bandwidth`` simulated seconds (the
serialization term the scalar delay model of ``hetero.latency`` never
had); the propagation term stays with ``sample_delay``'s distributions —
``hetero.latency.sync_delay_s`` composes the two. ``bandwidth_mbps=inf``
(the default everywhere) makes every transfer free, reproducing the
legacy payload-blind behavior bit-for-bit.

The link can also drop mid-transfer (``drop_after_bytes`` one-shot fuse):
the exception reports how many bytes made it, so a subscriber can keep
partial progress and resume from the byte offset instead of re-paying the
whole chunk.
"""
from __future__ import annotations

import math
from typing import Optional

from repro import obs


class LinkDropped(Exception):
    """The link died mid-transfer; ``bytes_delivered`` made it across."""

    def __init__(self, bytes_delivered: int) -> None:
        super().__init__(f"link dropped after {bytes_delivered} bytes")
        self.bytes_delivered = int(bytes_delivered)


class SyncInterrupted(RuntimeError):
    """A sync aborted on a dropped link. Partial progress is retained by
    the subscriber; the next attempt resumes from the byte offset."""


def serialization_seconds(nbytes: int, bandwidth_mbps: float) -> float:
    """The one bytes→seconds formula (``nbytes / bandwidth``) shared by
    the link telemetry and the event-sim delay model
    (``hetero.latency.sync_delay_s``) — they must never disagree."""
    if not math.isfinite(bandwidth_mbps) or bandwidth_mbps <= 0:
        return 0.0
    return nbytes * 8.0 / (bandwidth_mbps * 1e6)


class SimulatedLink:
    """Per-sampler WAN link with byte/time/drop telemetry."""

    def __init__(self, bandwidth_mbps: float = float("inf"), *,
                 drop_after_bytes: Optional[int] = None) -> None:
        self.bandwidth_mbps = float(bandwidth_mbps)
        self.drop_after_bytes = drop_after_bytes    # one-shot fuse (tests)
        self.bytes_on_wire = 0
        self.transfers = 0
        self.drops = 0
        self.seconds = 0.0          # simulated serialization time charged

    def transfer_seconds(self, nbytes: int) -> float:
        return serialization_seconds(nbytes, self.bandwidth_mbps)

    def _charge(self, nbytes: int) -> float:
        secs = self.transfer_seconds(nbytes)
        self.bytes_on_wire += int(nbytes)
        self.transfers += 1
        self.seconds += secs
        tr = obs.trace
        if tr.enabled:
            # chunk fetches render as async flows: transfers overlap in
            # wall/sim time, so they must not nest on the caller's track
            fid = tr.next_flow_id()
            t0 = tr.now()
            tr.async_begin("chunk_transfer", fid, cat="transport", ts=t0,
                           bytes=int(nbytes))
            tr.async_end("chunk_transfer", fid, cat="transport",
                         ts=t0 + secs)
        if obs.metrics.enabled:
            obs.metrics.counter(
                "link_transfer_bytes_total",
                "bytes moved over simulated WAN links").inc(nbytes)
            obs.metrics.counter(
                "link_transfers_total", "chunk/manifest transfers").inc()
        return secs

    def transfer(self, nbytes: int) -> float:
        """Move ``nbytes``; returns the simulated seconds charged. Raises
        ``LinkDropped`` (after charging the partial bytes) when the drop
        fuse fires inside this transfer."""
        if (self.drop_after_bytes is not None
                and self.bytes_on_wire + nbytes > self.drop_after_bytes):
            delivered = max(self.drop_after_bytes - self.bytes_on_wire, 0)
            self.drop_after_bytes = None
            self.drops += 1
            if delivered:
                self._charge(delivered)
            raise LinkDropped(delivered)
        return self._charge(nbytes)
