"""repro.transport — shard-streamed, delta-compressed weight distribution.

The learner publishes per-shard, content-addressed chunks of each param
leaf (``publish_params``); samplers subscribe with their ``ExecutionPlan``
(``ChunkSubscriber``) and fetch only the chunks their plan needs, only
when the content changed, over a ``SimulatedLink`` whose delay finally
depends on the bytes moved. ``PolicyStore`` (repro.checkpoint) is the
chunk-index/version backend.
"""
from repro.transport.chunks import (ChunkRef, assemble_leaf, chunk_host_leaf,
                                    content_hash, overlaps, region_map,
                                    shard_regions)
from repro.transport.link import (LinkDropped, SimulatedLink,
                                  SyncInterrupted)
from repro.transport.manifest import LeafManifest, Manifest
from repro.transport.publish import PublishStats, publish_params
from repro.transport.subscribe import ChunkSubscriber, SyncStats

__all__ = [
    "ChunkRef", "LeafManifest", "Manifest",
    "assemble_leaf", "chunk_host_leaf", "content_hash", "overlaps",
    "region_map", "shard_regions",
    "LinkDropped", "SimulatedLink", "SyncInterrupted",
    "PublishStats", "publish_params",
    "ChunkSubscriber", "SyncStats",
]
