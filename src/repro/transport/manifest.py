"""Version manifests: the index a publisher ships instead of a blob.

A manifest names every leaf (same path keys as the npz checkpoint
format), its dtype/shape, and the chunk grid — content hashes, offsets,
replica multiplicity. It is the only thing a subscriber *must* download
per version; chunk payloads follow only where the local cache misses.
JSON-encoded so its wire size is honest and a real cross-host deployment
could speak it as-is.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, FrozenSet, Tuple

from repro.transport.chunks import ChunkRef


@dataclasses.dataclass(frozen=True)
class LeafManifest:
    key: str
    dtype: str
    shape: Tuple[int, ...]
    chunks: Tuple[ChunkRef, ...]

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.chunks)


@dataclasses.dataclass(frozen=True)
class Manifest:
    version: int
    leaves: Tuple[LeafManifest, ...]

    # ---- accounting ------------------------------------------------------
    @property
    def num_chunks(self) -> int:
        """Distinct shard-grid cells across all leaves."""
        return sum(len(lm.chunks) for lm in self.leaves)

    @property
    def num_entries(self) -> int:
        """Per-device shard entries (replicas counted) — what a naive
        per-device broadcast would push."""
        return sum(c.replicas for lm in self.leaves for c in lm.chunks)

    @property
    def payload_bytes(self) -> int:
        """One full copy of the model: distinct grid cells tile each leaf
        exactly once."""
        return sum(lm.nbytes for lm in self.leaves)

    @property
    def entry_bytes(self) -> int:
        """Replica-weighted bytes (the naive broadcast payload)."""
        return sum(c.nbytes * c.replicas for lm in self.leaves
                   for c in lm.chunks)

    def hashes(self) -> FrozenSet[str]:
        return frozenset(c.hash for lm in self.leaves for c in lm.chunks)

    def hash_bytes(self) -> Dict[str, int]:
        return {c.hash: c.nbytes for lm in self.leaves for c in lm.chunks}

    # ---- wire format -----------------------------------------------------
    def to_json(self) -> bytes:
        doc = {"version": self.version, "leaves": [
            {"key": lm.key, "dtype": lm.dtype, "shape": list(lm.shape),
             "chunks": [[c.hash, c.nbytes, list(c.start), list(c.shape),
                         c.replicas] for c in lm.chunks]}
            for lm in self.leaves]}
        return json.dumps(doc, separators=(",", ":")).encode()

    @staticmethod
    def from_json(data: bytes) -> Manifest:
        doc = json.loads(data.decode())
        leaves = tuple(
            LeafManifest(
                key=ld["key"], dtype=ld["dtype"], shape=tuple(ld["shape"]),
                chunks=tuple(ChunkRef(hash=h, nbytes=n, start=tuple(st),
                                      shape=tuple(sp), replicas=r)
                             for h, n, st, sp, r in ld["chunks"]))
            for ld in doc["leaves"])
        return Manifest(version=doc["version"], leaves=leaves)
