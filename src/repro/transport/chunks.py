"""Chunk codec: cut an array along its ``NamedSharding`` shard grid.

One chunk per *distinct* shard of a leaf — replicas collapse onto a single
content-addressed chunk (the manifest records the multiplicity), and the
distinct chunks of a leaf tile it exactly once, so assembling them is a
byte-exact restore. Chunk payloads use the raw-byte codec shared with the
npz checkpoint format (``repro.checkpoint.store.encode_array``): bf16 and
other ml_dtypes travel as raw bytes + (dtype, shape) sidecar, never
upcast.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.store import decode_array, encode_array

Region = Tuple[Tuple[int, ...], Tuple[int, ...]]     # (start, shape)


@dataclasses.dataclass(frozen=True)
class ChunkRef:
    """One shard-grid cell of a leaf: where it sits, how many devices of
    the publisher's plan hold it, and the content hash addressing its
    bytes in the store."""
    hash: str
    nbytes: int
    start: Tuple[int, ...]
    shape: Tuple[int, ...]
    replicas: int = 1

    def slices(self) -> Tuple[slice, ...]:
        return tuple(slice(s, s + n) for s, n in zip(self.start, self.shape, strict=True))


def content_hash(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=16).hexdigest()


def _normalize_index(idx: Tuple, shape: Tuple[int, ...]) -> Region:
    start, cshape = [], []
    for i, dim in enumerate(shape):
        sl = idx[i] if i < len(idx) else slice(None)
        lo = 0 if sl.start is None else int(sl.start)
        hi = dim if sl.stop is None else int(sl.stop)
        start.append(lo)
        cshape.append(hi - lo)
    return tuple(start), tuple(cshape)


def region_map(sharding, shape: Tuple[int, ...],
               devices: Optional[Iterable] = None) -> Dict[Region, List]:
    """Distinct shard regions of ``sharding`` over ``shape`` → the devices
    holding each. ``devices`` restricts to one host's device subset (the
    multi-host view: a host needs only its own rows of the grid)."""
    devs = set(devices) if devices is not None else None
    out: Dict[Region, List] = {}
    for dev, idx in sharding.devices_indices_map(tuple(shape)).items():
        if devs is not None and dev not in devs:
            continue
        out.setdefault(_normalize_index(idx, shape), []).append(dev)
    return out


def shard_regions(sharding, shape: Tuple[int, ...],
                  devices: Optional[Iterable] = None
                  ) -> List[Tuple[Tuple[int, ...], Tuple[int, ...], int]]:
    """Sorted ``(start, chunk_shape, replicas)`` triples — the chunk grid
    of a leaf under ``sharding``."""
    return [(start, cshape, len(devs))
            for (start, cshape), devs in sorted(region_map(
                sharding, shape, devices).items())]


def chunk_host_leaf(leaf: Any, sharding, regions=None
                    ) -> List[Tuple[ChunkRef, bytes]]:
    """Cut ``leaf`` into its shard-grid chunks, pulling *per-shard host
    views*: a placed ``jax.Array`` contributes each distinct shard's
    device-local buffer directly (no global host-gather); plain host
    arrays (or shards placed differently than the grid says) are sliced.
    ``regions`` takes a precomputed ``shard_regions`` result so callers
    that also need the region→device map resolve the grid only once.
    """
    shape = tuple(leaf.shape)
    if regions is None:
        regions = shard_regions(sharding, shape)
    shard_views: Dict[Region, Any] = {}
    if isinstance(leaf, jax.Array):
        for sh in getattr(leaf, "addressable_shards", ()):
            shard_views.setdefault(_normalize_index(sh.index, shape), sh.data)
    host = None
    out = []
    for start, cshape, replicas in regions:
        view = shard_views.get((start, cshape))
        if view is None:
            if host is None:
                host = np.asarray(leaf)
            view = host[tuple(slice(s, s + n)
                              for s, n in zip(start, cshape, strict=True))]
        data = encode_array(view)
        out.append((ChunkRef(hash=content_hash(data), nbytes=len(data),
                             start=start, shape=cshape, replicas=replicas),
                    data))
    return out


def assemble_leaf(dtype: str, shape: Tuple[int, ...],
                  parts: Iterable[Tuple[ChunkRef, bytes]]) -> np.ndarray:
    """Tile chunks back into a host array. The grid must cover the leaf
    exactly once — partial (host-scoped) fetches cannot assemble."""
    parts = list(parts)
    if not shape:
        ref, data = parts[0]
        return decode_array(data, dtype, shape).copy()
    out = np.empty(shape, jax.numpy.dtype(dtype))
    covered = 0
    for ref, data in parts:
        out[ref.slices()] = decode_array(data, dtype, ref.shape)
        covered += int(np.prod(ref.shape))
    total = int(np.prod(shape))
    if covered != total:
        raise ValueError(f"chunks cover {covered} of {total} elements — "
                         "partial fetches cannot assemble a full leaf")
    return out


def overlaps(ref: ChunkRef, start: Tuple[int, ...],
             cshape: Tuple[int, ...]) -> bool:
    """Does chunk ``ref`` intersect the region (start, cshape)?"""
    return all(s0 < s1 + n1 and s1 < s0 + n0
               for s0, n0, s1, n1 in zip(ref.start, ref.shape, start, cshape,
                          strict=True))
