"""Publisher half of the shard-streamed transport.

``publish_params`` walks the param tree alongside the publisher plan's
fitted shardings, cuts each leaf along its shard grid (per-shard host
views — a placed ``jax.Array`` never round-trips through a full
host-gather), content-addresses every chunk, and pushes only net-new
bytes into the ``PolicyStore`` chunk index before versioning the
manifest. Re-publishing unchanged content is nearly free: the manifest
moves, the chunks do not.

``PublishStats.max_host_egress`` is the multi-host story: with the grid
cut per shard, each learner host uploads only the shards it owns, so the
worst per-host upload is ``payload / (shards-per-leaf)`` instead of the
whole-blob gather-then-upload on host 0.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

from repro.checkpoint.store import PolicyStore, flatten_with_paths
from repro.transport.chunks import chunk_host_leaf, region_map
from repro.transport.manifest import LeafManifest, Manifest


@dataclasses.dataclass
class PublishStats:
    version: int
    payload_bytes: int = 0      # one full model copy (distinct chunks)
    bytes_new: int = 0          # net-new chunk bytes entering the store
    manifest_bytes: int = 0
    chunks: int = 0             # distinct grid cells
    chunks_new: int = 0
    entries: int = 0            # per-device shard entries (incl. replicas)
    max_host_egress: int = 0    # worst per-device upload of this publish

    @property
    def delta_ratio(self) -> float:
        """Fraction of the model that actually moved (1.0 on a cold
        store, → 0 as publishes repeat unchanged content)."""
        return self.bytes_new / self.payload_bytes if self.payload_bytes \
            else 0.0


def publish_params(store: PolicyStore, version: int, plan, cfg,
                   params: Any) -> PublishStats:
    """Chunk ``params`` along ``plan``'s fitted shard grid and publish
    (chunks + manifest) to ``store`` as ``version``."""
    flat_params = flatten_with_paths(params)
    flat_shard = dict(flatten_with_paths(plan.param_shardings(cfg)))
    stats = PublishStats(version=version)
    seen_this_publish: Dict[str, int] = {}
    egress: Dict[Any, int] = {}
    leaves = []
    for key, leaf in flat_params:
        sharding = flat_shard.get(key)
        if sharding is None:
            raise KeyError(f"no sharding for leaf {key!r} — params tree "
                           "does not match plan.param_shardings(cfg)")
        rmap = region_map(sharding, tuple(leaf.shape))
        regions = [(start, cshape, len(devs))
                   for (start, cshape), devs in sorted(rmap.items())]
        items = chunk_host_leaf(leaf, sharding, regions=regions)
        owners = {region: min(devs, key=lambda d: d.id)
                  for region, devs in rmap.items()}
        refs = []
        for ref, data in items:
            if ref.hash not in seen_this_publish:
                if store.put_chunk(ref.hash, data):
                    stats.chunks_new += 1
                    stats.bytes_new += ref.nbytes
                seen_this_publish[ref.hash] = ref.nbytes
            stats.payload_bytes += ref.nbytes
            stats.chunks += 1
            stats.entries += ref.replicas
            owner = owners[(ref.start, ref.shape)]
            egress[owner] = egress.get(owner, 0) + ref.nbytes
            refs.append(ref)
        leaves.append(LeafManifest(key=key, dtype=str(leaf.dtype),
                                   shape=tuple(leaf.shape),
                                   chunks=tuple(refs)))
    manifest = Manifest(version=version, leaves=tuple(leaves))
    blob = manifest.to_json()
    store.publish_manifest(version, blob, manifest.hashes())
    stats.manifest_bytes = len(blob)
    stats.max_host_egress = max(egress.values(), default=0)
    return stats
