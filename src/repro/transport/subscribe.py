"""Subscriber half of the shard-streamed transport.

A ``ChunkSubscriber`` is one sampler's checkpoint client: it pulls the
newest manifest over its ``SimulatedLink``, computes the chunk set *its
execution plan needs* (the chunks overlapping its plan's shard grid —
optionally scoped to one host's device subset), delta-syncs against its
local content-addressed cache (unchanged chunks never touch the wire),
and survives a dropped link mid-transfer: partial byte progress is kept
per chunk and the next sync resumes from the offset.

Because assembly happens on host from cached chunks, a fetched version
lands correctly on a *changed* plan too — elastic re-fit is just "sync
with the new plan": cached chunks are re-tiled and ``device_put`` onto
the new shard grid without moving a byte.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax

from repro.checkpoint.store import PolicyStore, path_key
from repro.transport.chunks import (ChunkRef, assemble_leaf, overlaps,
                                    shard_regions)
from repro.transport.link import LinkDropped, SimulatedLink, SyncInterrupted
from repro.transport.manifest import LeafManifest, Manifest


@dataclasses.dataclass
class SyncStats:
    version: int = -1
    manifest_bytes: int = 0
    chunk_bytes: int = 0        # chunk payload moved this sync
    bytes_resumed: int = 0      # skipped thanks to partial-progress resume
    chunks_fetched: int = 0
    chunk_hits: int = 0         # needed refs already in the local cache
    seconds: float = 0.0        # simulated serialization seconds charged

    @property
    def bytes_on_wire(self) -> int:
        return self.manifest_bytes + self.chunk_bytes

    @property
    def dedup_ratio(self) -> float:
        """Fraction of needed chunk refs served from the local cache."""
        total = self.chunks_fetched + self.chunk_hits
        return self.chunk_hits / total if total else 0.0


class ChunkSubscriber:
    """Plan-scoped, delta-synced, resumable checkpoint client."""

    def __init__(self, store: PolicyStore,
                 link: Optional[SimulatedLink] = None) -> None:
        self.store = store
        self.link = link if link is not None else SimulatedLink()
        self._cache: Dict[str, bytes] = {}
        self._partial: Dict[str, int] = {}   # hash -> bytes received so far
        # cumulative telemetry
        self.syncs = 0
        self.chunks_fetched = 0
        self.chunk_hits = 0
        self.bytes_fetched = 0
        self.manifest_bytes = 0

    # ---- need-set computation -------------------------------------------
    def needed_refs(self, manifest: Manifest, *, plan=None, cfg=None,
                    devices: Optional[Iterable] = None
                    ) -> List[Tuple[LeafManifest, List[ChunkRef]]]:
        """The publisher chunks this plan needs, per leaf: every chunk
        overlapping a distinct shard region of the plan's fitted sharding.
        ``devices`` scopes to one host's shard subset — a strict subset of
        the manifest whenever the plan shards any leaf. Without device
        scoping a plan's shard regions tile every leaf in full, so the
        need-set is provably all chunks and the overlap scan is skipped."""
        if plan is None or cfg is None or devices is None:
            return [(lm, list(lm.chunks)) for lm in manifest.leaves]
        from repro.checkpoint.store import flatten_with_paths
        shardings = dict(flatten_with_paths(plan.param_shardings(cfg)))
        out = []
        for lm in manifest.leaves:
            sharding = shardings.get(lm.key)
            if sharding is None:
                out.append((lm, list(lm.chunks)))
                continue
            regions = shard_regions(sharding, lm.shape, devices=devices)
            need = [ref for ref in lm.chunks
                    if any(overlaps(ref, start, cshape)
                           for start, cshape, _ in regions)]
            out.append((lm, need))
        return out

    # ---- sync ------------------------------------------------------------
    def sync(self, like: Any, *, cfg=None, plan=None,
             version: Optional[int] = None,
             devices: Optional[Iterable] = None,
             assemble: Optional[bool] = None
             ) -> Tuple[int, Any, SyncStats]:
        """Fetch ``version`` (newest when None) and assemble it into the
        structure of ``like``. Returns ``(version, host_tree, stats)``;
        ``host_tree`` is None for device-scoped fetches — those are
        partial by construction, so ``assemble`` defaults to
        ``devices is None`` and forcing it on a scoped fetch is an error.
        Raises ``SyncInterrupted`` if the link drops; call again to
        resume from the recorded byte offsets."""
        if assemble is None:
            assemble = devices is None
        elif assemble and devices is not None:
            raise ValueError("a device-scoped fetch is partial — it "
                             "cannot assemble full leaves; pass "
                             "assemble=False (or drop devices=)")
        v, blob = self.store.fetch(version)
        manifest = Manifest.from_json(blob)
        stats = SyncStats(version=v, manifest_bytes=len(blob))
        self.manifest_bytes += len(blob)
        try:
            stats.seconds += self.link.transfer(len(blob))
        except LinkDropped:
            raise SyncInterrupted(
                "link dropped while fetching the manifest") from None
        needed = self.needed_refs(manifest, plan=plan, cfg=cfg,
                                  devices=devices)
        missing, seen = [], set()
        for _, refs in needed:
            for ref in refs:
                if ref.hash in seen:
                    continue
                seen.add(ref.hash)
                if ref.hash in self._cache:
                    stats.chunk_hits += 1
                    self.chunk_hits += 1
                else:
                    missing.append(ref)
        # atomic snapshot: grab every missing chunk under one store lock
        # before paying the (long, interruptible) simulated transfers — a
        # concurrent publisher pruning this manifest mid-sync cannot yank
        # chunks from under us (content is hash-addressed, so a snapshot
        # taken now stays valid across a resume)
        payload = self.store.get_chunks([r.hash for r in missing])
        for ref in missing:
            self._fetch(ref, payload[ref.hash], stats)
        tree = None
        if assemble:
            tree = self._assemble(manifest, like)
        # cache hygiene: keep only chunks the current version references —
        # the cache is bounded by one model copy, not the run length
        keep = manifest.hashes()
        self._cache = {h: d for h, d in self._cache.items() if h in keep}
        self._partial = {h: n for h, n in self._partial.items() if h in keep}
        self.syncs += 1
        return v, tree, stats

    def _fetch(self, ref: ChunkRef, data: bytes, stats: SyncStats) -> None:
        got = self._partial.get(ref.hash, 0)
        remaining = ref.nbytes - got
        try:
            stats.seconds += self.link.transfer(remaining)
        except LinkDropped as e:
            self._partial[ref.hash] = got + e.bytes_delivered
            stats.chunk_bytes += e.bytes_delivered
            self.bytes_fetched += e.bytes_delivered
            raise SyncInterrupted(
                f"link dropped {e.bytes_delivered} bytes into chunk "
                f"{ref.hash} ({got + e.bytes_delivered}/{ref.nbytes} "
                "received) — re-sync resumes from this offset") from e
        self._partial.pop(ref.hash, None)
        self._cache[ref.hash] = data
        stats.chunk_bytes += remaining
        stats.bytes_resumed += got
        stats.chunks_fetched += 1
        self.chunks_fetched += 1
        self.bytes_fetched += remaining

    def _assemble(self, manifest: Manifest, like: Any) -> Any:
        by_key = {lm.key: lm for lm in manifest.leaves}
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, _ in flat:
            key = path_key(path)
            lm = by_key.get(key)
            if lm is None:
                raise KeyError(f"leaf {key!r} missing from manifest "
                               f"version {manifest.version}")
            leaves.append(assemble_leaf(
                lm.dtype, lm.shape,
                [(ref, self._cache[ref.hash]) for ref in lm.chunks]))
        return jax.tree_util.tree_unflatten(treedef, leaves)
