"""Configuration system for the HeteroRL/GEPO framework.

Everything is a frozen dataclass so configs are hashable and usable as jit
static arguments. `ModelConfig` describes any of the supported architecture
families via a per-layer *block pattern* that is cycled over the depth; the
model code scans over homogeneous super-blocks of one pattern period.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Layer kinds usable in ``block_pattern``.
ATTN = "attn"          # global causal self-attention
LOCAL = "local"        # sliding-window causal self-attention
MAMBA = "mamba"        # Mamba2 / SSD block (attention-free)
CROSS = "cross"        # cross-attention to a stub modality memory (VLM)

# FFN kinds usable in ``ffn_pattern``.
MLP = "mlp"
MOE = "moe"
NONE = "none"          # e.g. Mamba2 blocks carry no separate FFN


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # layer layout -------------------------------------------------------
    block_pattern: Tuple[str, ...] = (ATTN,)
    ffn_pattern: Tuple[str, ...] = (MLP,)

    # attention options ---------------------------------------------------
    qkv_bias: bool = False
    sliding_window: int = 4096      # used by LOCAL layers
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 1_000_000.0

    # MoE options ---------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD) options -----------------------------------------
    ssm_state: int = 0              # N, state dimension
    ssm_headdim: int = 64           # P, channels per SSM head
    ssm_expand: int = 2             # d_inner = expand * d_model
    ssm_ngroups: int = 1            # B/C groups
    ssm_conv: int = 4               # depthwise conv width
    ssm_chunk: int = 256            # SSD chunk length

    # encoder / multimodal stubs -----------------------------------------
    encoder_layers: int = 0         # >0 -> encoder-decoder (whisper)
    encoder_seq: int = 0            # frames for audio / patches for vision
    memory_seq: int = 0             # stub modality memory length for CROSS

    # numerics ------------------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    scale_embed: bool = False       # multiply embeddings by sqrt(d) (gemma)

    # implementation knobs (not architecture) -----------------------------
    attn_impl: str = "chunked"      # naive | chunked | pallas
    attn_chunk: int = 512           # query/kv block for chunked attention
    # Paged-decode backend for the continuous engine's hot loop
    # (repro.kernels.ops.paged_decode): "gather" materializes the logical
    # KV view and stays bit-identical to the dense decode path (the
    # static ≡ continuous parity contract — hence the default); "auto"
    # picks the in-place Pallas kernel on TPU / its jnp ref elsewhere;
    # "pallas" | "ref" force a backend.
    paged_attn_impl: str = "gather"
    remat: bool = True              # activation checkpointing per block
    # residual-stream sharding constraint between blocks (set by the
    # launcher; nested tuples of mesh axis names / None). E.g. Megatron-SP
    # style ((("pod","data"),), "model", None) shards (B, S, d) as
    # batch->dp, seq->model.
    act_sharding: Optional[Tuple] = None
    # §Perf H-A1 (REFUTED for dense-train: 3.3× more collective bytes —
    # see EXPERIMENTS.md): force head-sharded full-S q/k/v before attention.
    attn_gather_qkv: bool = False
    # §Perf H-B2/H-C3: shard_map expert-parallel MoE ("train"|"serve",
    # None = GSPMD baseline); ep_dp_axes = data axes of the mesh.
    moe_ep: Optional[str] = None
    ep_dp_axes: Optional[Tuple[str, ...]] = None
    # §Perf H-G1: ring-buffer KV cache for LOCAL (sliding-window) layers —
    # the cache stores only `sliding_window` entries (gemma2 long-context
    # decode: local-layer KV shrinks seq_len/window ≈ 128×).
    local_ring_kv: bool = False

    # ---------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        period = len(self.block_pattern)
        assert self.num_layers % period == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern period {period}")

    # derived -------------------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_blocks(self) -> int:
        """Number of scanned super-blocks (one pattern period each)."""
        return self.num_layers // self.period

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so it shards evenly over 16-way model parallelism
        and stays lane-aligned (multiples of 256)."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    def ffn_kind(self, layer_in_block: int) -> str:
        return self.ffn_pattern[layer_in_block % len(self.ffn_pattern)]

    @property
    def has_attention(self) -> bool:
        return any(k in (ATTN, LOCAL, CROSS) for k in self.block_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    # parameter counting (for roofline MODEL_FLOPS = 6·N·D) ---------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; ``active_only`` counts only the
        experts that fire per token (for MoE rooflines)."""
        d, h = self.d_model, self.head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.padded_vocab * d          # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab * d     # lm head
        for li in range(self.num_layers):
            kind = self.block_pattern[li % self.period]
            if kind in (ATTN, LOCAL, CROSS):
                total += d * (n_q * h) + 2 * d * (n_kv * h) + (n_q * h) * d
            elif kind == MAMBA:
                di, N, G = self.d_inner, self.ssm_state, self.ssm_ngroups
                total += d * (2 * di + 2 * G * N + self.ssm_heads)  # in_proj
                total += di * d                                      # out_proj
                total += self.ssm_conv * (di + 2 * G * N)            # conv
            fk = self.ffn_kind(li % self.period)
            if fk == MLP:
                total += 3 * d * self.d_ff
            elif fk == MOE:
                n_e = (self.experts_per_token if active_only
                       else self.num_experts)
                total += 3 * d * self.d_ff * n_e
                total += d * self.num_experts                        # router
                if self.shared_expert:
                    total += 3 * d * self.d_ff
            total += 2 * d                                            # norms
        if self.encoder_layers:
            total += self.encoder_layers * (4 * d * (self.num_heads * h)
                                            + 3 * d * self.d_ff + 2 * d)
        return total


@dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
INPUT_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


@dataclass(frozen=True)
class RLConfig:
    """Policy-optimization settings (paper §3/§4 + App. B)."""
    loss_type: str = "gepo"        # grpo|dr_grpo|bnpo|gspo|gepo|tis|cispo|topr
    group_size: int = 8
    clip_eps: float = 0.2          # PPO-style clip (token/seq level methods)
    cispo_eps_low: float = 1.0     # IW clip band for CISPO
    cispo_eps_high: float = 0.27
    beta_kl: float = 0.005         # CPPO-KL coefficient (0 => off)
    adv_normalize: bool = True     # divide by group std (off for dr_grpo)
    seq_len_normalize: bool = True # length-norm of seq logprob (GSPO eq. 61)
    gepo_smooth: float = 0.0       # App. H defensive denominator: λ·p mix
    temperature: float = 0.6
    top_k: int = 20
    top_p: float = 0.95
    max_new_tokens: int = 32
    recompute_sampler_logps: bool = True   # App. B.1 vLLM/FSDP mismatch fix
    entropy_bonus: float = 0.0
    # Generation engine for sampler nodes: "static" = one lax.scan to
    # max_new_tokens; "continuous" = slot pool + paged KV cache with EOS
    # slot recycling (see repro/sampling/scheduler.py).
    engine: str = "static"


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-6
    warmup_frac: float = 0.03
    total_steps: int = 1000
    weight_decay: float = 0.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    grad_accum: int = 1
    seed: int = 0
    # Learner device mesh as "DxM" (data×model; "PxDxM" adds the slow
    # inter-pod axis). "1x1" = single device. Resolved by the unified
    # execution layer (repro.parallel.plan_from_flag); on CPU a >1 mesh
    # needs XLA_FLAGS=--xla_force_host_platform_device_count=N exported
    # before the first jax import.
    mesh: str = "1x1"
    # Learner-side log-prob implementation (the RL hot path):
    #   "fused"   — auto-dispatch repro.kernels.ops.fused_token_logprob
    #               (Pallas TPU kernel, chunked lax.map elsewhere); no
    #               V-sized f32 activation in forward or backward.
    #   "pallas" | "chunked" — force one fused backend.
    #   "naive"   — materializing log-softmax (repro.core.logprob).
    logprob_impl: str = "fused"


@dataclass(frozen=True)
class HeteroConfig:
    """HeteroRL runtime settings (paper §4.1 + App. E)."""
    num_samplers: int = 4
    max_delay_steps: int = 64        # staleness window in learner steps
    delay_distribution: str = "lognormal"   # lognormal | weibull | exponential
    delay_min_s: float = 60.0
    delay_max_s: float = 1800.0
    delay_median_s: float = 60.0
    sync_interval_steps: int = 1     # learner checkpoint publish period
    window_s: float = 1800.0         # rollout eligibility window
    seed: int = 0
    # Simulated WAN bandwidth of the model-sync link (Mbit/s). The default
    # inf reproduces the legacy payload-blind delay model bit-for-bit; a
    # finite value adds payload_bytes/bandwidth serialization time on top
    # of the sampled propagation delay (latency.sync_delay_s), so D_M
    # finally depends on how many bytes the transport actually moves.
    bandwidth_mbps: float = float("inf")
    # Sampler-node device mesh as "DxM" (serve-mode tensor parallelism);
    # same conventions as TrainConfig.mesh. All sampler nodes share it —
    # HeteroRL's point is that it can differ from the learner's mesh.
    sampler_mesh: str = "1x1"
    # Sampler-side paged-decode backend override (ModelConfig.
    # paged_attn_impl vocabulary; None keeps the arch default). Lets the
    # hetero sweeps A/B the in-place kernel against the gather path.
    paged_attn_impl: Optional[str] = None
    # Sampler-side speculative decoding (continuous engine only): draft
    # cap per verification round; 0 = off. Distribution-preserving, so
    # table2-style runs can A/B it purely as a decode-latency lever.
    spec_k: int = 0


@dataclass(frozen=True)
class ServeConfig:
    """Deployment settings for a generation engine and its serving front
    door — one shared config object instead of the nine loose argparse
    flags ``launch/serve.py`` used to carry.

    Split of responsibilities: :class:`repro.serving.api.SamplingParams`
    describes a *request* (temperature/top-k/top-p/token budget);
    ``ServeConfig`` describes a *deployment* (engine kind, KV capacity,
    decode horizon, mesh, admission limits). The same object configures
    batch serving (``launch/serve.py``), the asyncio front door
    (``repro.serving.server``), and HeteroRL sampler nodes.
    """
    # engine ---------------------------------------------------------------
    engine: str = "continuous"       # static | continuous
    num_slots: int = 8               # decode slots (continuous engine)
    page_size: int = 16              # KV page size in tokens
    prefill_chunk: int = 0           # prompt tokens per chunk (0 = whole)
    sync_every: int = 8              # decode horizon per scheduler sync
    # capacity: per-request prompt+completion cap; the page pool defaults
    # to 1 scratch + num_slots * pages_for(max_total_tokens) pages, and
    # num_pages overrides it (smaller = real admission pressure, larger =
    # headroom for the shared-prefix cache to keep pages resident)
    max_total_tokens: int = 256
    num_pages: int = 0               # 0 = derive from slots × budget
    prefix_cache: bool = True        # shared-prefix KV page reuse
    prefix_cache_entries: int = 64
    mesh: str = "1x1"                # serve mesh DxM (TrainConfig.mesh conv.)
    paged_attn_impl: Optional[str] = None   # ModelConfig override (None=keep)
    # speculative decoding (continuous engine only): drafts per
    # verification round (0 = off). Acceptance preserves the sampled
    # distribution exactly; greedy stays bit-identical to spec off.
    spec_k: int = 0
    spec_ngram_max: int = 3          # prompt-lookup suffix n-gram (longest)
    spec_ngram_min: int = 1          # ... shortest suffix tried
    # rescore acceptance through one fused paged_prefill_layers launch
    # per round and export max |fused - in-forward| as a drift gauge
    spec_rescore: bool = True
    # front door -----------------------------------------------------------
    host: str = "127.0.0.1"
    port: int = 8100
    max_queue: int = 256             # admission: queued-request cap
    # admission: shed load once the KV pages promised to queued requests
    # exceed this many turns of the page pool (1.0 = the queue may never
    # hold more demand than the pool serves in one full drain)
    queue_overcommit: float = 4.0
    default_priority: int = 1        # priority class for unlabelled requests
    default_deadline_s: float = 0.0  # TTFT SLO applied when none given (0=off)
    seed: int = 0

    def __post_init__(self):
        if self.engine not in ("static", "continuous"):
            raise ValueError(f"engine={self.engine!r} not static|continuous")
        if self.num_slots < 1 or self.page_size < 1 or self.sync_every < 1:
            raise ValueError("num_slots, page_size, sync_every must be >= 1")
        if self.max_total_tokens < 2:
            raise ValueError("max_total_tokens must hold a prompt token "
                             "and a completion token at least")
        if self.prefill_chunk < 0 or self.num_pages < 0:
            raise ValueError("prefill_chunk / num_pages must be >= 0")
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.queue_overcommit < 1.0:
            raise ValueError("queue_overcommit < 1 would reject requests "
                             "an idle pool could serve")
        if self.spec_k < 0:
            raise ValueError("spec_k must be >= 0 (0 = speculation off)")
        if self.spec_k > 0 and self.engine != "continuous":
            raise ValueError("speculative decoding (spec_k > 0) needs the "
                             "continuous engine")
        if not 1 <= self.spec_ngram_min <= self.spec_ngram_max:
            raise ValueError("need 1 <= spec_ngram_min <= spec_ngram_max")

    # derived --------------------------------------------------------------
    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_total_tokens // self.page_size)

    @property
    def resolved_num_pages(self) -> int:
        """Page-pool size: explicit ``num_pages``, or scratch + the full
        budget for every slot — plus 50% headroom when the prefix cache
        is on, so cached prefixes survive full slot occupancy instead of
        being evicted the moment every slot reserves its worst-case
        budget."""
        if self.num_pages:
            return self.num_pages
        base = self.num_slots * self.pages_per_slot
        headroom = base // 2 if self.prefix_cache else 0
        return 1 + base + headroom


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A reduced same-family variant for CPU smoke tests: ≤2 pattern periods
    of layers, d_model ≤ 256, ≤ 4 experts."""
    period = cfg.period
    small = dict(
        num_layers=2 * period if 2 * period <= 4 else period,
        d_model=256 if cfg.d_model >= 256 else cfg.d_model,
        num_heads=min(cfg.num_heads, 4) if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=64 if cfg.num_heads else 0,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=32 if cfg.encoder_seq else 0,
        memory_seq=16 if cfg.memory_seq else 0,
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_headdim=32 if cfg.ssm_state else cfg.ssm_headdim,
        ssm_chunk=16 if cfg.ssm_state else cfg.ssm_chunk,
        sliding_window=min(cfg.sliding_window, 64),
        attn_impl="naive",
        attn_chunk=32,
        dtype="float32",
        remat=False,
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
