"""Importance-weight computation — the heart of the paper (Listing 1 +
Table 11). All quantities are computed in log-space for stability.

Token level  (GRPO / Dr.GRPO / BNPO):   w_t = p_t / q_t
Sequence lvl (GSPO):                    w   = p(y|x) / q(y|x)
Group level  (GEPO, ours):              w   = p(y|x) / Ê_q[q(y|x)]
  with  Ê_q[q] = Σ_i q(y_i|x)^2 / Σ_i q(y_i|x)   over the G responses of
  the group (eq. 2), denominator stop-gradiented (it is sampler-side).

Sequence probabilities are length-normalized (eq. 61, GSPO convention):
log p(y|x) = (Σ_t log p_t · m_t) / Σ_t m_t.

Async baselines (App. C, Table 11): Truncated-IS (IMPALA), CISPO, TOPR —
these reshape a *stop-gradiented* weight onto a REINFORCE term and are
assembled in ``repro.core.loss``.

Batch layout: sequences of one group are contiguous — shape (n_groups * G,
T). The defensive smoothed denominator of App. H ("future work") is
implemented behind ``gepo_smooth`` (λ=0 recovers the paper).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

TOKEN_LEVEL = ("grpo", "dr_grpo", "bnpo")
SEQ_LEVEL = ("gspo", "tis", "topr")
GROUP_LEVEL = ("gepo",)
RATIO_METHODS = TOKEN_LEVEL + ("gspo", "gepo")
ASYNC_METHODS = ("tis", "cispo", "topr")
ALL_METHODS = TOKEN_LEVEL + ("gspo", "gepo") + ASYNC_METHODS


def seq_logprob(token_lp: jax.Array, mask: jax.Array,
                length_normalize: bool = True) -> jax.Array:
    """(B, T) token log-probs -> (B,) sequence log-prob."""
    s = (token_lp * mask).sum(-1)
    if length_normalize:
        s = s / jnp.maximum(mask.sum(-1), 1.0)
    return s


def group_expectation_log_denominator(sampler_seq_lp: jax.Array,
                                      group_size: int,
                                      smooth: float = 0.0,
                                      learner_seq_lp: jax.Array | None = None
                                      ) -> jax.Array:
    """log Ê_q[q] per sequence (eq. 2), broadcast back to (B,).

    Ê_q[q] = Σ q_i² / Σ q_i  computed per group in log space:
        log Ê_q[q] = logsumexp(2·log q) − logsumexp(log q).

    ``smooth`` λ>0 enables the App.-H defensive denominator
    (1−λ)·Ê_q[q] + λ·p(y|x)  (p detached).
    """
    b = sampler_seq_lp.shape[0]
    g = group_size
    lp = sampler_seq_lp.reshape(b // g, g)
    log_den = (jax.nn.logsumexp(2.0 * lp, axis=-1)
               - jax.nn.logsumexp(lp, axis=-1))            # (n_groups,)
    log_den = jnp.repeat(log_den, g)
    if smooth > 0.0:
        assert learner_seq_lp is not None
        log_den = jnp.logaddexp(
            jnp.log1p(-smooth) + log_den,
            jnp.log(smooth) + jax.lax.stop_gradient(learner_seq_lp))
    return log_den


def importance_weights(loss_type: str,
                       learner_lp: jax.Array,
                       sampler_lp: jax.Array,
                       mask: jax.Array,
                       *,
                       group_size: int,
                       length_normalize: bool = True,
                       gepo_smooth: float = 0.0,
                       ) -> Tuple[jax.Array, jax.Array]:
    """Returns ``(log_w, level)`` where ``log_w`` is (B, T) for token-level
    methods and (B,) for sequence/group-level ones. Gradients flow through
    the learner log-probs only (sampler side is data)."""
    sampler_lp = jax.lax.stop_gradient(sampler_lp)
    if loss_type in TOKEN_LEVEL or loss_type == "cispo":
        return learner_lp - sampler_lp, "token"

    p_seq = seq_logprob(learner_lp, mask, length_normalize)
    q_seq = seq_logprob(sampler_lp, mask, length_normalize)
    if loss_type in ("gspo", "tis", "topr"):
        return p_seq - q_seq, "seq"
    if loss_type == "gepo":
        log_den = group_expectation_log_denominator(
            q_seq, group_size, smooth=gepo_smooth, learner_seq_lp=p_seq)
        return p_seq - jax.lax.stop_gradient(log_den), "seq"
    raise ValueError(f"unknown loss_type {loss_type!r}")
