"""Training-stability diagnostics (Fig. 4/5/7): metric history accumulation
and the correlation analysis between staleness, KL, IW variance and
estimation error."""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Sequence, Tuple

import numpy as np


class MetricsHistory:
    """Append-only store of scalar metrics per learner step."""

    def __init__(self) -> None:
        self._data: Dict[str, List[float]] = defaultdict(list)

    def append(self, step: int, metrics: Dict[str, float]) -> None:
        self._data["step"].append(float(step))
        for k, v in metrics.items():
            self._data[k].append(float(v))

    def get(self, key: str) -> np.ndarray:
        return np.asarray(self._data[key], np.float64)

    def keys(self):
        return self._data.keys()

    def last(self, key: str, default: float = float("nan")) -> float:
        v = self._data.get(key)
        return v[-1] if v else default

    def summary(self, keys: Sequence[str]) -> Dict[str, float]:
        out = {}
        for k in keys:
            v = self.get(k)
            if len(v):
                out[f"{k}_mean"] = float(v.mean())
                out[f"{k}_last"] = float(v[-1])
                out[f"{k}_max"] = float(v.max())
        return out


def pearson(x: np.ndarray, y: np.ndarray) -> float:
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    if len(x) < 2 or x.std() == 0 or y.std() == 0:
        return float("nan")
    return float(np.corrcoef(x, y)[0, 1])


def correlation_matrix(hist: MetricsHistory,
                       keys: Sequence[str]) -> Dict[Tuple[str, str], float]:
    """Pairwise Pearson correlations (Fig. 7)."""
    out = {}
    for i, a in enumerate(keys):
        for b_ in keys[i + 1:]:
            out[(a, b_)] = pearson(hist.get(a), hist.get(b_))
    return out


def best_last_gap(eval_scores: Sequence[float]) -> Tuple[float, float, float]:
    """(best, last, gap) — the paper's stability headline (Δ, Table 2)."""
    s = np.asarray(list(eval_scores), np.float64)
    if len(s) == 0:
        return float("nan"), float("nan"), float("nan")
    return float(s.max()), float(s[-1]), float(s.max() - s[-1])
