"""Group-relative advantage estimation.

Baseline b(x) is the within-group mean reward (GRPO, §2). Variants:

- ``grpo``/``gspo``/``gepo``: A = (r − mean) [/ std if ``normalize``]
- ``dr_grpo``: no std normalization (Liu et al. 2025 debiasing)
- ``bnpo``:    Beta-normalization — for (near-)binary rewards the batch
               success rate ρ parameterizes Beta(α̂, β̂); A = (r−ρ)/√(ρ(1−ρ))

Per App. F (localized reward computation) these statistics are computed
*per group*, never via a cross-process all-gather — the HeteroRL runtime
guarantees each group is generated and scored on one node.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def group_advantages(rewards: jax.Array, group_size: int, *,
                     normalize: bool = True, kind: str = "grpo",
                     eps: float = 1e-6) -> jax.Array:
    """rewards (B,) with group-contiguous layout -> advantages (B,)."""
    b = rewards.shape[0]
    g = group_size
    r = rewards.reshape(b // g, g)
    if kind == "bnpo":
        rho = jnp.clip(r.mean(), eps, 1.0 - eps)     # batch success rate
        a = (r - rho) / jnp.sqrt(rho * (1.0 - rho))
        return a.reshape(b)
    mean = r.mean(axis=-1, keepdims=True)
    a = r - mean
    if normalize and kind != "dr_grpo":
        a = a / (r.std(axis=-1, keepdims=True) + eps)
    return a.reshape(b)
