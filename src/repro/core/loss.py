"""Policy-optimization loss assembly for every method in the paper.

Ratio family (GRPO / Dr.GRPO / BNPO / GSPO / GEPO):
    PPO-style clipped surrogate on the (token|seq|group)-level ratio:
        L = −E[min(w·A, clip(w, 1±ε)·A)]
    (For GEPO the group-expectation denominator keeps w well-conditioned,
     so the clip rarely binds — exactly the paper's argument.)

Async family (Table 11):
    Truncated-IS:  −E[ sg(clip(w, 0, 1)) · A · log p ]        (seq level)
    CISPO:         −E[ sg(clip(w_t, 1−ε_l, 1+ε_h)) · A · log p_t ]
    TOPR:          −E[ (1_{A>0} + 1_{A≤0}·sg(clip(w, 0, 1))) · A · log p ]

KL regularization: CPPO-KL (Zhang et al. 2024) against the *sampler*
policy (no separate reference model — App. B.1), k3 estimator.

Everything returns rich metrics so the stability diagnostics of Fig. 4/5
(IW variance, KL, estimation error of E[A]) fall out of training for free.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import RLConfig
from repro.core.importance import (ALL_METHODS, importance_weights,
                                   seq_logprob)

sg = jax.lax.stop_gradient


def _masked_mean(x: jax.Array, mask: jax.Array) -> jax.Array:
    return (x * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _per_seq_mean(x: jax.Array, mask: jax.Array) -> jax.Array:
    """(B,T) -> (B,): mean over valid tokens of each sequence."""
    return (x * mask).sum(-1) / jnp.maximum(mask.sum(-1), 1.0)


def kl_k3(learner_lp: jax.Array, sampler_lp: jax.Array,
          mask: jax.Array, clamp: float = 20.0) -> jax.Array:
    """k3 estimator of KL(p‖q) on sampled tokens: E[exp(q−p) − (q−p) − 1].

    Only the exponential term is clamped (±20 nats): with strongly
    divergent policies exp(q−p) otherwise overflows; clamping the whole
    log-ratio would zero the gradient exactly when regularization is
    needed most. The linear term stays live, so at saturation the
    gradient still pushes p toward q."""
    d = sg(sampler_lp) - learner_lp
    d_exp = jnp.clip(d, -clamp, clamp)
    return _masked_mean(jnp.exp(d_exp) - d - 1.0, mask)


def policy_loss(rl: RLConfig,
                learner_lp: jax.Array,
                sampler_lp: jax.Array,
                mask: jax.Array,
                advantages: jax.Array,
                entropy: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """learner_lp/sampler_lp/mask: (B,T); advantages: (B,).

    ``entropy`` (B,T), when provided (the fused-logprob path computes it
    in the same vocab sweep as the log-probs), feeds the
    ``entropy_bonus`` term with the *true* policy entropy H(p(·|x_<t));
    without it the bonus falls back to the −log p(y_t) surrogate.

    Returns (scalar loss, metrics).
    """
    assert rl.loss_type in ALL_METHODS, rl.loss_type
    log_w, level = importance_weights(
        rl.loss_type, learner_lp, sampler_lp, mask,
        group_size=rl.group_size, length_normalize=rl.seq_len_normalize,
        gepo_smooth=rl.gepo_smooth)
    adv = sg(advantages)

    if rl.loss_type in ("grpo", "dr_grpo", "bnpo", "gspo", "gepo"):
        if level == "token":
            w = jnp.exp(log_w)                              # (B,T)
            a = adv[:, None]
            w_clip = jnp.clip(w, 1.0 - rl.clip_eps, 1.0 + rl.clip_eps)
            per_tok = -jnp.minimum(w * a, w_clip * a)
            clip_frac = _masked_mean(
                (jnp.abs(w - 1.0) > rl.clip_eps).astype(jnp.float32), mask)
            if rl.loss_type == "dr_grpo":
                # Dr.GRPO: no per-sequence length normalization
                loss = (per_tok * mask).sum() / (mask.shape[0] * mask.shape[1])
            else:
                loss = _per_seq_mean(per_tok, mask).mean()
            w_seq = jnp.exp(sg(seq_logprob(learner_lp, mask)
                               - seq_logprob(sampler_lp, mask)))
        else:                                               # seq / group
            w = jnp.exp(log_w)                              # (B,)
            w_clip = jnp.clip(w, 1.0 - rl.clip_eps, 1.0 + rl.clip_eps)
            loss = -jnp.minimum(w * adv, w_clip * adv).mean()
            clip_frac = (jnp.abs(sg(w) - 1.0) > rl.clip_eps).mean()
            w_seq = sg(w)
    elif rl.loss_type == "tis":
        w = sg(jnp.clip(jnp.exp(log_w), 0.0, 1.0))          # (B,)
        reinforce = _per_seq_mean(learner_lp, mask)
        loss = -(w * adv * reinforce).mean()
        clip_frac = (jnp.exp(sg(log_w)) > 1.0).astype(jnp.float32).mean()
        w_seq = sg(jnp.exp(log_w))
    elif rl.loss_type == "cispo":
        w_t = sg(jnp.clip(jnp.exp(log_w), 1.0 - rl.cispo_eps_low,
                          1.0 + rl.cispo_eps_high))         # (B,T)
        per_tok = -(w_t * adv[:, None] * learner_lp)
        loss = _per_seq_mean(per_tok, mask).mean()
        clip_frac = _masked_mean(
            ((jnp.exp(sg(log_w)) > 1.0 + rl.cispo_eps_high) |
             (jnp.exp(sg(log_w)) < 1.0 - rl.cispo_eps_low)
             ).astype(jnp.float32), mask)
        w_seq = jnp.exp(sg(seq_logprob(learner_lp, mask)
                           - seq_logprob(sampler_lp, mask)))
    elif rl.loss_type == "topr":
        w = sg(jnp.clip(jnp.exp(log_w), 0.0, 1.0))          # (B,)
        coef = jnp.where(adv > 0, 1.0, w)
        reinforce = _per_seq_mean(learner_lp, mask)
        loss = -(coef * adv * reinforce).mean()
        clip_frac = ((adv <= 0) & (jnp.exp(sg(log_w)) > 1.0)).astype(
            jnp.float32).mean()
        w_seq = sg(jnp.exp(log_w))
    else:
        raise ValueError(rl.loss_type)

    kl = kl_k3(learner_lp, sampler_lp, mask)
    if rl.beta_kl > 0.0:
        loss = loss + rl.beta_kl * kl
    if rl.entropy_bonus > 0.0:
        if entropy is not None:
            loss = loss - rl.entropy_bonus * _masked_mean(entropy, mask)
        else:
            # entropy surrogate on sampled tokens
            loss = loss - rl.entropy_bonus * _masked_mean(-learner_lp, mask)

    # --- stability diagnostics (Fig. 4/5) --------------------------------
    est = (w_seq * adv).mean()          # Monte-Carlo E_q[w·A]; E_p[A] ≈ 0
    metrics = {
        "loss": sg(loss),
        "kl": sg(kl),
        "iw_mean": w_seq.mean(),
        "iw_var": w_seq.var(),
        "iw_max": w_seq.max(),
        "clip_frac": clip_frac,
        "est_error": jnp.abs(est),      # estimation error of E[A] (Fig. 5c)
        "adv_mean": adv.mean(),
        "adv_std": adv.std(),
    }
    if entropy is not None:
        metrics["entropy"] = sg(_masked_mean(entropy, mask))
    return loss, metrics
