"""The paper's algorithmic core: importance weights (token / sequence /
group level), group-relative advantages, loss assembly for every method,
stability diagnostics and the analytic theory of Theorems 1-3."""
from repro.core.advantage import group_advantages
from repro.core.importance import (ALL_METHODS, importance_weights,
                                   group_expectation_log_denominator,
                                   seq_logprob)
from repro.core.loss import kl_k3, policy_loss

__all__ = ["group_advantages", "importance_weights", "policy_loss",
           "kl_k3", "seq_logprob", "ALL_METHODS",
           "group_expectation_log_denominator"]
