"""Analytic machinery for Theorems 1–3 (App. A): exact variance of the
standard and group-expectation importance weights over discrete
distributions, the KL/χ² bounds, and the bias bound — used by the
property-based tests and by the Fig. 2 benchmark.

Population form (App. A): Ê_q[q] := Σ_i q_i²  (= ‖q‖₂²).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _norm(p: np.ndarray) -> np.ndarray:
    p = np.asarray(p, np.float64)
    return p / p.sum()


def kl(p: np.ndarray, q: np.ndarray) -> float:
    p, q = _norm(p), _norm(q)
    return float(np.sum(p * (np.log(p) - np.log(q))))


def chi2(p: np.ndarray, q: np.ndarray) -> float:
    p, q = _norm(p), _norm(q)
    return float(np.sum(p * p / q) - 1.0)


def var_std(p: np.ndarray, q: np.ndarray) -> float:
    """Var_q[p/q] = Σ p²/q − 1   (eq. 10)."""
    p, q = _norm(p), _norm(q)
    return float(np.sum(p * p / q) - 1.0)


def var_new(p: np.ndarray, q: np.ndarray) -> float:
    """Var_q[p/Ê_q[q]] (eq. 14) with Ê_q[q] = Σ q²."""
    p, q = _norm(p), _norm(q)
    eq = np.sum(q * q)
    i2 = np.sum(p * p * q)
    b = np.sum(p * q)
    return float((i2 - b * b) / (eq * eq))


def theorem1_terms(p: np.ndarray, q: np.ndarray) -> Tuple[float, float, float]:
    """Returns (Δ = Var_std − Var_new, exp(KL), C = n²+1): Theorem 1 states
    Δ ≥ exp(KL) − C."""
    p, q = _norm(p), _norm(q)
    n = p.shape[0]
    delta = var_std(p, q) - var_new(p, q)
    return delta, float(np.exp(kl(p, q))), float(n * n + 1)


def bias_gepo(p: np.ndarray, q: np.ndarray, a: np.ndarray) -> float:
    """|E_p[A] − E_q[(p/Ê_q[q])·A]| with E_p[A] = 0 enforced by centering
    (Theorem 2 setting)."""
    p, q = _norm(p), _norm(q)
    a = np.asarray(a, np.float64)
    a = a - np.sum(p * a)                      # center so E_p[A] = 0
    a = a / max(np.abs(a).max(), 1e-12)        # |A| <= 1
    eq = np.sum(q * q)
    return float(abs(np.sum(p * q * a) / eq))


def bias_bound(p: np.ndarray, q: np.ndarray) -> float:
    """‖p‖₂ / ‖q‖₂ (Theorem 2)."""
    p, q = _norm(p), _norm(q)
    return float(np.linalg.norm(p) / np.linalg.norm(q))


# --------------------------------------------------------------------------
# Fig. 2 closed forms / quadrature


def bernoulli_vars(a: float, b: float) -> Tuple[float, float]:
    """p ~ Bernoulli(a), q ~ Bernoulli(b): (Var_std, Var_new)."""
    p = np.array([1 - a, a])
    q = np.array([1 - b, b])
    return var_std(p, q), var_new(p, q)


def gaussian_vars(a: float, b: float, num: int = 20001,
                  span: float = 12.0) -> Tuple[float, float, float]:
    """p ~ N(a,1), q ~ N(b,1) by quadrature: (Var_std, Var_new, KL)."""
    lo = min(a, b) - span
    hi = max(a, b) + span
    y = np.linspace(lo, hi, num)
    dy = y[1] - y[0]

    def pdf(m):
        return np.exp(-0.5 * (y - m) ** 2) / np.sqrt(2 * np.pi)

    p, q = pdf(a), pdf(b)
    eq = np.sum(q * q) * dy                    # ∫ q²
    v_std = np.sum(p * p / np.maximum(q, 1e-300)) * dy - 1.0
    i2 = np.sum(p * p * q) * dy
    ipq = np.sum(p * q) * dy
    v_new = (i2 - ipq ** 2) / eq ** 2
    kl_pq = 0.5 * (a - b) ** 2                 # exact for unit-variance
    return float(v_std), float(v_new), float(kl_pq)
