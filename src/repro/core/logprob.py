"""Sharding-friendly token log-probabilities.

``take_along_axis`` over a vocab-sharded logits tensor makes GSPMD
all-gather the full vocabulary (tens of GB at RL shapes). The masked-sum
formulation below keeps every op elementwise/reduction along the sharded
vocab axis, so the only cross-device traffic is an all-reduce of (B, S)
scalars. These are the *materializing* reference implementations; the
training hot path dispatches to ``repro.kernels.ops.fused_token_logprob``
(Pallas on TPU, chunked ``lax.map`` elsewhere), which computes identical
values and gradients without a V-sized f32 activation in either pass.

Target-id contract (shared with the fused kernels): target ids are
clamped to [0, V) before the gather. Padded positions conventionally
carry arbitrary ids (0, -1, a tokenizer PAD beyond the model vocab, ...)
and are excluded by the loss mask — with the clamp they yield the
(finite, well-defined) log-prob of a valid token rather than silently
degenerating to −logsumexp(logits), which used to poison any unmasked
reduction and every naive↔fused parity check.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def clamp_target_ids(targets: jax.Array, vocab: int) -> jax.Array:
    """The shared target-id contract, in one place: ids clamp to
    [0, vocab). Used by the naive helpers here, the fused kernels
    (``repro.kernels.fused_logprob``) and the oracle (``kernels.ref``)."""
    return jnp.clip(targets.astype(jnp.int32), 0, vocab - 1)


def token_logprob_from_logits(logits: jax.Array, targets: jax.Array
                              ) -> jax.Array:
    """logits (B, S, V) [any dtype], targets (B, S) int32 -> (B, S) f32."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    tgt = clamp_target_ids(targets, lg.shape[-1])
    hit = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1) \
        == tgt[..., None]
    tl = jnp.where(hit, lg, 0.0).sum(axis=-1)
    return tl - lse


def token_logprob_entropy_lse(logits: jax.Array, targets: jax.Array
                              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(logp, entropy, lse) triple over the last axis, all f32 — the
    single source of truth for the masked-sum log-prob/entropy math:
    used whole-array here and chunk-at-a-time by the fused kernels'
    fallback (``repro.kernels.fused_logprob._chunk_fwd``), whose custom
    VJP saves the ``lse`` residual."""
    lg = logits.astype(jnp.float32)
    m = lg.max(axis=-1)
    p_un = jnp.exp(lg - m[..., None])
    l = jnp.maximum(p_un.sum(axis=-1), 1e-30)
    lse = m + jnp.log(l)
    tgt = clamp_target_ids(targets, lg.shape[-1])
    hit = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1) \
        == tgt[..., None]
    tl = jnp.where(hit, lg, 0.0).sum(axis=-1)
    ent = lse - (p_un * lg).sum(-1) / l
    return tl - lse, ent, lse


def token_logprob_and_entropy(logits: jax.Array, targets: jax.Array
                              ) -> Tuple[jax.Array, jax.Array]:
    lp, ent, _ = token_logprob_entropy_lse(logits, targets)
    return lp, ent
