"""Sharding-friendly token log-probabilities.

``take_along_axis`` over a vocab-sharded logits tensor makes GSPMD
all-gather the full vocabulary (tens of GB at RL shapes). The masked-sum
formulation below keeps every op elementwise/reduction along the sharded
vocab axis, so the only cross-device traffic is an all-reduce of (B, S)
scalars. On TPU the ``repro.kernels.fused_logprob`` Pallas kernel computes
the same quantity without materializing log-softmax at all.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def token_logprob_from_logits(logits: jax.Array, targets: jax.Array
                              ) -> jax.Array:
    """logits (B, S, V) [any dtype], targets (B, S) int32 -> (B, S) f32."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    v = lg.shape[-1]
    hit = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1) \
        == targets[..., None]
    tgt = jnp.where(hit, lg, 0.0).sum(axis=-1)
    return tgt - lse


def token_logprob_and_entropy(logits: jax.Array, targets: jax.Array
                              ) -> Tuple[jax.Array, jax.Array]:
    lg = logits.astype(jnp.float32)
    m = lg.max(axis=-1, keepdims=True)
    p_un = jnp.exp(lg - m)
    l = p_un.sum(axis=-1)
    lse = m[..., 0] + jnp.log(l)
    hit = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1) \
        == targets[..., None]
    tgt = jnp.where(hit, lg, 0.0).sum(axis=-1)
    ent = lse - (p_un * lg).sum(-1) / l
    return tgt - lse, ent
