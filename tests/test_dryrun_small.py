"""Dry-run machinery on a CI-sized fake mesh (subprocess so the
XLA_FLAGS device-count override never leaks into other tests). Also unit
tests for the roofline HLO parsers."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.roofline import (_shape_bytes, parse_collective_bytes,
                                   parse_collectives_loop_aware)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.config import RLConfig, TrainConfig, ShapeConfig
    from repro.configs import smoke
    from repro.launch import sharding as shd, step_fns as sf
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh(2, 2, multi_pod=True)    # (2,2,2) = 8 devices
    cfg = dataclasses.replace(smoke("{arch}"), remat=True,
                              act_sharding=shd.act_sharding_for("train",
                                                                mesh))
    shape = ShapeConfig("tiny_train", 64, 16, "train")
    rl, tc = RLConfig(group_size=4), TrainConfig()
    with mesh:
        step = sf.make_train_fn(cfg, rl, tc)
        state = sf.abstract_state(cfg)
        batch = sf.abstract_batch(cfg, shape)
        pspecs = shd.param_specs(cfg, "train", mesh)
        ss = sf.TrainState(params=pspecs,
                           opt=shd.opt_specs(pspecs, sf.optimizer_for(cfg)),
                           step=P())
        compiled = jax.jit(
            step,
            in_shardings=(shd.to_named_fit(mesh, ss, state),
                          shd.to_named_fit(mesh, shd.batch_specs(cfg, mesh),
                                           batch)),
            out_shardings=(shd.to_named_fit(mesh, ss, state), None),
        ).lower(state, batch).compile()
    hlo = compiled.as_text()
    assert "all-reduce" in hlo or "all-gather" in hlo
    from repro.launch.roofline import normalize_cost_analysis
    ca = normalize_cost_analysis(compiled.cost_analysis())
    print(json.dumps({{"ok": True, "flops": ca.get("flops", 0)}}))
""")


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-1.3b",
                                  "llama4-scout-17b-a16e"])
def test_train_step_lowers_on_multipod_debug_mesh(arch):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT.format(arch=arch)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]


class TestRooflineParsers:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16[4,8]") == 64
        assert _shape_bytes("(f32[2,2], s32[4])") == 32
        assert _shape_bytes("pred[]") == 1

    def test_collective_parse(self):
        hlo = """
ENTRY %main (p0: f32[4]) -> f32[4] {
  %ag = f32[64,32]{1,0} all-gather(%x), replica_groups={}
  %ar = bf16[16]{0} all-reduce(%y), to_apply=%add
  ROOT %r = f32[4] add(%p0, %p0)
}
"""
        out = parse_collective_bytes(hlo)
        assert out["all-gather"] == 64 * 32 * 4
        assert out["all-reduce"] == 32

    def test_loop_aware_multiplies_trip_count(self):
        hlo = """
%cond (p: (s32[])) -> pred[] {
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
%body (p: (s32[])) -> (s32[]) {
  %ag = f32[8]{0} all-gather(%z), replica_groups={}
  ROOT %t = (s32[]) tuple(%i)
}
ENTRY %main (p0: f32[4]) -> f32[4] {
  %w = (s32[]) while(%init), condition=%cond, body=%body
  %ar = f32[4]{0} all-reduce(%p0), to_apply=%add
  ROOT %r = f32[4] add(%p0, %p0)
}
"""
        out = parse_collectives_loop_aware(hlo)
        assert out["all-gather"] == 5 * 8 * 4
        assert out["all-reduce"] == 16
