"""Model-substrate equivalence tests: flash-vjp chunked attention vs naive
(fwd + grads), SSD chunked vs sequential reference, head slicing,
decode-state continuity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (chunked_attention, decode_attention,
                                    naive_attention)
from repro.models.ssm import (ssd_chunked, ssd_decode_step, ssd_reference)


@pytest.mark.parametrize("kind,window", [("causal", 0), ("local", 24),
                                         ("bidir", 0)])
@pytest.mark.parametrize("seqs", [(64, 64), (96, 96), (32, 80)])
def test_chunked_vs_naive_fwd_bwd(rng, kind, window, seqs):
    sq, sk = seqs
    b, hq, hkv, d = 2, 4, 2, 32
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d))
    k = jax.random.normal(ks[1], (b, sk, hkv, d))
    v = jax.random.normal(ks[2], (b, sk, hkv, d))
    pq = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    pk = jnp.broadcast_to(jnp.arange(sk), (b, sk))

    def f_naive(q, k, v):
        return naive_attention(q, k, v, pos_q=pq, pos_k=pk, kind=kind,
                               window=window)

    def f_chunk(q, k, v):
        return chunked_attention(q, k, v, pos_q=pq, pos_k=pk, kind=kind,
                                 window=window, q_chunk=32, kv_chunk=32)

    np.testing.assert_allclose(np.asarray(f_chunk(q, k, v)),
                               np.asarray(f_naive(q, k, v)),
                               rtol=1e-5, atol=1e-5)
    w = jnp.cos(jnp.arange(d))
    for i in range(3):
        g1 = jax.grad(lambda *a: (f_chunk(*a) * w).sum(), argnums=i)(q, k, v)
        g2 = jax.grad(lambda *a: (f_naive(*a) * w).sum(), argnums=i)(q, k, v)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-4)


def test_decode_attention_matches_row_of_naive(rng):
    b, s, hq, hkv, d = 2, 32, 4, 2, 16
    ks = jax.random.split(rng, 3)
    q_all = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    full = naive_attention(q_all, k, v, pos_q=pos, pos_k=pos, kind="causal")
    t = s - 3
    out = decode_attention(q_all[:, t:t + 1], k, v, pos=jnp.int32(t),
                           kind="causal")
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, t]),
                               rtol=1e-5, atol=1e-5)


class TestSSD:
    def test_chunked_matches_reference(self, rng):
        b, s, h, p, g, n = 2, 96, 4, 16, 2, 8
        ks = jax.random.split(rng, 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        bb = jax.random.normal(ks[3], (b, s, g, n))
        cc = jax.random.normal(ks[4], (b, s, g, n))
        init = jax.random.normal(ks[0], (b, h, p, n))
        y0, s0 = ssd_reference(x, dt, a, bb, cc, init_state=init)
        for chunk in (16, 32, 96):
            for hs in (0, 1, 2):
                y, st = ssd_chunked(x, dt, a, bb, cc, chunk=chunk,
                                    init_state=init, head_slice=hs)
                np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                           rtol=1e-3, atol=1e-3)
                np.testing.assert_allclose(np.asarray(st), np.asarray(s0),
                                           rtol=1e-3, atol=1e-3)

    def test_grad_through_head_slices(self, rng):
        b, s, h, p, g, n = 1, 32, 4, 8, 1, 4
        ks = jax.random.split(rng, 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        bb = jax.random.normal(ks[3], (b, s, g, n))
        cc = jax.random.normal(ks[4], (b, s, g, n))

        def loss(hs):
            return lambda x: ssd_chunked(x, dt, a, bb, cc, chunk=8,
                                         head_slice=hs)[0].sum()
        g0 = jax.grad(loss(0))(x)
        g2 = jax.grad(loss(2))(x)
        np.testing.assert_allclose(np.asarray(g0), np.asarray(g2),
                                   rtol=1e-4, atol=1e-4)

    def test_decode_continues_prefill_state(self, rng):
        """state from chunked prefill + one recurrent step == reference
        over the extended sequence."""
        b, s, h, p, g, n = 1, 32, 2, 8, 1, 4
        ks = jax.random.split(rng, 5)
        x = jax.random.normal(ks[0], (b, s + 1, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s + 1, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        bb = jax.random.normal(ks[3], (b, s + 1, g, n))
        cc = jax.random.normal(ks[4], (b, s + 1, g, n))
        _, st = ssd_chunked(x[:, :s], dt[:, :s], a, bb[:, :s], cc[:, :s],
                            chunk=8)
        st2, y_t = ssd_decode_step(st, x[:, s], dt[:, s], a, bb[:, s],
                                   cc[:, s])
        y_ref, st_ref = ssd_reference(x, dt, a, bb, cc)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_ref[:, s]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st2), np.asarray(st_ref),
                                   rtol=1e-4, atol=1e-4)


class TestRingKV:
    """§Perf H-G1: ring-buffer local-window KV cache (gemma2 long decode)
    must produce identical logits to the full cache."""

    def test_ring_decode_matches_full(self, rng):
        import dataclasses
        from repro.configs import smoke
        from repro.models import decode_step, forward, init_cache, init_params
        cfg0 = smoke("gemma2-9b", sliding_window=8)
        cfg1 = dataclasses.replace(cfg0, local_ring_kv=True)
        params = init_params(cfg0, rng)
        b, s = 2, 24                           # 3× the window
        toks = jax.random.randint(rng, (b, s), 0, cfg0.vocab_size)
        outs = {}
        for name, cfg in [("full", cfg0), ("ring", cfg1)]:
            cache = init_cache(cfg, params, b, s)
            row = []
            for t in range(s):
                lg, cache = decode_step(cfg, params, cache, toks[:, t],
                                        jnp.int32(t))
                row.append(lg)
            outs[name] = jnp.stack(row, 1)
        np.testing.assert_allclose(np.asarray(outs["full"]),
                                   np.asarray(outs["ring"]),
                                   rtol=2e-4, atol=2e-4)

    def test_ring_prefill_then_decode(self, rng):
        import dataclasses
        from repro.configs import smoke
        from repro.models import decode_step, forward, init_cache, init_params
        cfg = dataclasses.replace(smoke("gemma2-9b", sliding_window=8),
                                  local_ring_kv=True)
        params = init_params(cfg, rng)
        b, s = 2, 20
        toks = jax.random.randint(rng, (b, s + 1), 0, cfg.vocab_size)
        full, _, _ = forward(cfg, params, toks)
        cache = init_cache(cfg, params, b, s + 1)
        _, cache, _ = forward(cfg, params, toks[:, :s], cache=cache)
        lg, _ = decode_step(cfg, params, cache, toks[:, s], jnp.int32(s))
        np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(lg),
                                   rtol=2e-4, atol=2e-4)
