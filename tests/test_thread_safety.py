"""Concurrency regression tests for the thread-shared hetero stores.

RA005 proves statically that the mutators hold locks; these tests hammer
them from real threads so a dropped lock shows up as a lost update or a
corrupted heap, not just an analyzer finding.
"""
import threading

import pytest

from repro.checkpoint import PolicyStore
from repro.hetero.events import EventSim, Transport


def _run_threads(workers):
    errors = []

    def wrap(fn):
        def go():
            try:
                fn()
            except Exception as e:           # surfaced in the main thread
                errors.append(e)
        return go

    threads = [threading.Thread(target=wrap(fn)) for fn in workers]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "worker thread hung"
    assert not errors, errors


class TestPolicyStoreHammer:
    def test_publish_fetch_four_threads(self):
        """2 publishers + 2 fetchers, interleaved versions. Every fetch
        must return an internally consistent (version, blob) pair and
        the final store must hold exactly the last `keep` versions."""
        store = PolicyStore(keep=8)
        n_per_pub = 200
        store.publish(0, b"v0:seed")

        def publisher(pid):
            def go():
                for i in range(n_per_pub):
                    v = pid * n_per_pub + i + 1
                    store.publish(v, f"v{v}:".encode() + b"x" * (v % 17))
            return go

        def fetcher():
            def go():
                for _ in range(400):
                    v, blob = store.fetch()
                    # blob must be the one published under v — a torn
                    # read across publish+prune would break this pairing
                    assert blob.startswith(f"v{v}:".encode()), (v, blob[:12])
                    assert store.latest_version() >= v
            return go

        _run_threads([publisher(0), publisher(1), fetcher(), fetcher()])
        assert store.latest_version() == 2 * n_per_pub
        v, blob = store.fetch()
        assert v == 2 * n_per_pub and blob.startswith(f"v{v}:".encode())

    def test_chunk_hammer_with_pruning_gc(self):
        """Chunk put/get racing manifest publishes that trigger GC: the
        atomic get_chunks snapshot must never observe a half-pruned
        index for chunks a retained manifest pins."""
        store = PolicyStore(keep=4)
        per_version = 8

        def hashes(v):
            return [f"c{v}-{j}" for j in range(per_version)]

        def publisher():
            for v in range(120):
                for h in hashes(v):
                    store.put_chunk(h, h.encode() * 3)
                store.publish_manifest(v, f"m{v}".encode(), hashes(v))

        def reader():
            for _ in range(300):
                v, _ = store.fetch() if store.latest_version() >= 0 \
                    else (None, None)
                if v is None:
                    continue
                try:
                    got = store.get_chunks(hashes(v))
                except KeyError:
                    continue      # v was pruned between fetch and get
                assert set(got) == set(hashes(v))
                assert all(got[h] == h.encode() * 3 for h in got)

        _run_threads([publisher, reader, reader, reader])
        # GC kept only the chunks of retained manifests
        assert store.num_chunks == 4 * per_version
        assert store.chunks_gced > 0


class TestEventStoreHammer:
    def test_concurrent_schedule_while_stepping(self):
        """Helper threads schedule while the main thread drains: no
        heap corruption, no lost events, handlers run outside the lock
        (a handler that reschedules must not deadlock)."""
        sim = EventSim()
        fired = []
        fired_lock = threading.Lock()
        n_threads, n_events = 4, 250

        def handler(tag):
            def fn():
                with fired_lock:
                    fired.append(tag)
                if tag[1] == 0:   # reentrant schedule from a handler
                    sim.schedule(0.5, handler((tag[0], -1)))
            return fn

        def scheduler(tid):
            def go():
                for i in range(n_events):
                    sim.schedule((i % 7) * 0.1, handler((tid, i)))
            return go

        _run_threads([scheduler(t) for t in range(n_threads)])
        sim.run_until()
        assert len(fired) == n_threads * (n_events + 1)
        # all scheduling happened at now=0: delays <= 0.6 plus the 0.5
        # reentrant hop bound the final clock
        assert 0.0 < sim.now <= 1.2

    def test_transport_counters(self):
        sim = EventSim()
        tr = Transport(sim)
        n_threads, n_msgs = 4, 500

        def sender():
            for _ in range(n_msgs):
                tr.send(0.0, lambda: None, nbytes=3)

        _run_threads([sender] * n_threads)
        # += under the lock: no lost updates
        assert tr.messages_sent == n_threads * n_msgs
        assert tr.bytes_sent == 3 * n_threads * n_msgs
        sim.run_until()
