"""Unified execution layer: ExecutionPlan construction and placement,
sharded-step equivalence + donation on the local (1×1) plan, checkpoint
round-trip fixes (bf16 dtype preservation, pruned-version fetch)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import PolicyStore, load_pytree, save_pytree
from repro.config import ModelConfig, RLConfig, TrainConfig, ATTN, MLP
from repro.models import init_params
from repro.parallel import (ExecutionPlan, local_plan, make_sharded_train_step,
                            plan_from_flag)
from repro.training import TrainState, init_state, train_step

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=48,
                   num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=32,
                   block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)
RL = RLConfig(loss_type="gepo", group_size=4, beta_kl=0.005)


def _batch(key, b=8, s=10):
    ks = jax.random.split(key, 3)
    return {
        "tokens": jax.random.randint(ks[0], (b, s), 0, 32),
        "mask": jnp.ones((b, s - 1)),
        "sampler_lp": -jnp.abs(jax.random.normal(ks[1], (b, s - 1))),
        "rewards": (jax.random.uniform(ks[2], (b,)) > 0.5).astype(
            jnp.float32),
    }


class TestExecutionPlan:
    def test_hashable_and_cached(self):
        p1, p2 = local_plan("train"), local_plan("train")
        assert p1 is p2 and hash(p1) == hash(p2)
        assert local_plan("serve") != p1
        assert plan_from_flag("1x1", "train") is p1
        assert plan_from_flag(None, "train") is p1

    def test_bad_specs_rejected(self):
        with pytest.raises(ValueError):
            ExecutionPlan(mesh=local_plan("train").mesh, mode="bogus")
        from repro.parallel import mesh_from_flag
        with pytest.raises(ValueError):
            mesh_from_flag("banana")
        with pytest.raises(RuntimeError):      # more devices than visible
            mesh_from_flag("64x64")

    def test_state_shardings_match_state_structure(self, rng):
        plan = local_plan("train")
        for optimizer in ("adamw", "adafactor"):
            state = init_state(TINY, TrainConfig(), init_params(TINY, rng),
                               optimizer=optimizer)
            sh = plan.state_shardings(TINY, optimizer)
            assert (jax.tree_util.tree_structure(state)
                    == jax.tree_util.tree_structure(sh))

    def test_device_put_and_gather_roundtrip(self, rng):
        plan = local_plan("train")
        params = init_params(TINY, rng)
        placed = plan.device_put_params(TINY, params, copy=True)
        host = plan.host_gather(placed)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(host)):
            np.testing.assert_array_equal(np.asarray(a), b)

    def test_batch_shardings_reject_unknown_keys(self):
        plan = local_plan("train")
        with pytest.raises(ValueError, match="no batch sharding rule"):
            plan.batch_shardings(TINY, {"mystery": jnp.ones((2, 2))})


class TestShardedStep:
    def test_local_plan_matches_unsharded_and_donates(self, rng):
        batch = _batch(jax.random.PRNGKey(5))
        params = init_params(TINY, rng)
        for accum in (1, 2):
            tc = TrainConfig(learning_rate=1e-3, grad_accum=accum,
                             total_steps=10)
            ref_new, ref_m = train_step(TINY, RL, tc,
                                        init_state(TINY, tc, params), batch)
            plan = local_plan("train")
            # the donated step consumes the state — give it its own copy
            # of params (device_put onto an identical sharding aliases)
            st = init_state(TINY, tc,
                            jax.tree_util.tree_map(jnp.array, params),
                            plan=plan)
            step = make_sharded_train_step(TINY, RL, tc, plan)
            new_state, m = step(st, batch)
            assert all(l.is_deleted() for l in
                       jax.tree_util.tree_leaves(st.params)), \
                "TrainState must be donated (no 2x param copies)"
            for a, b in zip(jax.tree_util.tree_leaves(ref_new.params),
                            jax.tree_util.tree_leaves(new_state.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=5e-5, atol=1e-6)
            for k in ref_m:
                np.testing.assert_allclose(float(ref_m[k]), float(m[k]),
                                           rtol=1e-4, atol=1e-6)

    def test_jit_train_step_goes_through_plan(self, rng):
        from repro.training import jit_train_step
        tc = TrainConfig(learning_rate=1e-3, total_steps=10)
        f = jit_train_step(TINY, RL, tc)
        assert f.plan is local_plan("train")
        st = init_state(TINY, tc, init_params(TINY, rng), plan=f.plan)
        new_state, m = f(st, _batch(jax.random.PRNGKey(6)))
        assert np.isfinite(float(m["loss"]))


class TestCheckpointDtypes:
    def test_bf16_roundtrip_preserves_dtype_and_values(self, rng):
        tree = {"w": (jax.random.normal(rng, (4, 6)) * 3
                      ).astype(jnp.bfloat16),
                "scalar": jnp.float32(2.5),
                "nested": {"b": jnp.arange(7, dtype=jnp.bfloat16)}}
        blob = save_pytree(tree)
        back = load_pytree(blob, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(back)):
            assert a.dtype == b.dtype, "bf16 leaf silently changed dtype"
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_bf16_params_roundtrip(self, rng):
        import dataclasses
        cfg = dataclasses.replace(TINY, dtype="bfloat16", name="tiny-bf16")
        params = init_params(cfg, rng)
        back = load_pytree(save_pytree(params), params)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(back)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


class TestPolicyStoreFetch:
    def test_pruned_version_degrades_to_oldest_retained(self):
        store = PolicyStore(keep=2)
        for v in range(5):
            store.publish(v, bytes([v]))
        v, data = store.fetch(0)               # pruned: degrade, count
        assert (v, data) == (3, bytes([3]))
        assert store.stale_fetches == 1
        v, data = store.fetch(4)               # retained: exact
        assert (v, data) == (4, bytes([4]))
        assert store.stale_fetches == 1

    def test_never_published_version_raises_descriptive(self):
        store = PolicyStore(keep=2)
        store.publish(0, b"x")
        with pytest.raises(KeyError, match="never published"):
            store.fetch(99)

    def test_gap_version_below_prune_horizon_still_raises(self):
        """Only versions that actually went through publish() may degrade
        to the oldest retained one — a gap version (sync_interval > 1)
        is a caller bug, not staleness, wherever it falls."""
        store = PolicyStore(keep=2)
        for v in (0, 2, 4, 6):
            store.publish(v, bytes([v]))
        v, data = store.fetch(0)               # published, pruned
        assert (v, data) == (4, bytes([4])) and store.stale_fetches == 1
        with pytest.raises(KeyError, match="never published"):
            store.fetch(1)                     # below horizon, never seen
        with pytest.raises(KeyError, match="never published"):
            store.fetch(5)                     # above horizon, never seen
        assert store.stale_fetches == 1

    def test_empty_store_raises_descriptive(self):
        with pytest.raises(KeyError, match="empty"):
            PolicyStore().fetch()
