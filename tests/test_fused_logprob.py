"""Differentiable fused-logprob: value AND gradient parity of the Pallas
kernel pair (interpret mode) and the chunked lax.map fallback against the
naive materializing oracle, including padded / non-divisible (T, V)
shapes and the out-of-range target-id contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.logprob import (token_logprob_and_entropy,
                                token_logprob_from_logits)
from repro.kernels import ops, ref
from repro.kernels.fused_logprob import chunked_logprob, fused_logprob


def _tols(dtype):
    return dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


def _naive_loss(logits, tgt, w_lp, w_ent):
    lp, ent = token_logprob_and_entropy(logits, tgt)
    return (w_lp * lp + w_ent * ent).sum()


def _mk_inputs(rng, t, v, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    logits = (4 * jax.random.normal(ks[0], (t, v))).astype(dtype)
    tgt = jax.random.randint(ks[1], (t,), 0, v)
    w_lp = jax.random.normal(ks[2], (t,))
    w_ent = jax.random.normal(ks[3], (t,))
    return logits, tgt, w_lp, w_ent


class TestGradParity:
    """jax.grad through the custom VJP == autodiff through the oracle,
    for both the logp and the entropy output."""

    @pytest.mark.parametrize("shape", [(64, 512), (128, 1024)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_pallas_interpret(self, rng, shape, dtype):
        t, v = shape
        logits, tgt, w_lp, w_ent = _mk_inputs(rng, t, v, dtype)

        def loss(x):
            lp, ent = fused_logprob(x, tgt, block_t=16, block_v=128,
                                    interpret=True)
            return (w_lp * lp + w_ent * ent).sum()

        val, grad = jax.value_and_grad(loss)(logits)
        val_e, grad_e = jax.value_and_grad(
            lambda x: _naive_loss(x, tgt, w_lp, w_ent))(logits)
        tol = _tols(dtype)
        np.testing.assert_allclose(float(val), float(val_e), rtol=1e-3)
        assert grad.dtype == logits.dtype
        np.testing.assert_allclose(np.asarray(grad, np.float32),
                                   np.asarray(grad_e, np.float32), **tol)

    @pytest.mark.parametrize("shape", [
        (100, 300, 32),          # non-divisible T and V
        (96, 257, 32),           # prime-ish vocab
        (37, 512, 64),           # T smaller than two chunks, ragged tail
        (64, 128, 64),           # exactly divisible
    ])
    def test_chunked_fallback(self, rng, shape):
        t, v, chunk = shape
        logits, tgt, w_lp, w_ent = _mk_inputs(rng, t, v)

        def loss(x):
            lp, ent = chunked_logprob(x, tgt, chunk=chunk)
            return (w_lp * lp + w_ent * ent).sum()

        val, grad = jax.value_and_grad(loss)(logits)
        val_e, grad_e = jax.value_and_grad(
            lambda x: _naive_loss(x, tgt, w_lp, w_ent))(logits)
        np.testing.assert_allclose(float(val), float(val_e), rtol=1e-4)
        np.testing.assert_allclose(np.asarray(grad), np.asarray(grad_e),
                                   rtol=2e-4, atol=2e-4)

    def test_values_match_ref(self, rng):
        logits, tgt, _, _ = _mk_inputs(rng, 64, 384)
        lp_e, ent_e = ref.fused_logprob_ref(logits, tgt)
        for lp, ent in (chunked_logprob(logits, tgt, chunk=24),
                        fused_logprob(logits, tgt, block_t=16,
                                      block_v=128, interpret=True)):
            np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_e),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(np.asarray(ent), np.asarray(ent_e),
                                       rtol=1e-4, atol=1e-4)


class TestDispatcher:
    def test_auto_on_cpu_handles_any_shape(self, rng):
        # (B, S, V) with non-divisible S·B and V: auto => chunked on CPU
        ks = jax.random.split(rng, 2)
        logits = jax.random.normal(ks[0], (3, 7, 129))
        tgt = jax.random.randint(ks[1], (3, 7), 0, 129)
        lp, ent = ops.fused_token_logprob(logits, tgt)
        lp_e, ent_e = token_logprob_and_entropy(logits, tgt)
        assert lp.shape == ent.shape == (3, 7)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_e),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ent), np.asarray(ent_e),
                                   rtol=1e-5, atol=1e-5)

    def test_pallas_ragged_falls_back(self, rng):
        ks = jax.random.split(rng, 2)
        logits = jax.random.normal(ks[0], (50, 300))     # 300 % 256 != 0...
        tgt = jax.random.randint(ks[1], (50,), 0, 300)
        # ...so impl="pallas" must still work (chunked under the hood)
        lp, _ = ops.fused_token_logprob(logits, tgt, impl="pallas",
                                        block_t=16, block_v=256)
        lp_e = token_logprob_from_logits(logits, tgt)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_e),
                                   rtol=1e-5, atol=1e-5)

    def test_tile_derivation_hits_real_model_shapes(self):
        """Realistic shapes — t = B·(S−1), 256-aligned padded vocab —
        rarely divide the default blocks; the dispatcher must shrink the
        tiles rather than silently abandoning the Pallas path."""
        from repro.kernels.ops import _largest_divisor
        assert _largest_divisor(64 * 4095, 256, 8) == 240
        assert _largest_divisor(152_064, 2048, 128) == 1536  # qwen2 vocab
        assert _largest_divisor(128_256, 2048, 128) == 768   # llama3.2
        assert _largest_divisor(100, 256, 8) == 0            # no aligned tile
        assert _largest_divisor(300, 2048, 128) == 0

    def test_pallas_forced_on_unaligned_shape(self, rng):
        # t=40 (mult of 8, not of block_t=256) and v=384 (mult of 128,
        # not of 2048): previously fell back silently; now tiles shrink
        ks = jax.random.split(rng, 2)
        logits = jax.random.normal(ks[0], (5, 8, 384))
        tgt = jax.random.randint(ks[1], (5, 8), 0, 384)
        lp, ent = ops.fused_token_logprob(logits, tgt, impl="pallas")
        lp_e, ent_e = token_logprob_and_entropy(logits, tgt)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_e),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ent), np.asarray(ent_e),
                                   rtol=1e-5, atol=1e-5)

    def test_rank1_logits(self, rng):
        logits = jax.random.normal(rng, (384,))
        tgt = jnp.asarray(7, jnp.int32)
        lp, ent = ops.fused_token_logprob(logits, tgt)
        lp_e, ent_e = token_logprob_and_entropy(logits[None], tgt[None])
        assert lp.shape == ent.shape == ()
        np.testing.assert_allclose(float(lp), float(lp_e[0]), rtol=1e-5)
        np.testing.assert_allclose(float(ent), float(ent_e[0]), rtol=1e-5)

    def test_unknown_impl_raises(self, rng):
        logits = jnp.zeros((4, 32))
        tgt = jnp.zeros((4,), jnp.int32)
        with pytest.raises(ValueError):
            ops.fused_token_logprob(logits, tgt, impl="magic")

    def test_grad_through_dispatcher(self, rng):
        logits, tgt, w_lp, w_ent = _mk_inputs(rng, 48, 160)
        g = jax.grad(lambda x: (
            w_lp * ops.fused_token_logprob(x, tgt)[0]
            + w_ent * ops.fused_token_logprob(x, tgt)[1]).sum())(logits)
        g_e = jax.grad(lambda x: _naive_loss(x, tgt, w_lp, w_ent))(logits)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_e),
                                   rtol=2e-4, atol=2e-4)


class TestTargetIdContract:
    """Masked positions may carry any id: out-of-range targets clamp to
    [0, V) instead of silently returning −lse, on every path."""

    def _dirty(self, rng, t=32, v=64):
        ks = jax.random.split(rng, 2)
        logits = jax.random.normal(ks[0], (t, v))
        tgt = jax.random.randint(ks[1], (t,), 0, v)
        dirty = tgt.at[0].set(-1).at[1].set(v).at[2].set(v + 1234)
        clean = jnp.clip(dirty, 0, v - 1)
        return logits, dirty, clean

    def test_naive_helpers_clamp(self, rng):
        logits, dirty, clean = self._dirty(rng)
        np.testing.assert_array_equal(
            np.asarray(token_logprob_from_logits(logits, dirty)),
            np.asarray(token_logprob_from_logits(logits, clean)))
        lp_d, ent_d = token_logprob_and_entropy(logits, dirty)
        lp_c, _ = token_logprob_and_entropy(logits, clean)
        np.testing.assert_array_equal(np.asarray(lp_d), np.asarray(lp_c))
        assert np.isfinite(np.asarray(lp_d)).all()
        assert np.isfinite(np.asarray(ent_d)).all()

    def test_fused_paths_match_naive_on_dirty_ids(self, rng):
        logits, dirty, _ = self._dirty(rng)
        lp_e, ent_e = token_logprob_and_entropy(logits, dirty)
        for lp, ent in (
                chunked_logprob(logits, dirty, chunk=8),
                fused_logprob(logits, dirty, block_t=8, block_v=32,
                              interpret=True),
                ops.fused_token_logprob(logits, dirty)):
            np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_e),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(np.asarray(ent), np.asarray(ent_e),
                                       rtol=1e-5, atol=1e-5)

    def test_ref_oracle_clamps(self, rng):
        logits, dirty, clean = self._dirty(rng)
        lp_d, _ = ref.fused_logprob_ref(logits, dirty)
        lp_c, _ = ref.fused_logprob_ref(logits, clean)
        np.testing.assert_array_equal(np.asarray(lp_d), np.asarray(lp_c))

    def test_grads_finite_on_dirty_ids(self, rng):
        logits, dirty, _ = self._dirty(rng)
        g = jax.grad(lambda x: chunked_logprob(x, dirty, chunk=8)[0].sum()
                     )(logits)
        assert np.isfinite(np.asarray(g)).all()


class TestTrainingParity:
    """The full RL loss agrees between naive and fused learner paths —
    values and parameter gradients."""

    def test_rl_loss_fused_vs_naive(self, rng):
        from repro.config import ModelConfig, RLConfig, ATTN, MLP
        from repro.models import init_params
        from repro.training import rl_loss_fn
        tiny = ModelConfig(name="tiny", family="dense", num_layers=2,
                           d_model=48, num_heads=4, num_kv_heads=2,
                           d_ff=96, vocab_size=32, block_pattern=(ATTN,),
                           ffn_pattern=(MLP,), dtype="float32",
                           attn_impl="naive", remat=False, rope_theta=1e4)
        params = init_params(tiny, rng)
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        b, s = 8, 10
        batch = {
            "tokens": jax.random.randint(ks[0], (b, s), 0, 32),
            "mask": jnp.ones((b, s - 1)),
            "sampler_lp": -jnp.abs(jax.random.normal(ks[1], (b, s - 1))),
            "rewards": (jax.random.uniform(ks[2], (b,)) > 0.5).astype(
                jnp.float32),
        }
        rl = RLConfig(loss_type="gepo", group_size=4, beta_kl=0.005)
        outs = {}
        for impl in ("naive", "fused"):
            (loss, _), grads = jax.value_and_grad(
                lambda p, i=impl: rl_loss_fn(tiny, rl, p, batch,
                                             logprob_impl=i),
                has_aux=True)(params)
            outs[impl] = (float(loss), grads)
        assert outs["naive"][0] == pytest.approx(outs["fused"][0],
                                                 rel=1e-5)
        for a, b_ in zip(jax.tree_util.tree_leaves(outs["naive"][1]),
                         jax.tree_util.tree_leaves(outs["fused"][1])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-4, atol=1e-6)
