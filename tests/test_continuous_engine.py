"""Continuous-batching engine: static-engine parity (tokens + logps),
slot/page recycling, allocator invariants, and architecture fallback."""
import warnings

import jax
import numpy as np
import pytest

from repro.config import ModelConfig, RLConfig, ATTN, LOCAL, MAMBA, MLP, NONE
from repro.sampling import (ContinuousScheduler, GenRequest, PageAllocator,
                            generate, generate_continuous, pages_for)
from repro.sampling.scheduler import DONE
from repro.data.tasks import EOS
from repro.models import init_params

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=32,
                   block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)

GQA_LOCAL = ModelConfig(name="gqa-local", family="dense", num_layers=4,
                        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                        vocab_size=32, block_pattern=(ATTN, LOCAL),
                        ffn_pattern=(MLP,), sliding_window=6,
                        dtype="float32", attn_impl="naive", remat=False,
                        rope_theta=1e4)


def _rollouts(cfg, rng, *, max_new=10, batch=6, **cont_kwargs):
    params = init_params(cfg, rng)
    prompts = jax.random.randint(rng, (batch, 5), 3, cfg.vocab_size)
    rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=max_new)
    r_static = generate(cfg, rl, params, prompts, rng, vocab_limit=20)
    r_cont = generate_continuous(cfg, rl, params, prompts, rng,
                                 vocab_limit=20, **cont_kwargs)
    return r_static, r_cont


class TestParity:
    """Acceptance: continuous engine ≡ static engine (tokens + logps)
    under identical seeds — RNG folds per request, never per slot."""

    @pytest.mark.parametrize("slots,sync_every", [(2, 1), (3, 8), (6, 4)])
    def test_tokens_logps_exact(self, rng, slots, sync_every):
        r1, r2 = _rollouts(TINY, rng, num_slots=slots, page_size=4,
                           sync_every=sync_every)
        np.testing.assert_array_equal(np.asarray(r1["completions"]),
                                      np.asarray(r2["completions"]))
        np.testing.assert_array_equal(np.asarray(r1["comp_mask"]),
                                      np.asarray(r2["comp_mask"]))
        np.testing.assert_allclose(np.asarray(r1["sampler_lp"]),
                                   np.asarray(r2["sampler_lp"]),
                                   rtol=1e-5, atol=1e-5)

    def test_parity_with_chunked_prefill_and_gqa_local(self, rng):
        """Sliding-window + GQA layers, prompt split into 2-token prefill
        chunks interleaved with decode. Tokens must still match exactly;
        logps only to float-accumulation tolerance (chunked attention
        reorders the softmax reductions)."""
        r1, r2 = _rollouts(GQA_LOCAL, rng, num_slots=2, page_size=4,
                           prefill_chunk=2, sync_every=3)
        np.testing.assert_array_equal(np.asarray(r1["completions"]),
                                      np.asarray(r2["completions"]))
        np.testing.assert_allclose(np.asarray(r1["sampler_lp"]),
                                   np.asarray(r2["sampler_lp"]),
                                   rtol=1e-3, atol=1e-3)

    def test_padded_prefill_tail_never_touches_live_pages(self, rng):
        """Long prompt + tiny max_new + big prefill chunk: the padded
        tail of the last chunk runs past the slot's logical capacity.
        Those writes must be dropped (OOB-fill page index), not clamped
        onto a live page — parity with static proves no corruption."""
        params = init_params(TINY, rng)
        prompts = jax.random.randint(rng, (4, 30), 3, TINY.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=2)
        r1 = generate(TINY, rl, params, prompts, rng, vocab_limit=20)
        r2 = generate_continuous(TINY, rl, params, prompts, rng,
                                 vocab_limit=20, num_slots=2, page_size=16,
                                 prefill_chunk=20, sync_every=2)
        np.testing.assert_array_equal(np.asarray(r1["completions"]),
                                      np.asarray(r2["completions"]))
        np.testing.assert_allclose(np.asarray(r1["sampler_lp"]),
                                   np.asarray(r2["sampler_lp"]),
                                   rtol=1e-5, atol=1e-5)

    def test_rlconfig_engine_switch(self, rng):
        params = init_params(TINY, rng)
        prompts = jax.random.randint(rng, (4, 5), 3, TINY.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0,
                      max_new_tokens=6, engine="continuous")
        roll = generate(TINY, rl, params, prompts, rng, vocab_limit=20)
        assert "stats" in roll and roll["stats"]["completed"] == 4


class TestSlotRecycling:
    def test_mixed_lengths_recycle_slots(self, rng):
        """Short + long prompts through 2 slots: every request completes,
        freed slots get re-admitted, and the engine never decodes more
        slot-steps than the static scan would."""
        params = init_params(TINY, rng)
        prompts = jax.random.randint(rng, (8, 7), 3, TINY.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=8)
        roll = generate_continuous(
            TINY, rl, params, prompts, rng, vocab_limit=20, num_slots=2,
            page_size=4, sync_every=2, prompt_lens=[7, 2, 5, 7, 3, 2, 6, 4])
        stats = roll["stats"]
        assert stats["submitted"] == stats["admitted"] == 8
        assert stats["completed"] == 8
        assert stats["max_active"] == 2          # never exceeds the pool
        comp = np.asarray(roll["completions"])
        mask = np.asarray(roll["comp_mask"])
        assert comp.shape == (8, 8)
        # every row produced at least one token; masked tail is PAD
        assert (mask.sum(axis=1) >= 1).all()
        for row, mrow in zip(comp, mask):
            n = int(mrow.sum())
            assert (mrow[:n] == 1.0).all() and (mrow[n:] == 0.0).all()
            if EOS in row.tolist():
                assert row.tolist().index(EOS) == n - 1

    def test_scheduler_recycles_pages_without_double_free(self):
        """Direct scheduler lifecycle: 6 requests through 2 slots with a
        pool that only fits 2 in flight; pages drain back to the
        allocator exactly once each."""
        page_size, pages_per_slot = 4, 3
        alloc = PageAllocator(1 + 2 * pages_per_slot)
        sched = ContinuousScheduler(2, pages_per_slot, page_size, alloc)
        for rid in range(6):
            sched.submit(GenRequest(rid=rid,
                                    prompt=np.full(5, 3, np.int32),
                                    max_new=7))   # 12 tokens -> 3 pages
        in_flight = sched.admit()
        assert len(in_flight) == 2 and alloc.available == 0
        assert not sched.admit()                 # pool exhausted -> defer
        sched.finish(in_flight[0], "eos")
        assert alloc.available == pages_per_slot
        assert in_flight[0].state == DONE
        again = sched.admit()                    # freed slot re-admitted
        assert len(again) == 1 and again[0].rid == 2
        assert again[0].slot == in_flight[0].slot
        # drain everything; every page must come home exactly once
        while not sched.all_done:
            for r in list(sched.slots):
                if r is not None:
                    sched.finish(r, "length")
            sched.admit()
        assert sched.stats["completed"] == 6
        assert alloc.available == 2 * pages_per_slot and alloc.in_use == 0


class TestPageAllocator:
    def test_double_free_raises(self):
        alloc = PageAllocator(8)
        pages = alloc.alloc(3)
        alloc.free(pages)
        with pytest.raises(ValueError, match="double free"):
            alloc.free(pages)

    def test_scratch_page_reserved(self):
        alloc = PageAllocator(4)
        pages = alloc.alloc(3)
        assert 0 not in pages and alloc.alloc(1) is None

    def test_exhaustion_defers(self):
        alloc = PageAllocator(4)
        assert alloc.alloc(4) is None            # only 3 usable
        first = alloc.alloc(3)
        assert alloc.alloc(1) is None
        alloc.free(first[:1])
        assert alloc.alloc(1) == first[:1]

    def test_pages_for(self):
        assert pages_for(1, 4) == 1
        assert pages_for(4, 4) == 1
        assert pages_for(5, 4) == 2


class TestFallback:
    def test_ssm_falls_back_to_static(self, rng):
        ssm = ModelConfig(name="ssm", family="ssm", num_layers=2,
                          d_model=64, num_heads=0, num_kv_heads=0, d_ff=0,
                          vocab_size=32, block_pattern=(MAMBA,),
                          ffn_pattern=(NONE,), ssm_state=16, ssm_headdim=32,
                          dtype="float32", remat=False)
        params = init_params(ssm, rng)
        prompts = jax.random.randint(rng, (2, 5), 3, 32)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=4)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            roll = generate(ssm, rl, params, prompts, rng, vocab_limit=20,
                            engine="continuous")
        assert any("falling back" in str(x.message) for x in w)
        assert np.asarray(roll["completions"]).shape == (2, 4)

    def test_continuous_refuses_unsupported(self, rng):
        ssm = ModelConfig(name="ssm2", family="ssm", num_layers=2,
                          d_model=64, num_heads=0, num_kv_heads=0, d_ff=0,
                          vocab_size=32, block_pattern=(MAMBA,),
                          ffn_pattern=(NONE,), ssm_state=16, ssm_headdim=32,
                          dtype="float32", remat=False)
        rl = RLConfig(max_new_tokens=4)
        with pytest.raises(ValueError, match="attention-only"):
            generate_continuous(ssm, rl, init_params(ssm, rng),
                                np.full((2, 5), 3), jax.random.PRNGKey(0))

    def test_unknown_engine_raises(self, rng):
        rl = RLConfig(max_new_tokens=4)
        with pytest.raises(ValueError, match="unknown engine"):
            generate(TINY, rl, init_params(TINY, rng),
                     np.full((2, 5), 3), rng, engine="turbo")

    def test_static_rejects_continuous_kwargs(self, rng):
        rl = RLConfig(max_new_tokens=4)
        with pytest.raises(TypeError, match="num_slots"):
            generate(TINY, rl, init_params(TINY, rng),
                     np.full((2, 5), 3), rng, num_slots=4)
