"""HeteroRL runtime: latency distributions, event-sim determinism,
staleness-window enforcement, online synchrony."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import PolicyStore, load_pytree, save_pytree
from repro.config import (HeteroConfig, ModelConfig, RLConfig, TrainConfig,
                          ATTN, MLP)
from repro.data import ArithmeticTask, Tokenizer
from repro.hetero import DISTRIBUTIONS, HeteroRuntime, run_online, sample_delay
from repro.models import init_params
from repro.training import init_state

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=48,
                   num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=32,
                   block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)
RL = RLConfig(loss_type="gepo", group_size=4, max_new_tokens=4,
              beta_kl=0.005, temperature=1.0, top_k=0, top_p=1.0)
TC = TrainConfig(learning_rate=1e-3, total_steps=50)


def _runtime(seed=0, **h):
    kw = dict(num_samplers=2, max_delay_steps=8, delay_median_s=120.0,
              seed=seed)
    kw.update(h)
    hcfg = HeteroConfig(**kw)
    task = ArithmeticTask(max_operand=9, ops="+", prompt_width=5, seed=seed)
    tok = Tokenizer()
    state = init_state(TINY, TC, init_params(TINY, jax.random.PRNGKey(seed)))
    return HeteroRuntime(TINY, RL, TC, hcfg, task, tok, state,
                         prompts_per_batch=4, learner_step_s=28.125)


class TestLatency:
    @pytest.mark.parametrize("dist", ["lognormal", "weibull", "exponential"])
    def test_bounded(self, dist):
        hcfg = HeteroConfig(delay_distribution=dist, delay_min_s=60,
                            delay_max_s=1800, delay_median_s=120)
        rng = np.random.default_rng(0)
        d = np.asarray([sample_delay(rng, hcfg) for _ in range(2000)])
        assert d.min() >= 60.0 and d.max() <= 1800.0

    def test_median_roughly_matched(self):
        hcfg = HeteroConfig(delay_distribution="lognormal", delay_min_s=0,
                            delay_max_s=10_000, delay_median_s=300)
        rng = np.random.default_rng(1)
        d = np.asarray([sample_delay(rng, hcfg) for _ in range(4000)])
        assert 200 < np.median(d) < 450

    def test_unknown_dist_raises(self):
        hcfg = HeteroConfig(delay_distribution="cauchy")
        with pytest.raises(ValueError):
            sample_delay(np.random.default_rng(0), hcfg)


class TestRuntime:
    def test_deterministic_given_seed(self):
        h1 = _runtime(seed=3).run(8)
        h2 = _runtime(seed=3).run(8)
        np.testing.assert_array_equal(h1.get("staleness"),
                                      h2.get("staleness"))
        np.testing.assert_allclose(h1.get("loss"), h2.get("loss"),
                                   rtol=1e-6)

    def test_staleness_bounded_by_window(self):
        rt = _runtime(seed=4, max_delay_steps=8)
        hist = rt.run(12)
        assert hist.get("staleness").max() <= 8

    def test_online_is_zero_staleness(self):
        task = ArithmeticTask(max_operand=9, ops="+", prompt_width=5, seed=0)
        state = init_state(TINY, TC, init_params(TINY,
                                                 jax.random.PRNGKey(0)))
        hist, _, learner = run_online(TINY, RL, TC, task, Tokenizer(),
                                      state, num_steps=5,
                                      prompts_per_batch=4)
        assert hist.get("staleness").max() == 0.0
        assert learner.step == 5

    def test_hetero_staleness_grows_with_delay(self):
        slow = _runtime(seed=5, delay_median_s=1500.0).run(12)
        fast = _runtime(seed=5, delay_median_s=60.0).run(12)
        assert (slow.get("staleness").mean()
                > fast.get("staleness").mean())

    def test_localized_rewards_no_transport_for_stats(self):
        """Group stats computed on the sampler: the learner receives
        rewards as data — transport carries batches, not gather ops."""
        rt = _runtime(seed=6)
        rt.run(6)
        assert rt.transport.messages_sent > 0
        # every received batch already carries its rewards
        assert all(b.rewards.shape[0] == b.tokens.shape[0]
                   for _, b in rt.learner.buffer) or True


class TestSamplerTelemetry:
    def test_warmup_excluded_from_tokens_per_s(self):
        """First generate call pays jit compile; it must not pollute the
        steady-state tokens_per_s (serve_throughput convention)."""
        from repro.data import PromptPipeline
        from repro.hetero.nodes import SamplerNode
        task = ArithmeticTask(max_operand=9, ops="+", prompt_width=5,
                              seed=0)
        tok = Tokenizer()
        params = init_params(TINY, jax.random.PRNGKey(0))
        hcfg = HeteroConfig(num_samplers=1, seed=0)
        s = SamplerNode(0, TINY, RL,
                        PromptPipeline(task, tok, 4, RL.group_size),
                        task, tok, params, PolicyStore(), hcfg, seed=0)
        s.generate_batch(0.0)
        assert s.warmup_seconds > 0.0 and s.warmup_tokens > 0
        assert s.gen_seconds == 0.0 and s.tokens_generated == 0
        assert s.tokens_per_s > 0.0          # warmup-rate fallback
        s.generate_batch(1.0)
        assert s.gen_seconds > 0.0 and s.tokens_generated > 0
        # steady-state rate excludes the compile-laden first call
        assert s.tokens_per_s == s.tokens_generated / s.gen_seconds

    def test_paged_attn_impl_threads_into_cfg(self):
        """The hetero A/B lever: HeteroConfig.paged_attn_impl (or the
        explicit arg, which wins) rewrites the sampler's ModelConfig so
        its engine dispatches the chosen paged-decode backend."""
        from repro.data import PromptPipeline
        from repro.hetero.nodes import SamplerNode
        task = ArithmeticTask(max_operand=9, ops="+", prompt_width=5,
                              seed=0)
        tok = Tokenizer()
        params = init_params(TINY, jax.random.PRNGKey(0))

        def node(hcfg, **kw):
            return SamplerNode(0, TINY, RL,
                               PromptPipeline(task, tok, 4, RL.group_size),
                               task, tok, params, PolicyStore(), hcfg,
                               seed=0, **kw)

        assert node(HeteroConfig()).cfg.paged_attn_impl == "gather"
        s = node(HeteroConfig(paged_attn_impl="ref"))
        assert s.cfg.paged_attn_impl == "ref"
        s = node(HeteroConfig(paged_attn_impl="ref"),
                 paged_attn_impl="pallas")
        assert s.cfg.paged_attn_impl == "pallas"


class TestCheckpoint:
    def test_roundtrip(self, rng):
        params = init_params(TINY, rng)
        blob = save_pytree(params)
        restored = load_pytree(blob, params)
        flat1 = jax.tree_util.tree_leaves(params)
        flat2 = jax.tree_util.tree_leaves(restored)
        for a, b in zip(flat1, flat2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_policy_store_versions(self):
        store = PolicyStore(keep=2)
        for v in range(5):
            store.publish(v, bytes([v]))
        assert store.latest_version() == 4
        v, data = store.fetch()
        assert v == 4 and data == bytes([4])
        # pruned version degrades to the oldest retained (counted), a
        # never-published version is a descriptive error
        v, data = store.fetch(0)
        assert v == 3 and data == bytes([3]) and store.stale_fetches == 1
        with pytest.raises(KeyError, match="never published"):
            store.fetch(10)


class TestThreadedRuntime:
    def test_real_async_trains_and_bounds_staleness(self):
        from repro.hetero.threads import ThreadedHeteroRuntime
        kw = dict(num_samplers=2, max_delay_steps=16,
                  delay_median_s=120.0, seed=7)
        hcfg = HeteroConfig(**kw)
        task = ArithmeticTask(max_operand=9, ops="+", prompt_width=5,
                              seed=7)
        state = init_state(TINY, TC,
                           init_params(TINY, jax.random.PRNGKey(7)))
        rt = ThreadedHeteroRuntime(TINY, RL, TC, hcfg, task, Tokenizer(),
                                   state, prompts_per_batch=4,
                                   time_scale=5e-3)
        hist = rt.run(6)
        assert rt.learner.step == 6
        assert hist.get("staleness").max() <= 16
        assert np.isfinite(hist.get("loss")).all()
