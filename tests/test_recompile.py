"""Recompile sentinel: the dynamic half of RA002.

Locks in PR-5's "O(log) executables" claim: the continuous engine's
pow2-bucketed block-table narrowing means a mixed-length workload
compiles at most `phases x pow2_bucket_count(pages_per_slot)` jitted
chunk executables (plus a bounded set of eager scatter/convert ops), and
a *steady* run — same shapes again — compiles exactly nothing.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.analysis.sentinel import (RecompileSentinel, executable_bound,
                                     pow2_bucket_count,
                                     prefill_executable_bound,
                                     spec_verify_executable_bound)
from repro.config import ATTN, MLP, ModelConfig, RLConfig
from repro.models import init_params
from repro.sampling import ContinuousEngine
from repro.serving.api import Request, SamplingParams

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=32,
                   block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)

NUM_SLOTS = 4
PREFILL_CHUNK = 4
# (prompt_len, max_new) mix spanning 1..5 pages of a page_size=4 pool —
# hits several pow2 width buckets in both prefill and decode
WORKLOAD = [(3, 4), (7, 8), (12, 6), (5, 8), (20, 8), (9, 3), (15, 8),
            (4, 8)]


def _engine(cfg=TINY):
    rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ContinuousEngine(cfg, params, rl=rl, max_total_tokens=32,
                           num_slots=NUM_SLOTS, page_size=4, sync_every=2,
                           prefill_chunk=PREFILL_CHUNK, vocab_limit=20,
                           prefix_cache=False, key=jax.random.PRNGKey(1))
    return eng, rl


def _epoch(eng, rl, rid0):
    rng = np.random.default_rng(0)
    reqs = [Request(rid=rid0 + i, prompt=rng.integers(3, 20, size=plen),
                    params=SamplingParams.from_rl(rl, max_new=mnew))
            for i, (plen, mnew) in enumerate(WORKLOAD)]
    return eng.generate(reqs, key=jax.random.PRNGKey(2))


class TestPow2BucketCount:
    def test_matches_live_width_enumeration(self):
        from repro.sampling.continuous import _live_width
        for cap in (1, 2, 3, 7, 8, 16, 100):
            widths = {_live_width(n, cap) for n in range(1, cap + 1)}
            assert pow2_bucket_count(cap) == len(widths)

    def test_log_growth(self):
        # the whole point: buckets grow like log2(pool), not pool
        assert pow2_bucket_count(8) == 4
        assert pow2_bucket_count(1024) == 11
        assert executable_bound(1024, phases=2, slack=0) == 22

    def test_prefill_bound(self):
        # prefill executables key on (chunk width, width bucket): the
        # configured chunk plus shorter final tails × pow2 table widths
        assert prefill_executable_bound(4, 8) == 4 * 4
        assert prefill_executable_bound(None, 1024) == 11
        # chunked prefill stays O(chunk · log pool), never O(pool)
        assert prefill_executable_bound(8, 1024) < 1024


class TestEngineExecutableBound:
    def test_mixed_lengths_bucketed_then_steady_zero(self):
        eng, rl = _engine()
        buckets = pow2_bucket_count(eng.pages_per_slot)
        # cold bound: decode-chunk executables over the width buckets,
        # prefill-chunk executables over (chunk width × width bucket)
        # per the analytic sentinel bound, plus the eager
        # per-(slot, chunk-offset) last-logits scatter and a handful of
        # one-off convert/fill ops
        eager_slack = NUM_SLOTS * PREFILL_CHUNK + 8
        bound = (buckets
                 + prefill_executable_bound(PREFILL_CHUNK,
                                            eng.pages_per_slot)
                 + eager_slack)
        with RecompileSentinel("cold") as cold:
            r1 = _epoch(eng, rl, rid0=0)
        assert cold.compiles > 0          # the sentinel actually counts
        cold.assert_bound(bound, "cold mixed-length epoch")

        # steady state: identical shape distribution, different rids and
        # page assignments — every executable must be a cache hit
        with RecompileSentinel("steady") as steady:
            r2 = _epoch(eng, rl, rid0=100)
        steady.assert_bound(0, "steady-state epoch")

        # both epochs did real work (rid seeds the RNG stream, so token
        # counts differ — but every request must have finished)
        assert len(r1) == len(WORKLOAD) and len(r2) == len(WORKLOAD)
        assert all(len(r.tokens) >= 1 for r in r1 + r2)

    def test_ref_backend_bucketed_then_steady_zero(self):
        # the paged-prefill/decode ref kernels (no dense gather) must hit
        # the same executable budget: widths still bucket through
        # _live_width, and a steady second epoch compiles nothing
        cfg = dataclasses.replace(TINY, paged_attn_impl="ref")
        eng, rl = _engine(cfg)
        bound = (pow2_bucket_count(eng.pages_per_slot)
                 + prefill_executable_bound(PREFILL_CHUNK,
                                            eng.pages_per_slot)
                 + NUM_SLOTS * PREFILL_CHUNK + 8)
        with RecompileSentinel("ref-cold") as cold:
            r1 = _epoch(eng, rl, rid0=0)
        assert cold.compiles > 0
        cold.assert_bound(bound, "ref-impl cold epoch")
        with RecompileSentinel("ref-steady") as steady:
            r2 = _epoch(eng, rl, rid0=100)
        steady.assert_bound(0, "ref-impl steady epoch")
        assert len(r1) == len(WORKLOAD) and len(r2) == len(WORKLOAD)

    def test_spec_varying_acceptance_steady_zero(self):
        """Speculative decoding under the same budget discipline: the
        verify executable keys on (pow2 verify width, pow2 table width)
        only, so per-round acceptance lengths — which vary freely within
        an epoch — trigger zero new compiles once the width buckets are
        warm. Greedy profile (top_k=1) makes both epochs emit identical
        token streams, hence identical width sequences."""
        rl = RLConfig(temperature=1.0, top_k=1, top_p=1.0,
                      max_new_tokens=8)
        params = init_params(TINY, jax.random.PRNGKey(0))
        eng = ContinuousEngine(TINY, params, rl=rl, max_total_tokens=32,
                               num_slots=NUM_SLOTS, page_size=4,
                               sync_every=2, prefill_chunk=PREFILL_CHUNK,
                               vocab_limit=20, prefix_cache=False,
                               spec_k=4, key=jax.random.PRNGKey(1))
        bound = (spec_verify_executable_bound(4, eng.pages_per_slot)
                 + prefill_executable_bound(PREFILL_CHUNK,
                                            eng.pages_per_slot)
                 + NUM_SLOTS * PREFILL_CHUNK + 8)
        with RecompileSentinel("spec-cold") as cold:
            r1 = _epoch(eng, rl, rid0=0)
        assert cold.compiles > 0
        cold.assert_bound(bound, "spec cold epoch")
        with RecompileSentinel("spec-steady") as steady:
            r2 = _epoch(eng, rl, rid0=100)
        steady.assert_bound(0, "spec steady-state epoch")
        st = eng.stats()
        assert st["spec_rounds"] > 0 and st["drafted_tokens_total"] > 0
        assert len(r1) == len(WORKLOAD) and len(r2) == len(WORKLOAD)

    def test_assert_bound_raises(self):
        s = RecompileSentinel("x")
        s.compiles = 3
        with pytest.raises(AssertionError, match="3 XLA compiles"):
            s.assert_bound(2)
