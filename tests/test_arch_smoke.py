"""Per-architecture smoke tests: instantiate the reduced same-family
variant of each assigned architecture, run one forward and one RL train
step on CPU, assert output shapes and finiteness. Decode-vs-forward
consistency for every family with a decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RLConfig, TrainConfig
from repro.configs import ARCHS, smoke
from repro.models import (decode_step, encode, forward, init_cache,
                          init_params)
from repro.training import init_state, rl_loss_fn, train_step

ARCH_IDS = sorted(ARCHS)


def _memory_for(cfg, params, b, key):
    if cfg.is_encdec:
        frames = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model),
                                   jnp.float32).astype(cfg.dtype)
        return encode(cfg, params, frames)
    if cfg.memory_seq:
        return 0.02 * jax.random.normal(
            key, (b, cfg.memory_seq, cfg.d_model)).astype(cfg.dtype)
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = smoke(arch)
    params = init_params(cfg, rng)
    b, s = 2, 16
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    memory = _memory_for(cfg, params, b, rng)
    logits, _, aux = forward(cfg, params, toks, memory=memory)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"
    if cfg.num_experts:
        assert "moe_load_balance" in aux


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_rl_train_step(arch, rng):
    cfg = smoke(arch)
    params = init_params(cfg, rng)
    rl = RLConfig(loss_type="gepo", group_size=4, beta_kl=0.005)
    tc = TrainConfig(learning_rate=1e-3, total_steps=10)
    state = init_state(cfg, tc, params)
    b, s = 8, 12
    ks = jax.random.split(rng, 3)
    tokens = jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)
    batch = {
        "tokens": tokens,
        "mask": jnp.ones((b, s - 1)),
        "sampler_lp": -jnp.abs(jax.random.normal(ks[1], (b, s - 1))),
        "rewards": (jax.random.uniform(ks[2], (b,)) > 0.5).astype(
            jnp.float32),
    }
    memory = _memory_for(cfg, params, b, rng)
    new_state, metrics = train_step(cfg, rl, tc, state, batch,
                                    memory=memory)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b_: float(jnp.abs(a.astype(jnp.float32)
                                    - b_.astype(jnp.float32)).max()),
        state.params, new_state.params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch, rng):
    cfg = smoke(arch)
    params = init_params(cfg, rng)
    b, s = 2, 8
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    memory = _memory_for(cfg, params, b, rng)
    full, _, _ = forward(cfg, params, toks, memory=memory)
    cache = init_cache(cfg, params, b, s, memory=memory)
    outs = []
    for t in range(s):
        lg, cache = decode_step(cfg, params, cache, toks[:, t],
                                jnp.int32(t), memory=memory)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    # MoE capacity effects allow a slightly looser tolerance
    tol = 2e-2 if cfg.num_experts else 1e-3
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_continues(arch, rng):
    """Prefill fills the cache; the next decode step must match the
    forward logits of the extended sequence."""
    cfg = smoke(arch)
    params = init_params(cfg, rng)
    b, s = 2, 8
    toks = jax.random.randint(rng, (b, s + 1), 0, cfg.vocab_size)
    memory = _memory_for(cfg, params, b, rng)
    cache = init_cache(cfg, params, b, s + 1, memory=memory)
    _, cache, _ = forward(cfg, params, toks[:, :s], cache=cache,
                          memory=memory)
    lg, _ = decode_step(cfg, params, cache, toks[:, s], jnp.int32(s),
                        memory=memory)
    full, _, _ = forward(cfg, params, toks, memory=memory)
    tol = 2e-2 if cfg.num_experts else 1e-3
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(lg),
                               atol=tol, rtol=tol)
