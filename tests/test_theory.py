"""Property-based tests (hypothesis) for Theorems 1–3 of App. A and the
Fig. 2 variance claims."""
import numpy as np
import pytest
from _hyp import given, settings, st   # hypothesis, or skip-shim without it

from repro.core import theory

# random discrete distributions over n outcomes
def dist(n, min_value=1e-3):
    return st.lists(st.floats(min_value=min_value, max_value=1.0),
                    min_size=n, max_size=n).map(
        lambda xs: np.asarray(xs) / np.sum(xs))


@st.composite
def pq_pair(draw, n_min=2, n_max=16, min_value=1e-3):
    n = draw(st.integers(n_min, n_max))
    p = draw(dist(n, min_value))
    q = draw(dist(n, min_value))
    return p, q


class TestTheorem1:
    @given(pq_pair())
    @settings(max_examples=200, deadline=None)
    def test_variance_gap_lower_bound(self, pq):
        """Δ = Var_std − Var_new ≥ exp(KL(p‖q)) − (n²+1)  (Theorem 1)."""
        p, q = pq
        delta, exp_kl, c = theory.theorem1_terms(p, q)
        assert delta >= exp_kl - c - 1e-6

    @given(pq_pair())
    @settings(max_examples=200, deadline=None)
    def test_high_kl_regime_variance_reduction(self, pq):
        """When KL > log C the new estimator strictly wins."""
        p, q = pq
        delta, exp_kl, c = theory.theorem1_terms(p, q)
        if exp_kl > c:
            assert delta > 0

    # well-conditioned q only: the MC estimate of Var[p/q] itself has
    # variance ~ Σp⁴/q³, which explodes for near-zero q masses.
    @given(pq_pair(n_max=8, min_value=0.15))
    @settings(max_examples=50, deadline=None)
    def test_var_std_formula_vs_monte_carlo(self, pq):
        p, q = pq
        rng = np.random.default_rng(0)
        idx = rng.choice(len(p), size=400_000, p=q)
        w = p[idx] / q[idx]
        assert np.isclose(w.var(), theory.var_std(p, q),
                          rtol=0.25, atol=0.05)

    @given(pq_pair(n_max=8, min_value=0.15))
    @settings(max_examples=50, deadline=None)
    def test_var_new_formula_vs_monte_carlo(self, pq):
        p, q = pq
        rng = np.random.default_rng(1)
        idx = rng.choice(len(p), size=400_000, p=q)
        w = p[idx] / np.sum(q * q)
        assert np.isclose(w.var(), theory.var_new(p, q),
                          rtol=0.25, atol=0.05)


class TestTheorem2:
    @given(pq_pair(), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=200, deadline=None)
    def test_bias_bound(self, pq, seed):
        """Bias(GEPO) < ‖p‖₂ / ‖q‖₂ for centered bounded advantages."""
        p, q = pq
        a = np.random.default_rng(seed).normal(size=len(p))
        assert theory.bias_gepo(p, q, a) <= theory.bias_bound(p, q) + 1e-9


class TestFig2:
    def test_bernoulli_high_kl_region(self):
        """p~Bern(0.9), q~Bern(0.1): strongly divergent — GEIW wins."""
        v_std, v_new = theory.bernoulli_vars(0.9, 0.1)
        assert v_new < v_std

    def test_bernoulli_low_kl_region_can_lose(self):
        """The paper admits a small green region where GEIW is worse."""
        v_std, v_new = theory.bernoulli_vars(0.5, 0.5)
        assert v_std == pytest.approx(0.0, abs=1e-12)
        assert v_new >= 0.0

    def test_gaussian_variance_reduction_grows_with_kl(self):
        gaps = []
        for delta_mu in (1.0, 2.0, 3.0):
            v_std, v_new, kl = theory.gaussian_vars(0.0, delta_mu)
            gaps.append(v_std - v_new)
        assert gaps[0] < gaps[1] < gaps[2]
        assert gaps[2] > 0

    def test_chi2_kl_inequality(self):
        """KL ≤ log(1 + χ²) (eq. 22) on random distributions."""
        rng = np.random.default_rng(0)
        for _ in range(100):
            n = rng.integers(2, 30)
            p = rng.dirichlet(np.ones(n))
            q = rng.dirichlet(np.ones(n))
            assert theory.kl(p, q) <= np.log1p(theory.chi2(p, q)) + 1e-9
