"""Shard-streamed weight transport (repro.transport): chunk codec
byte-exactness (bf16/exotic dtypes included), delta-sync determinism,
resume-after-drop, payload-aware delays, PolicyStore chunk-index GC +
bounded bookkeeping, and (in a forced-device subprocess) elastic re-fit
parity of a sampler on a smaller plan against the whole-blob path."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import PolicyStore, load_pytree, save_pytree
from repro.checkpoint.store import path_key
from repro.config import ATTN, MLP, HeteroConfig, ModelConfig
from repro.hetero.latency import sample_delay, sync_delay_s
from repro.models import init_params
from repro.parallel import local_plan
from repro.transport import (ChunkSubscriber, Manifest, SimulatedLink,
                             SyncInterrupted, assemble_leaf, chunk_host_leaf,
                             publish_params)

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=48,
                   num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=32,
                   block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _leaf_roundtrip(arr):
    sharding = local_plan("serve").replicated
    parts = chunk_host_leaf(arr, sharding)
    back = assemble_leaf(str(arr.dtype), tuple(arr.shape), parts)
    host = np.asarray(arr)
    assert back.dtype == host.dtype
    assert back.tobytes() == np.ascontiguousarray(host).tobytes()
    return parts


class TestChunkCodec:
    @pytest.mark.parametrize("dtype", ["float32", "int32", "bfloat16",
                                       "float16"])
    def test_roundtrip_byte_exact(self, dtype):
        x = (jnp.arange(24, dtype=jnp.float32) * 0.37 - 3).reshape(4, 6)
        arr = x.astype(dtype)
        parts = _leaf_roundtrip(arr)
        assert sum(r.nbytes for r, _ in parts) == np.asarray(arr).nbytes

    def test_roundtrip_exotic_float8(self):
        if not hasattr(jnp, "float8_e4m3fn"):
            pytest.skip("float8 not available in this jax")
        arr = jnp.arange(16, dtype=jnp.float32).astype(jnp.float8_e4m3fn)
        _leaf_roundtrip(arr)

    def test_scalar_and_odd_shapes(self):
        _leaf_roundtrip(jnp.float32(2.5))
        _leaf_roundtrip(jnp.arange(7, dtype=jnp.bfloat16))

    def test_content_hash_deterministic(self):
        sharding = local_plan("serve").replicated
        a = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
        h1 = [r.hash for r, _ in chunk_host_leaf(a, sharding)]
        h2 = [r.hash for r, _ in chunk_host_leaf(jnp.array(a), sharding)]
        assert h1 == h2
        h3 = [r.hash for r, _ in chunk_host_leaf(a + 1, sharding)]
        assert h1 != h3


class TestDeltaSync:
    def _publish_sync(self):
        params = init_params(TINY, jax.random.PRNGKey(0))
        store = PolicyStore()
        plan = local_plan("train")
        st0 = publish_params(store, 0, plan, TINY, params)
        link = SimulatedLink()
        sub = ChunkSubscriber(store, link)
        return params, store, plan, st0, link, sub

    def test_same_params_move_zero_chunks(self):
        params, store, plan, st0, link, sub = self._publish_sync()
        _, tree0, s0 = sub.sync(params, cfg=TINY, plan=local_plan("serve"))
        # cold: full fetch of every distinct chunk (identical-content
        # leaves dedup even within one publish, hence bytes_new)
        assert s0.chunk_bytes == st0.bytes_new
        st1 = publish_params(store, 1, plan, TINY, params)
        assert st1.bytes_new == 0 and st1.chunks_new == 0
        v, tree1, s1 = sub.sync(params, cfg=TINY, plan=local_plan("serve"))
        assert v == 1
        assert s1.chunk_bytes == 0 and s1.chunks_fetched == 0
        assert s1.dedup_ratio == 1.0
        assert s1.bytes_on_wire == s1.manifest_bytes      # manifest only
        for a, b in zip(jax.tree_util.tree_leaves(tree0),
                        jax.tree_util.tree_leaves(tree1)):
            np.testing.assert_array_equal(a, b)

    def test_partial_change_moves_only_changed_chunks(self):
        params, store, plan, st0, link, sub = self._publish_sync()
        sub.sync(params, cfg=TINY, plan=local_plan("serve"))

        def bump(path, leaf):
            return leaf + 1.0 if "attn" in path_key(path) else leaf

        p2 = jax.tree_util.tree_map_with_path(bump, params)
        st2 = publish_params(store, 1, plan, TINY, p2)
        assert 0 < st2.bytes_new < st2.payload_bytes
        _, tree, s2 = sub.sync(p2, cfg=TINY, plan=local_plan("serve"))
        assert s2.chunk_bytes == st2.bytes_new            # exactly the delta
        # restore byte-identical to the legacy whole-blob path
        legacy = load_pytree(save_pytree(p2), p2)
        for a, b in zip(jax.tree_util.tree_leaves(legacy),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_resume_after_drop(self):
        params, store, plan, st0, _, _ = self._publish_sync()
        link = SimulatedLink(drop_after_bytes=st0.bytes_new // 3)
        sub = ChunkSubscriber(store, link)
        with pytest.raises(SyncInterrupted, match="resumes"):
            sub.sync(params, cfg=TINY, plan=local_plan("serve"))
        partial = link.bytes_on_wire
        assert 0 < partial < st0.bytes_new
        v, tree, ss = sub.sync(params, cfg=TINY, plan=local_plan("serve"))
        assert ss.bytes_resumed > 0
        # no chunk byte was paid twice: total wire = one copy of every
        # distinct chunk plus one manifest per attempt
        assert link.bytes_on_wire == (st0.bytes_new
                                      + 2 * ss.manifest_bytes)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestPayloadAwareDelay:
    def test_inf_bandwidth_bit_compatible(self):
        hcfg = HeteroConfig(delay_distribution="lognormal",
                            delay_median_s=120.0)
        d1 = [sample_delay(np.random.default_rng(3), hcfg)
              for _ in range(16)]
        d2 = [sync_delay_s(np.random.default_rng(3), hcfg, 10**9)
              for _ in range(16)]
        # same rng draw, no payload term at bandwidth inf
        assert d1 == d2

    def test_payload_adds_serialization_time(self):
        hcfg = HeteroConfig(delay_distribution="constant",
                            delay_median_s=60.0, bandwidth_mbps=100.0)
        rng = np.random.default_rng(0)
        base = sync_delay_s(rng, hcfg, 0)
        loaded = sync_delay_s(rng, hcfg, 10**8)       # 100 MB at 100 Mbps
        assert base == 60.0
        assert loaded == pytest.approx(60.0 + 8.0)


class TestPolicyStoreBookkeeping:
    def test_bytes_published_counts_net_new_only(self):
        store = PolicyStore()
        store.publish(0, b"abcd")
        store.publish(0, b"abcd")                 # re-publish: no growth
        assert store.bytes_published == 4
        store.publish(0, b"abcdef")               # replaced: delta only
        assert store.bytes_published == 6

    def test_published_set_bounded_with_degrade_below_horizon(self):
        store = PolicyStore(keep=2, track=8)
        for v in range(30):
            store.publish(v, bytes([v]))
        assert len(store._published) <= 8
        v, _ = store.fetch(0)                     # below horizon: degrade
        assert v == 28 and store.stale_fetches == 1
        with pytest.raises(KeyError, match="never published"):
            store.fetch(40)                       # beyond latest: error

    def test_chunk_gc_on_manifest_prune(self):
        from repro.transport import ChunkRef, content_hash
        from repro.transport.manifest import LeafManifest
        store = PolicyStore(keep=2)
        for v in range(6):
            data = bytes([v]) * 8
            h = content_hash(data)
            store.put_chunk(h, data)
            m = Manifest(version=v, leaves=(LeafManifest(
                key="w", dtype="uint8", shape=(8,),
                chunks=(ChunkRef(hash=h, nbytes=8, start=(0,),
                                 shape=(8,)),)),))
            store.publish_manifest(v, m.to_json(), m.hashes())
        # only the chunks of the 2 retained manifests survive
        assert store.num_chunks == 2
        assert store.chunks_gced == 4

    def test_publish_manifest_requires_chunks(self):
        store = PolicyStore()
        m = Manifest(version=0, leaves=())
        store.publish_manifest(0, m.to_json(), m.hashes())   # empty ok
        with pytest.raises(KeyError, match="put_chunk first"):
            store.publish_manifest(1, b"{}", ["deadbeef"])


class TestSamplerRefit:
    def test_refit_with_empty_store_keeps_plan_and_params_consistent(self):
        """sync(plan=...) before anything is published must still re-place
        the live params onto the new plan — plan and placement may never
        disagree."""
        from repro.config import RLConfig
        from repro.data import ArithmeticTask, PromptPipeline, Tokenizer
        from repro.hetero.nodes import SamplerNode
        params = init_params(TINY, jax.random.PRNGKey(0))
        task = ArithmeticTask(max_operand=9, ops="+", prompt_width=5,
                              seed=0)
        tok = Tokenizer()
        s = SamplerNode(0, TINY, RLConfig(group_size=4),
                        PromptPipeline(task, tok, 4, 4), task, tok,
                        params, PolicyStore(), HeteroConfig(num_samplers=1),
                        seed=0)
        new_plan = local_plan("long")
        assert s.sync(plan=new_plan) == 0          # nothing to fetch
        assert s.plan is new_plan
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(s.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, numpy as np
    from repro.checkpoint import PolicyStore, load_pytree, save_pytree
    from repro.config import (ATTN, MLP, HeteroConfig, ModelConfig,
                              RLConfig, TrainConfig)
    from repro.models import init_params
    from repro.parallel import ExecutionPlan, make_debug_mesh
    from repro.transport import ChunkSubscriber, Manifest, publish_params

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2,
                      d_model=48, num_heads=4, num_kv_heads=2, d_ff=96,
                      vocab_size=32, block_pattern=(ATTN,),
                      ffn_pattern=(MLP,), dtype="float32",
                      attn_impl="naive", remat=False, rope_theta=1e4)
    learner_plan = ExecutionPlan(mesh=make_debug_mesh(2, 2), mode="train")
    plan_12 = ExecutionPlan(mesh=jax.make_mesh((1, 2), ("data", "model")),
                            mode="serve")
    plan_21 = ExecutionPlan(mesh=jax.make_mesh((2, 1), ("data", "model")),
                            mode="serve")

    host = init_params(cfg, jax.random.PRNGKey(0))
    placed = learner_plan.device_put_params(cfg, host)
    store = PolicyStore()
    stats = publish_params(store, 0, learner_plan, cfg, placed)
    v, blob = store.fetch()
    manifest = Manifest.from_json(blob)

    legacy = load_pytree(save_pytree(
        learner_plan.host_gather(placed)), host)

    def check_parity(tree):
        for a, b in zip(jax.tree_util.tree_leaves(legacy),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    sub = ChunkSubscriber(store)
    # sampler synced on the (smaller) 1x2 plan == whole-blob fetch
    v, tree, ss = sub.sync(host, cfg=cfg, plan=plan_12)
    check_parity(tree)
    placed_12 = plan_12.device_put_params(cfg, tree)
    check_parity(placed_12)
    # elastic re-fit: the cached version lands on *changed* plans
    for refit_plan in (plan_21, None):
        before = sub.chunks_fetched
        v2, tree2, ss2 = sub.sync(host, cfg=cfg,
                                  plan=refit_plan) if refit_plan \\
            else sub.sync(host, cfg=cfg)
        assert sub.chunks_fetched == before, "re-fit must not refetch"
        check_parity(tree2)
        if refit_plan is not None:
            check_parity(refit_plan.device_put_params(cfg, tree2))
    # plan-scoped: one host of the sampler mesh needs a strict subset
    need = sub.needed_refs(manifest, plan=plan_12, cfg=cfg,
                           devices=[plan_12.mesh.devices[0, 0]])
    scoped = {r.hash for _, refs in need for r in refs}
    full = manifest.hashes()
    assert scoped < full, (len(scoped), len(full))
    assert sub.chunks_fetched < manifest.num_entries
    print(json.dumps({"ok": True, "chunks": manifest.num_chunks,
                      "entries": manifest.num_entries,
                      "scoped": len(scoped), "hashes": len(full),
                      "egress": stats.max_host_egress,
                      "payload": stats.payload_bytes}))
""")


class TestElasticRefitParity:
    def test_refit_parity_on_debug_mesh(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        env.pop("XLA_FLAGS", None)
        out = subprocess.run([sys.executable, "-c", SUBPROC],
                             capture_output=True, text=True, env=env,
                             timeout=420)
        assert out.returncode == 0, out.stderr[-4000:]
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        assert rec["ok"]
        # per-shard publish cut the worst host upload below a full copy
        assert rec["egress"] < rec["payload"]
        assert rec["scoped"] < rec["hashes"] <= rec["chunks"] \
            < rec["entries"]
