"""repro.analysis: rule firing, suppression, baseline workflow, and the
repo-clean invariant (`python -m repro.analysis src tests benchmarks`
must pass with the checked-in baseline), plus the dynamic twin of RA002:
configs that ride `static_argnames` must actually hash.
"""
import dataclasses
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.core import (DEFAULT_EXCLUDES, SourceFile,
                                 apply_baseline, collect_files,
                                 load_baseline, run_analysis, run_rules,
                                 save_baseline)
from repro.analysis.rules import RULE_DOCS, default_rules

REPO = Path(__file__).resolve().parent.parent
FIXTURE = (REPO / "src" / "repro" / "analysis" / "_fixtures"
           / "known_bad.py")


def _analyze_source(src: str, name: str = "mod.py"):
    f = SourceFile(Path(name), name, textwrap.dedent(src))
    return run_rules([f])


class TestRuleFiring:
    def test_all_rules_fire_on_fixture(self):
        files = collect_files([FIXTURE], root=FIXTURE.parent, excludes=())
        findings = run_rules(files)
        assert {f.rule for f in findings} == set(RULE_DOCS)

    def test_fixture_excluded_from_normal_runs(self):
        files = collect_files([FIXTURE.parent.parent], root=REPO)
        assert all("_fixtures" not in f.rel for f in files)
        assert "_fixtures" in DEFAULT_EXCLUDES

    def test_ra001_rebind_is_clean(self):
        findings = _analyze_source("""
            import functools, jax

            @functools.partial(jax.jit, donate_argnums=(0,))
            def step(state, batch):
                return state

            def ok(state, batch):
                state = step(state, batch)      # rebind: donation is fine
                return state["params"]
        """)
        assert [f for f in findings if f.rule == "RA001"] == []

    def test_ra001_read_after_donation_fires(self):
        findings = _analyze_source("""
            import functools, jax

            @functools.partial(jax.jit, donate_argnums=(0,))
            def step(state, batch):
                return state

            def bad(state, batch):
                new = step(state, batch)
                return state["params"], new     # read of donated buffer
        """)
        assert [f.rule for f in findings] == ["RA001"]

    def test_ra002_frozen_dataclass_static_is_clean(self):
        findings = _analyze_source("""
            import dataclasses, functools, jax

            @dataclasses.dataclass(frozen=True)
            class Cfg:
                n: int = 1

            @functools.partial(jax.jit, static_argnames=("cfg",))
            def fwd(cfg: Cfg, x):
                return x
        """)
        assert findings == []

    def test_ra002_plain_dataclass_static_fires(self):
        findings = _analyze_source("""
            import dataclasses, functools, jax

            @dataclasses.dataclass
            class Cfg:
                n: int = 1

            @functools.partial(jax.jit, static_argnames=("cfg",))
            def fwd(cfg: Cfg, x):
                return x
        """)
        assert [f.rule for f in findings] == ["RA002"]
        assert "non-frozen dataclass" in findings[0].message

    def test_ra002_lru_cached_builder_is_clean(self):
        findings = _analyze_source("""
            import functools, jax

            @functools.lru_cache(maxsize=8)
            def build(n):
                def step(x):
                    return x * n
                return jax.jit(step)
        """)
        assert findings == []

    def test_ra003_sync_outside_hot_path_is_clean(self):
        findings = _analyze_source("""
            import jax
            import numpy as np

            @jax.jit
            def fwd(x):
                return x

            def report(x):                      # not a hot-path name
                y = fwd(x)
                return float(y)
        """)
        assert findings == []

    PREFETCH = """
        import jax
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        def kern(tbl_ref, x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def call(x, table, bq):
            def imap({params}):
                return (i, j)

            spec = pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(4, 2),
                in_specs=[pl.BlockSpec(({dim}, 8, 128), imap)],
                out_specs=pl.BlockSpec((8, 128), lambda i, j, t: (i, 0)),
            )
            return pl.pallas_call(kern, grid_spec=spec,
                                  out_shape=x)(table, x)
    """

    def test_ra004_prefetch_contract_clean(self):
        findings = _analyze_source(
            self.PREFETCH.format(params="i, j, tbl", dim="bq"))
        assert [f.rule for f in findings] == []

    def test_ra004_prefetch_map_wrong_arity_fires(self):
        findings = _analyze_source(
            self.PREFETCH.format(params="i, j", dim="bq"))
        assert [f.rule for f in findings] == ["RA004"]
        assert "scalar-prefetch" in findings[0].message

    def test_ra004_prefetch_qchunk_misaligned_fires(self):
        findings = _analyze_source(
            self.PREFETCH.format(params="i, j, tbl", dim="12"))
        assert [f.rule for f in findings] == ["RA004"]
        assert "q-chunk" in findings[0].message

    def test_ra005_locked_mutation_is_clean(self):
        findings = _analyze_source("""
            import threading

            class Shared:
                def __init__(self):
                    self.n = 0
                    self._lock = threading.Lock()

                def bump(self):
                    with self._lock:
                        self.n += 1

                def run(self):
                    threading.Thread(target=self.bump).start()
        """)
        assert findings == []


class TestSuppressionAndBaseline:
    BAD = """
        import jax
        import numpy as np

        @jax.jit
        def fwd(x):
            return x

        def step(x):
            y = fwd(x)
            return float(y){noqa}
    """

    def test_noqa_suppresses_exact_rule(self):
        assert _analyze_source(self.BAD.format(noqa="")) != []
        assert _analyze_source(
            self.BAD.format(noqa="  # noqa: RA003")) == []
        assert _analyze_source(self.BAD.format(noqa="  # noqa")) == []
        # a different code does not suppress
        assert _analyze_source(
            self.BAD.format(noqa="  # noqa: RA001")) != []

    def test_baseline_roundtrip_and_staleness(self, tmp_path):
        findings = _analyze_source(self.BAD.format(noqa=""))
        assert findings
        bl_path = tmp_path / "baseline.json"
        save_baseline(bl_path, findings)
        baseline = load_baseline(bl_path)
        assert json.loads(bl_path.read_text())["version"] == 1

        new, stale = apply_baseline(findings, baseline)
        assert new == [] and stale == []

        # key is content-addressed: the same finding on a shifted line
        # is still baselined
        shifted = _analyze_source("\n\n" + textwrap.dedent(
            self.BAD.format(noqa="")))
        new, stale = apply_baseline(shifted, baseline)
        assert new == []

        # fixing the finding leaves a stale entry (prompt to re-baseline)
        new, stale = apply_baseline([], baseline)
        assert new == [] and len(stale) == len({f.key for f in findings})


class TestRepoIsClean:
    def test_repo_analysis_clean_with_checked_in_baseline(self):
        new, stale, _total = run_analysis(
            [REPO / "src", REPO / "tests", REPO / "benchmarks"],
            root=REPO, baseline_path=REPO / "analysis_baseline.json")
        assert new == [], "new analysis findings:\n" + "\n".join(
            f.render() for f in new)
        assert stale == [], f"stale baseline entries: {stale}"

    def test_cli_selftest_passes(self):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--selftest"],
            cwd=REPO, capture_output=True, text=True,
            env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "selftest OK" in proc.stdout


class TestConfigHashability:
    """RA002's dynamic twin: every config that rides ``static_argnames``
    (and every field it carries) must be hashable, or the first jit call
    with it dies — catch the next list-typed field at test time."""

    def _configs(self):
        from repro.config import (ATTN, MLP, HeteroConfig, ModelConfig,
                                  RLConfig, ServeConfig, TrainConfig)
        model = ModelConfig(name="t", family="dense", num_layers=1,
                            d_model=8, num_heads=2, num_kv_heads=1,
                            d_ff=16, vocab_size=8, block_pattern=(ATTN,),
                            ffn_pattern=(MLP,))
        return [model, RLConfig(), TrainConfig(), HeteroConfig(),
                ServeConfig()]

    def test_default_instances_hash(self):
        for cfg in self._configs():
            hash(cfg)  # raises TypeError on any unhashable field value

    def test_every_field_value_hashable(self):
        for cfg in self._configs():
            for f in dataclasses.fields(cfg):
                v = getattr(cfg, f.name)
                try:
                    hash(v)
                except TypeError:
                    pytest.fail(
                        f"{type(cfg).__name__}.{f.name} = {v!r} is "
                        "unhashable — it would break every jit that "
                        "takes the config as a static arg")

    def test_configs_are_frozen(self):
        for cfg in self._configs():
            with pytest.raises(dataclasses.FrozenInstanceError):
                object.__getattribute__(cfg, "__class__")  # appease lint
                setattr(cfg, dataclasses.fields(cfg)[0].name, None)

    def test_execution_plan_hashes(self):
        from repro.parallel import plan_from_flag
        plan = plan_from_flag(None, "serve")
        hash(plan)
        assert plan == plan_from_flag(None, "serve")
