"""Paged-attention decode kernel: pallas (interpret) and jnp-ref parity
against the dense-gather oracle, scratch-page poisoning robustness,
dispatcher contracts, engine-level backend parity, and TP-over-kv-heads
composition via shard_map on ``make_debug_mesh``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import ATTN, LOCAL, MLP, ModelConfig, RLConfig
from repro.kernels.ops import (paged_decode, paged_decode_layers,
                               paged_prefill, paged_prefill_layers)
from repro.kernels.paged_attention import paged_attention
from repro.models import init_params
from repro.sampling import generate, generate_continuous

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = {"check_rep": False}


def _tols(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def make_case(*, b=4, hkv=2, rep=4, d=32, page=8, npages=6, pool=None,
              dtype=jnp.float32, seed=0, max_len=None):
    """Random pools + a block table of distinct physical pages per slot
    (page 0 reserved as scratch) + ragged per-slot lengths."""
    pool = pool or (1 + b * npages + 3)
    hq = hkv * rep
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d), dtype)
    kp = jax.random.normal(ks[1], (pool, page, hkv, d), dtype)
    vp = jax.random.normal(ks[2], (pool, page, hkv, d), dtype)
    host = np.random.default_rng(seed)
    perm = host.permutation(np.arange(1, pool))
    table = perm[:b * npages].reshape(b, npages).astype(np.int32)
    hi = max_len or npages * page
    lengths = host.integers(1, hi + 1, size=b).astype(np.int32)
    return q, kp, vp, jnp.asarray(table), jnp.asarray(lengths)


class TestParity:
    @pytest.mark.parametrize("page", [8, 16])
    @pytest.mark.parametrize("rep", [1, 4])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep_vs_gather_oracle(self, page, rep, dtype):
        q, kp, vp, table, lengths = make_case(page=page, rep=rep,
                                              dtype=dtype, seed=page + rep)
        oracle = paged_decode(q, kp, vp, table, lengths, impl="gather")
        for impl in ("ref", "pallas"):
            out = paged_decode(q, kp, vp, table, lengths, impl=impl,
                               interpret=True)
            np.testing.assert_allclose(
                np.asarray(out, np.float32), np.asarray(oracle, np.float32),
                err_msg=impl, **_tols(dtype))

    @pytest.mark.parametrize("window", [5, 16])
    def test_sliding_window_and_softcap(self, window):
        q, kp, vp, table, lengths = make_case(seed=7)
        for cap in (None, 20.0):
            oracle = paged_decode(q, kp, vp, table, lengths, kind="local",
                                  window=window, softcap=cap, impl="gather")
            for impl in ("ref", "pallas"):
                out = paged_decode(q, kp, vp, table, lengths, kind="local",
                                   window=window, softcap=cap, impl=impl,
                                   interpret=True)
                np.testing.assert_allclose(
                    np.asarray(out), np.asarray(oracle), rtol=2e-5,
                    atol=2e-5, err_msg=f"{impl} cap={cap}")

    def test_ragged_lengths_match_per_slot_dense(self):
        """Each slot must attend exactly its first ``lengths[b]`` logical
        positions — checked against a per-slot dense softmax built from
        the table by hand."""
        q, kp, vp, table, lengths = make_case(b=3, rep=2, seed=11)
        page = kp.shape[1]
        out = np.asarray(paged_decode(q, kp, vp, table, lengths,
                                      impl="ref"), np.float32)
        tb, ln = np.asarray(table), np.asarray(lengths)
        for b in range(q.shape[0]):
            kc = np.asarray(kp, np.float32)[tb[b]].reshape(-1, *kp.shape[2:])
            vc = np.asarray(vp, np.float32)[tb[b]].reshape(-1, *vp.shape[2:])
            kc, vc = kc[:ln[b]], vc[:ln[b]]
            qb = np.asarray(q, np.float32)[b, 0]          # (Hq, D)
            g, r = kp.shape[2], q.shape[2] // kp.shape[2]
            qg = qb.reshape(g, r, -1)
            s = np.einsum("grd,kgd->grk", qg, kc) / np.sqrt(qb.shape[-1])
            p = np.exp(s - s.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            o = np.einsum("grk,kgd->grd", p, vc).reshape(qb.shape)
            np.testing.assert_allclose(out[b, 0], o, rtol=2e-5, atol=2e-5)


class TestScratchPoisoning:
    """Garbage (even NaN) in the scratch page / dead table tails must be
    causally invisible: live-slot outputs are bit-identical to a clean
    pool. (The dense-gather path fails this — 0 · NaN = NaN — which is
    exactly why the kernel zeroes masked values.)"""

    @pytest.mark.parametrize("impl", ["ref", "pallas"])
    def test_nan_scratch_page_invisible(self, impl):
        q, kp, vp, table, lengths = make_case(seed=3, max_len=3 * 8)
        # dead tail of every slot parked on the scratch page, like the
        # engine's block table for partially-filled slots
        tb = np.asarray(table).copy()
        tb[:, 4:] = 0
        clean = paged_decode(q, kp, vp, jnp.asarray(tb), lengths,
                             impl=impl, interpret=True)
        kp_bad = kp.at[0].set(jnp.nan)
        vp_bad = vp.at[0].set(jnp.nan)
        poisoned = paged_decode(q, kp_bad, vp_bad, jnp.asarray(tb), lengths,
                                impl=impl, interpret=True)
        assert bool(jnp.isfinite(poisoned).all())
        np.testing.assert_array_equal(np.asarray(poisoned),
                                      np.asarray(clean))

    @pytest.mark.parametrize("impl", ["ref", "pallas"])
    def test_dead_slot_yields_finite_output(self, impl):
        """A dead slot (whole row on scratch, length 1) — the engine's
        PAD-decoding idle slots — must not contaminate anything."""
        q, kp, vp, table, lengths = make_case(seed=5)
        tb = np.asarray(table).copy()
        tb[1, :] = 0
        ln = np.asarray(lengths).copy()
        ln[1] = 1
        out = paged_decode(q, kp, vp, jnp.asarray(tb), jnp.asarray(ln),
                           impl=impl, interpret=True)
        assert bool(jnp.isfinite(out).all())


class TestDispatcher:
    def test_unknown_impl_raises(self):
        q, kp, vp, table, lengths = make_case(b=1, npages=2)
        with pytest.raises(ValueError, match="unknown paged-attention"):
            paged_decode(q, kp, vp, table, lengths, impl="turbo")

    def test_bidir_rejected(self):
        q, kp, vp, table, lengths = make_case(b=1, npages=2)
        with pytest.raises(ValueError, match="causal-only"):
            paged_decode(q, kp, vp, table, lengths, kind="bidir")

    def test_auto_matches_ref_off_tpu(self):
        q, kp, vp, table, lengths = make_case(seed=9)
        auto = paged_decode(q, kp, vp, table, lengths)
        ref = paged_decode(q, kp, vp, table, lengths, impl="ref")
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))

    def test_window_ignored_unless_local(self):
        q, kp, vp, table, lengths = make_case(seed=13)
        causal = paged_decode(q, kp, vp, table, lengths, kind="causal",
                              window=4, impl="ref")
        nowin = paged_decode(q, kp, vp, table, lengths, kind="causal",
                             impl="ref")
        np.testing.assert_array_equal(np.asarray(causal), np.asarray(nowin))


def make_prefill_case(*, b=3, c=8, hkv=2, rep=4, d=32, page=8, npages=6,
                      dtype=jnp.float32, seed=0, starts=None):
    """Random pools + block table + *ragged chunk offsets*: slot s holds
    a C-token query chunk at absolute positions starts[s] + [0, C), and
    every position < starts[s] + C already has k/v in its pages (the
    engine scatters the chunk's k/v before attending)."""
    hq = hkv * rep
    pool = 1 + b * npages + 3
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, c, hq, d), dtype)
    kp = jax.random.normal(ks[1], (pool, page, hkv, d), dtype)
    vp = jax.random.normal(ks[2], (pool, page, hkv, d), dtype)
    host = np.random.default_rng(seed)
    perm = host.permutation(np.arange(1, pool))
    table = perm[:b * npages].reshape(b, npages).astype(np.int32)
    if starts is None:
        starts = host.integers(0, npages * page - c + 1, size=b)
    starts = np.asarray(starts, np.int32)
    positions = starts[:, None] + np.arange(c, dtype=np.int32)[None]
    return q, kp, vp, jnp.asarray(table), jnp.asarray(positions)


def _prefill_oracle(q, kp, vp, table, positions, *, window=None,
                    softcap=None):
    """Per-slot dense numpy softmax over the table's logical view, row
    i attending kv positions <= positions[s, i] (window band applied) —
    independent of every jax code path under test."""
    qn = np.asarray(q, np.float32)
    kpn, vpn = np.asarray(kp, np.float32), np.asarray(vp, np.float32)
    tb, pos = np.asarray(table), np.asarray(positions)
    b, c, hq, d = qn.shape
    g = kpn.shape[2]
    rep = hq // g
    out = np.zeros_like(qn)
    for s in range(b):
        kc = kpn[tb[s]].reshape(-1, g, d)              # (W·page, G, D)
        vc = vpn[tb[s]].reshape(-1, g, d)
        cols = np.arange(kc.shape[0])
        for i in range(c):
            ok = cols <= pos[s, i]
            if window is not None:
                ok &= cols > pos[s, i] - window
            for h in range(hq):
                sc = kc[:, h // rep] @ qn[s, i, h] / np.sqrt(d)
                if softcap is not None:
                    sc = softcap * np.tanh(sc / softcap)
                p = np.where(ok, np.exp(sc - sc[ok].max()), 0.0)
                p /= p.sum()
                out[s, i, h] = p @ np.where(ok[:, None], vc[:, h // rep], 0)
    return out


class TestPrefillParity:
    @pytest.mark.parametrize("page", [8, 16])
    @pytest.mark.parametrize("rep", [1, 4])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep_vs_per_slot_dense(self, page, rep, dtype):
        q, kp, vp, table, positions = make_prefill_case(
            page=page, rep=rep, dtype=dtype, seed=page + rep)
        oracle = _prefill_oracle(q, kp, vp, table, positions)
        for impl in ("gather", "ref", "pallas"):
            out = paged_prefill(q, kp, vp, table, positions, impl=impl,
                                interpret=True)
            np.testing.assert_allclose(
                np.asarray(out, np.float32), oracle, err_msg=impl,
                **_tols(dtype))

    @pytest.mark.parametrize("window", [5, 16])
    def test_sliding_window_and_softcap(self, window):
        q, kp, vp, table, positions = make_prefill_case(seed=17)
        for cap in (None, 20.0):
            oracle = _prefill_oracle(q, kp, vp, table, positions,
                                     window=window, softcap=cap)
            for impl in ("gather", "ref", "pallas"):
                out = paged_prefill(q, kp, vp, table, positions,
                                    kind="local", window=window,
                                    softcap=cap, impl=impl, interpret=True)
                np.testing.assert_allclose(
                    np.asarray(out), oracle, rtol=2e-5, atol=2e-5,
                    err_msg=f"{impl} cap={cap}")

    def test_zero_offset_chunk(self):
        # a fresh prompt's first chunk: starts = 0 everywhere
        q, kp, vp, table, positions = make_prefill_case(
            starts=[0, 0, 0], seed=23)
        oracle = _prefill_oracle(q, kp, vp, table, positions)
        for impl in ("ref", "pallas"):
            out = paged_prefill(q, kp, vp, table, positions, impl=impl,
                                interpret=True)
            np.testing.assert_allclose(np.asarray(out), oracle,
                                       rtol=2e-5, atol=2e-5, err_msg=impl)

    def test_odd_chunk_width(self):
        # C that doesn't divide the default q block: _fit_block tiling
        q, kp, vp, table, positions = make_prefill_case(c=5, seed=29)
        oracle = _prefill_oracle(q, kp, vp, table, positions)
        out = paged_prefill(q, kp, vp, table, positions, impl="pallas",
                            interpret=True)
        np.testing.assert_allclose(np.asarray(out), oracle, rtol=2e-5,
                                   atol=2e-5)


class TestPrefillPoisoning:
    """NaN in the scratch page / unreachable table tails must be causally
    invisible to every prefill row — same contract as decode."""

    @pytest.mark.parametrize("impl", ["ref", "pallas"])
    def test_nan_scratch_page_invisible(self, impl):
        q, kp, vp, table, positions = make_prefill_case(
            starts=[0, 3, 9], seed=31)
        # park every page past the chunk's reach on the scratch page,
        # like the engine's table for a partially-prefilled slot
        page = kp.shape[1]
        tb = np.asarray(table).copy()
        pos = np.asarray(positions)
        for s in range(tb.shape[0]):
            live = -(-int(pos[s, -1] + 1) // page)
            tb[s, live:] = 0
        clean = paged_prefill(q, kp, vp, jnp.asarray(tb), positions,
                              impl=impl, interpret=True)
        poisoned = paged_prefill(q, kp.at[0].set(jnp.nan),
                                 vp.at[0].set(jnp.nan), jnp.asarray(tb),
                                 positions, impl=impl, interpret=True)
        assert bool(jnp.isfinite(poisoned).all())
        np.testing.assert_array_equal(np.asarray(poisoned),
                                      np.asarray(clean))


class TestPrefillDispatcher:
    def test_unknown_impl_raises(self):
        q, kp, vp, table, positions = make_prefill_case(b=1, npages=2, c=4)
        with pytest.raises(ValueError, match="unknown paged-attention"):
            paged_prefill(q, kp, vp, table, positions, impl="turbo")

    def test_bidir_rejected(self):
        q, kp, vp, table, positions = make_prefill_case(b=1, npages=2, c=4)
        with pytest.raises(ValueError, match="causal-only"):
            paged_prefill(q, kp, vp, table, positions, kind="bidir")

    def test_auto_matches_ref_off_tpu(self):
        q, kp, vp, table, positions = make_prefill_case(seed=37)
        auto = paged_prefill(q, kp, vp, table, positions)
        ref = paged_prefill(q, kp, vp, table, positions, impl="ref")
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))

    def test_no_dense_view_in_ref_lowering(self):
        """The point of the kernel: the ref path's XLA temp footprint
        must undercut the gather path's materialized
        (B, W·page, Hkv, D) logical view at wide tables."""
        q, kp, vp, table, positions = make_prefill_case(
            b=2, c=4, npages=24, page=8, starts=[0, 5], seed=41)
        args = (q, kp, vp, table, positions)

        def temp_bytes(impl):
            lowered = paged_prefill.lower(*args, impl=impl)
            return lowered.compile().memory_analysis().temp_size_in_bytes

        # table width 24 pages but only pages_for(5 + 4) = 2 live pages:
        # gather materializes the full-width view, ref streams per page
        assert temp_bytes("ref") * 4 < temp_bytes("gather")


class TestFusedLayers:
    """One launch for all layers' pools: the folded (L→slot axis) call
    must be bit-exact vs per-layer calls and issue exactly one
    pallas_call."""

    def _stacked(self, lyr=3, seed=43):
        qs, kps, vps = [], [], []
        for l in range(lyr):
            q, kp, vp, table, positions = make_prefill_case(
                seed=seed + 7 * l, starts=[2, 0, 11])
            qs.append(q), kps.append(kp), vps.append(vp)
        return (jnp.stack(qs), jnp.stack(kps), jnp.stack(vps), table,
                positions)

    @pytest.mark.parametrize("impl", ["gather", "ref", "pallas"])
    def test_prefill_fused_bitexact(self, impl):
        q, kp, vp, table, positions = self._stacked()
        per = jnp.stack([paged_prefill(q[l], kp[l], vp[l], table, positions,
                                       impl=impl, interpret=True)
                         for l in range(q.shape[0])])
        fused = paged_prefill_layers(q, kp, vp, table, positions,
                                     impl=impl, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(fused), np.asarray(per))  # noqa: RA003 — test sync

    @pytest.mark.parametrize("impl", ["gather", "ref", "pallas"])
    def test_decode_fused_bitexact(self, impl):
        q, kp, vp, table, positions = self._stacked()
        qd = q[:, :, :1]                                # (L, B, 1, Hq, D)
        lengths = positions[:, -1] + 1
        per = jnp.stack([paged_decode(qd[l], kp[l], vp[l], table, lengths,
                                      impl=impl, interpret=True)
                         for l in range(q.shape[0])])
        fused = paged_decode_layers(qd, kp, vp, table, lengths,
                                    impl=impl, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(fused), np.asarray(per))  # noqa: RA003 — test sync

    def test_single_pallas_launch(self, monkeypatch):
        import repro.kernels.ops as ops_mod
        import repro.kernels.paged_attention as pa
        q, kp, vp, table, positions = self._stacked()
        lengths = positions[:, -1] + 1
        calls = []
        real = pa.pl.pallas_call

        def counting(*a, **kw):
            calls.append(1)
            return real(*a, **kw)

        monkeypatch.setattr(pa.pl, "pallas_call", counting)
        lyr = q.shape[0]
        qf, kpf, vpf, tbl, ln = ops_mod._fold_layers(
            q[:, :, :1], kp, vp, table, lengths)
        pa.paged_attention(qf[:, 0], kpf, vpf, tbl, ln, interpret=True)
        assert len(calls) == 1                  # ONE launch for L layers
        calls.clear()
        for l in range(lyr):
            pa.paged_attention(q[l, :, 0], kp[l], vp[l], table, lengths,
                               interpret=True)
        assert len(calls) == lyr


TINY = ModelConfig(name="tiny-paged", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=32, block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)

GQA_LOCAL = dataclasses.replace(TINY, name="tiny-paged-local", num_layers=4,
                                block_pattern=(ATTN, LOCAL),
                                sliding_window=6)


class TestEngineBackends:
    """The continuous engine run end-to-end under every paged backend
    must reproduce the static engine (the gather default bit-exactly;
    kernel/ref to float-reassociation tolerance — empirically exact at
    these scales)."""

    @pytest.mark.parametrize("impl", ["gather", "ref", "pallas"])
    def test_static_parity_all_impls(self, rng, impl):
        cfg = dataclasses.replace(TINY, paged_attn_impl=impl)
        params = init_params(cfg, rng)
        prompts = jax.random.randint(rng, (6, 5), 3, cfg.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0,
                      max_new_tokens=10)
        r1 = generate(cfg, rl, params, prompts, rng, vocab_limit=20)
        r2 = generate_continuous(cfg, rl, params, prompts, rng,
                                 vocab_limit=20, num_slots=3, page_size=4,
                                 sync_every=4)
        np.testing.assert_array_equal(np.asarray(r1["completions"]),
                                      np.asarray(r2["completions"]))
        np.testing.assert_allclose(np.asarray(r1["sampler_lp"]),
                                   np.asarray(r2["sampler_lp"]),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("impl", ["gather", "ref", "pallas"])
    def test_chunked_prefill_static_parity(self, rng, impl):
        """Chunked prefill (the paged_prefill hot path — ragged chunk
        offsets, narrowed tables) under every backend reproduces the
        static engine."""
        cfg = dataclasses.replace(TINY, paged_attn_impl=impl)
        params = init_params(cfg, rng)
        prompts = jax.random.randint(rng, (5, 9), 3, cfg.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=8)
        r1 = generate(cfg, rl, params, prompts, rng, vocab_limit=20)
        r2 = generate_continuous(cfg, rl, params, prompts, rng,
                                 vocab_limit=20, num_slots=2, page_size=4,
                                 prefill_chunk=4, sync_every=3)
        np.testing.assert_array_equal(np.asarray(r1["completions"]),
                                      np.asarray(r2["completions"]))
        np.testing.assert_allclose(np.asarray(r1["sampler_lp"]),
                                   np.asarray(r2["sampler_lp"]),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("impl", ["gather", "ref"])
    def test_prefix_cache_cow_pages(self, rng, impl):
        """Shared-prefix COW pages + chunked prefill: requests whose
        prompts share a prefix prefill against refcounted pages from
        `prefix_cache`; every backend must leave completions unchanged
        vs the uncached run."""
        cfg = dataclasses.replace(TINY, paged_attn_impl=impl)
        params = init_params(cfg, rng)
        base = np.asarray(jax.random.randint(rng, (1, 10), 3,
                                             cfg.vocab_size))
        prompts = np.repeat(base, 4, axis=0)
        prompts[2:, -2:] = [[3, 4], [5, 6]]    # diverge after the prefix
        prompts = jnp.asarray(prompts)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=6)
        cached = generate_continuous(cfg, rl, params, prompts, rng,
                                     vocab_limit=20, num_slots=2,
                                     page_size=4, prefill_chunk=4,
                                     sync_every=3, prefix_cache=True)
        plain = generate_continuous(cfg, rl, params, prompts, rng,
                                    vocab_limit=20, num_slots=2,
                                    page_size=4, prefill_chunk=4,
                                    sync_every=3, prefix_cache=False)
        np.testing.assert_array_equal(np.asarray(cached["completions"]),
                                      np.asarray(plain["completions"]))

    def test_gqa_local_window_ref_backend(self, rng):
        cfg = dataclasses.replace(GQA_LOCAL, paged_attn_impl="ref")
        params = init_params(cfg, rng)
        prompts = jax.random.randint(rng, (4, 7), 3, cfg.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=8)
        r1 = generate(cfg, rl, params, prompts, rng, vocab_limit=20)
        r2 = generate_continuous(cfg, rl, params, prompts, rng,
                                 vocab_limit=20, num_slots=2, page_size=4,
                                 prefill_chunk=3, sync_every=3)
        np.testing.assert_array_equal(np.asarray(r1["completions"]),
                                      np.asarray(r2["completions"]))
        np.testing.assert_allclose(np.asarray(r1["sampler_lp"]),
                                   np.asarray(r2["sampler_lp"]),
                                   rtol=1e-3, atol=1e-3)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >=2 devices (XLA_FLAGS="
                           "--xla_force_host_platform_device_count)")
class TestTensorParallel:
    """The kernel composes with the TP-over-kv-heads sharding the
    ExecutionPlan gives the kp/vp pools: per-shard dispatch via
    shard_map on a debug mesh reproduces the unsharded oracle."""

    def test_shard_map_kv_heads(self):
        from repro.parallel import make_debug_mesh
        mesh = make_debug_mesh(1, 2)
        q, kp, vp, table, lengths = make_case(hkv=2, rep=2, seed=21)

        # q heads are grouped per kv head ((B, 1, G·rep, D) with head
        # index g·rep + r), so sharding heads over 'model' keeps each
        # shard's q heads aligned with its kv heads.
        qs = P(None, None, "model", None)
        ps = P(None, None, "model", None)          # (pages, page, Hkv, D)

        def local(qx, kpx, vpx, tbl, ln):
            return paged_attention(qx[:, 0], kpx, vpx, tbl, ln,
                                   interpret=True)[:, None]

        fn = _shard_map(local, mesh=mesh,
                        in_specs=(qs, ps, ps, P(None, None), P(None)),
                        out_specs=qs, **_CHECK_KW)
        fn_jit = jax.jit(fn)
        out = fn_jit(q, kp, vp, table, lengths)
        oracle = paged_decode(q, kp, vp, table, lengths, impl="gather")
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   rtol=2e-5, atol=2e-5)

    def test_serve_plan_ref_backend(self):
        """The GSPMD-native ref backend under a real 1x2 serve plan —
        what `serve --mesh 1x4 --paged-attn-impl ref` runs."""
        from repro.parallel import ExecutionPlan, make_debug_mesh
        plan = ExecutionPlan(mesh=make_debug_mesh(1, 2), mode="serve")
        cfg = dataclasses.replace(TINY, paged_attn_impl="ref")
        key = jax.random.PRNGKey(0)
        params = plan.device_put_params(cfg, init_params(cfg, key))
        prompts = jax.random.randint(key, (4, 5), 3, cfg.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=6)
        roll = generate_continuous(cfg, rl, params, prompts, key,
                                   vocab_limit=20, num_slots=2,
                                   page_size=4, sync_every=2, plan=plan)
        ref1 = generate_continuous(cfg, rl, params, prompts, key,
                                   vocab_limit=20, num_slots=2,
                                   page_size=4, sync_every=2)
        np.testing.assert_array_equal(np.asarray(roll["completions"]),
                                      np.asarray(ref1["completions"]))

    def test_serve_plan_ref_backend_chunked_prefill(self):
        """Chunked prefill (paged_prefill_ref under the plan's sharding
        constraints) on a 1x2 serve plan matches the unplanned run."""
        from repro.parallel import ExecutionPlan, make_debug_mesh
        plan = ExecutionPlan(mesh=make_debug_mesh(1, 2), mode="serve")
        cfg = dataclasses.replace(TINY, paged_attn_impl="ref")
        key = jax.random.PRNGKey(1)
        params = plan.device_put_params(cfg, init_params(cfg, key))
        prompts = jax.random.randint(key, (4, 9), 3, cfg.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=6)
        roll = generate_continuous(cfg, rl, params, prompts, key,
                                   vocab_limit=20, num_slots=2,
                                   page_size=4, prefill_chunk=3,
                                   sync_every=2, plan=plan)
        ref1 = generate_continuous(cfg, rl, params, prompts, key,
                                   vocab_limit=20, num_slots=2,
                                   page_size=4, prefill_chunk=3,
                                   sync_every=2)
        np.testing.assert_array_equal(np.asarray(roll["completions"]),
                                      np.asarray(ref1["completions"]))
