"""Paged-attention decode kernel: pallas (interpret) and jnp-ref parity
against the dense-gather oracle, scratch-page poisoning robustness,
dispatcher contracts, engine-level backend parity, and TP-over-kv-heads
composition via shard_map on ``make_debug_mesh``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import ATTN, LOCAL, MLP, ModelConfig, RLConfig
from repro.kernels.ops import paged_decode
from repro.kernels.paged_attention import paged_attention
from repro.models import init_params
from repro.sampling import generate, generate_continuous

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = {"check_rep": False}


def _tols(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def make_case(*, b=4, hkv=2, rep=4, d=32, page=8, npages=6, pool=None,
              dtype=jnp.float32, seed=0, max_len=None):
    """Random pools + a block table of distinct physical pages per slot
    (page 0 reserved as scratch) + ragged per-slot lengths."""
    pool = pool or (1 + b * npages + 3)
    hq = hkv * rep
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d), dtype)
    kp = jax.random.normal(ks[1], (pool, page, hkv, d), dtype)
    vp = jax.random.normal(ks[2], (pool, page, hkv, d), dtype)
    host = np.random.default_rng(seed)
    perm = host.permutation(np.arange(1, pool))
    table = perm[:b * npages].reshape(b, npages).astype(np.int32)
    hi = max_len or npages * page
    lengths = host.integers(1, hi + 1, size=b).astype(np.int32)
    return q, kp, vp, jnp.asarray(table), jnp.asarray(lengths)


class TestParity:
    @pytest.mark.parametrize("page", [8, 16])
    @pytest.mark.parametrize("rep", [1, 4])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep_vs_gather_oracle(self, page, rep, dtype):
        q, kp, vp, table, lengths = make_case(page=page, rep=rep,
                                              dtype=dtype, seed=page + rep)
        oracle = paged_decode(q, kp, vp, table, lengths, impl="gather")
        for impl in ("ref", "pallas"):
            out = paged_decode(q, kp, vp, table, lengths, impl=impl,
                               interpret=True)
            np.testing.assert_allclose(
                np.asarray(out, np.float32), np.asarray(oracle, np.float32),
                err_msg=impl, **_tols(dtype))

    @pytest.mark.parametrize("window", [5, 16])
    def test_sliding_window_and_softcap(self, window):
        q, kp, vp, table, lengths = make_case(seed=7)
        for cap in (None, 20.0):
            oracle = paged_decode(q, kp, vp, table, lengths, kind="local",
                                  window=window, softcap=cap, impl="gather")
            for impl in ("ref", "pallas"):
                out = paged_decode(q, kp, vp, table, lengths, kind="local",
                                   window=window, softcap=cap, impl=impl,
                                   interpret=True)
                np.testing.assert_allclose(
                    np.asarray(out), np.asarray(oracle), rtol=2e-5,
                    atol=2e-5, err_msg=f"{impl} cap={cap}")

    def test_ragged_lengths_match_per_slot_dense(self):
        """Each slot must attend exactly its first ``lengths[b]`` logical
        positions — checked against a per-slot dense softmax built from
        the table by hand."""
        q, kp, vp, table, lengths = make_case(b=3, rep=2, seed=11)
        page = kp.shape[1]
        out = np.asarray(paged_decode(q, kp, vp, table, lengths,
                                      impl="ref"), np.float32)
        tb, ln = np.asarray(table), np.asarray(lengths)
        for b in range(q.shape[0]):
            kc = np.asarray(kp, np.float32)[tb[b]].reshape(-1, *kp.shape[2:])
            vc = np.asarray(vp, np.float32)[tb[b]].reshape(-1, *vp.shape[2:])
            kc, vc = kc[:ln[b]], vc[:ln[b]]
            qb = np.asarray(q, np.float32)[b, 0]          # (Hq, D)
            g, r = kp.shape[2], q.shape[2] // kp.shape[2]
            qg = qb.reshape(g, r, -1)
            s = np.einsum("grd,kgd->grk", qg, kc) / np.sqrt(qb.shape[-1])
            p = np.exp(s - s.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            o = np.einsum("grk,kgd->grd", p, vc).reshape(qb.shape)
            np.testing.assert_allclose(out[b, 0], o, rtol=2e-5, atol=2e-5)


class TestScratchPoisoning:
    """Garbage (even NaN) in the scratch page / dead table tails must be
    causally invisible: live-slot outputs are bit-identical to a clean
    pool. (The dense-gather path fails this — 0 · NaN = NaN — which is
    exactly why the kernel zeroes masked values.)"""

    @pytest.mark.parametrize("impl", ["ref", "pallas"])
    def test_nan_scratch_page_invisible(self, impl):
        q, kp, vp, table, lengths = make_case(seed=3, max_len=3 * 8)
        # dead tail of every slot parked on the scratch page, like the
        # engine's block table for partially-filled slots
        tb = np.asarray(table).copy()
        tb[:, 4:] = 0
        clean = paged_decode(q, kp, vp, jnp.asarray(tb), lengths,
                             impl=impl, interpret=True)
        kp_bad = kp.at[0].set(jnp.nan)
        vp_bad = vp.at[0].set(jnp.nan)
        poisoned = paged_decode(q, kp_bad, vp_bad, jnp.asarray(tb), lengths,
                                impl=impl, interpret=True)
        assert bool(jnp.isfinite(poisoned).all())
        np.testing.assert_array_equal(np.asarray(poisoned),
                                      np.asarray(clean))

    @pytest.mark.parametrize("impl", ["ref", "pallas"])
    def test_dead_slot_yields_finite_output(self, impl):
        """A dead slot (whole row on scratch, length 1) — the engine's
        PAD-decoding idle slots — must not contaminate anything."""
        q, kp, vp, table, lengths = make_case(seed=5)
        tb = np.asarray(table).copy()
        tb[1, :] = 0
        ln = np.asarray(lengths).copy()
        ln[1] = 1
        out = paged_decode(q, kp, vp, jnp.asarray(tb), jnp.asarray(ln),
                           impl=impl, interpret=True)
        assert bool(jnp.isfinite(out).all())


class TestDispatcher:
    def test_unknown_impl_raises(self):
        q, kp, vp, table, lengths = make_case(b=1, npages=2)
        with pytest.raises(ValueError, match="unknown paged-attention"):
            paged_decode(q, kp, vp, table, lengths, impl="turbo")

    def test_bidir_rejected(self):
        q, kp, vp, table, lengths = make_case(b=1, npages=2)
        with pytest.raises(ValueError, match="causal-only"):
            paged_decode(q, kp, vp, table, lengths, kind="bidir")

    def test_auto_matches_ref_off_tpu(self):
        q, kp, vp, table, lengths = make_case(seed=9)
        auto = paged_decode(q, kp, vp, table, lengths)
        ref = paged_decode(q, kp, vp, table, lengths, impl="ref")
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))

    def test_window_ignored_unless_local(self):
        q, kp, vp, table, lengths = make_case(seed=13)
        causal = paged_decode(q, kp, vp, table, lengths, kind="causal",
                              window=4, impl="ref")
        nowin = paged_decode(q, kp, vp, table, lengths, kind="causal",
                             impl="ref")
        np.testing.assert_array_equal(np.asarray(causal), np.asarray(nowin))


TINY = ModelConfig(name="tiny-paged", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=32, block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)

GQA_LOCAL = dataclasses.replace(TINY, name="tiny-paged-local", num_layers=4,
                                block_pattern=(ATTN, LOCAL),
                                sliding_window=6)


class TestEngineBackends:
    """The continuous engine run end-to-end under every paged backend
    must reproduce the static engine (the gather default bit-exactly;
    kernel/ref to float-reassociation tolerance — empirically exact at
    these scales)."""

    @pytest.mark.parametrize("impl", ["gather", "ref", "pallas"])
    def test_static_parity_all_impls(self, rng, impl):
        cfg = dataclasses.replace(TINY, paged_attn_impl=impl)
        params = init_params(cfg, rng)
        prompts = jax.random.randint(rng, (6, 5), 3, cfg.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0,
                      max_new_tokens=10)
        r1 = generate(cfg, rl, params, prompts, rng, vocab_limit=20)
        r2 = generate_continuous(cfg, rl, params, prompts, rng,
                                 vocab_limit=20, num_slots=3, page_size=4,
                                 sync_every=4)
        np.testing.assert_array_equal(np.asarray(r1["completions"]),
                                      np.asarray(r2["completions"]))
        np.testing.assert_allclose(np.asarray(r1["sampler_lp"]),
                                   np.asarray(r2["sampler_lp"]),
                                   rtol=1e-5, atol=1e-5)

    def test_gqa_local_window_ref_backend(self, rng):
        cfg = dataclasses.replace(GQA_LOCAL, paged_attn_impl="ref")
        params = init_params(cfg, rng)
        prompts = jax.random.randint(rng, (4, 7), 3, cfg.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=8)
        r1 = generate(cfg, rl, params, prompts, rng, vocab_limit=20)
        r2 = generate_continuous(cfg, rl, params, prompts, rng,
                                 vocab_limit=20, num_slots=2, page_size=4,
                                 prefill_chunk=3, sync_every=3)
        np.testing.assert_array_equal(np.asarray(r1["completions"]),
                                      np.asarray(r2["completions"]))
        np.testing.assert_allclose(np.asarray(r1["sampler_lp"]),
                                   np.asarray(r2["sampler_lp"]),
                                   rtol=1e-3, atol=1e-3)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >=2 devices (XLA_FLAGS="
                           "--xla_force_host_platform_device_count)")
class TestTensorParallel:
    """The kernel composes with the TP-over-kv-heads sharding the
    ExecutionPlan gives the kp/vp pools: per-shard dispatch via
    shard_map on a debug mesh reproduces the unsharded oracle."""

    def test_shard_map_kv_heads(self):
        from repro.parallel import make_debug_mesh
        mesh = make_debug_mesh(1, 2)
        q, kp, vp, table, lengths = make_case(hkv=2, rep=2, seed=21)

        # q heads are grouped per kv head ((B, 1, G·rep, D) with head
        # index g·rep + r), so sharding heads over 'model' keeps each
        # shard's q heads aligned with its kv heads.
        qs = P(None, None, "model", None)
        ps = P(None, None, "model", None)          # (pages, page, Hkv, D)

        def local(qx, kpx, vpx, tbl, ln):
            return paged_attention(qx[:, 0], kpx, vpx, tbl, ln,
                                   interpret=True)[:, None]

        fn = _shard_map(local, mesh=mesh,
                        in_specs=(qs, ps, ps, P(None, None), P(None)),
                        out_specs=qs, **_CHECK_KW)
        fn_jit = jax.jit(fn)
        out = fn_jit(q, kp, vp, table, lengths)
        oracle = paged_decode(q, kp, vp, table, lengths, impl="gather")
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   rtol=2e-5, atol=2e-5)

    def test_serve_plan_ref_backend(self):
        """The GSPMD-native ref backend under a real 1x2 serve plan —
        what `serve --mesh 1x4 --paged-attn-impl ref` runs."""
        from repro.parallel import ExecutionPlan, make_debug_mesh
        plan = ExecutionPlan(mesh=make_debug_mesh(1, 2), mode="serve")
        cfg = dataclasses.replace(TINY, paged_attn_impl="ref")
        key = jax.random.PRNGKey(0)
        params = plan.device_put_params(cfg, init_params(cfg, key))
        prompts = jax.random.randint(key, (4, 5), 3, cfg.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=6)
        roll = generate_continuous(cfg, rl, params, prompts, key,
                                   vocab_limit=20, num_slots=2,
                                   page_size=4, sync_every=2, plan=plan)
        ref1 = generate_continuous(cfg, rl, params, prompts, key,
                                   vocab_limit=20, num_slots=2,
                                   page_size=4, sync_every=2)
        np.testing.assert_array_equal(np.asarray(roll["completions"]),
                                      np.asarray(ref1["completions"]))
