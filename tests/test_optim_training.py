"""Optimizers vs numpy references; schedules; grad-accum equivalence;
SFT loss decreases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, RLConfig, TrainConfig, ATTN, MLP
from repro.core.logprob import (token_logprob_and_entropy,
                                token_logprob_from_logits)
from repro.models import init_params
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, global_norm,
                         warmup_schedule)
from repro.training import (TrainState, init_state, jit_sft_step,
                            train_step)

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=48,
                   num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=32,
                   block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)


class TestAdamW:
    def test_matches_numpy_reference(self, rng):
        tc = TrainConfig(learning_rate=1e-2, b1=0.9, b2=0.95, eps=1e-8,
                         weight_decay=0.01, total_steps=100,
                         warmup_frac=0.0)
        p = {"w": jax.random.normal(rng, (4, 3))}
        state = adamw_init(p)
        g = {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 3))}
        m = v = np.zeros((4, 3))
        pw = np.asarray(p["w"], np.float64)
        for step in range(1, 4):
            p, state = adamw_update(tc, g, state, p, jnp.float32(1e-2))
            gw = np.asarray(g["w"], np.float64)
            m = 0.9 * m + 0.1 * gw
            v = 0.95 * v + 0.05 * gw * gw
            mh = m / (1 - 0.9 ** step)
            vh = v / (1 - 0.95 ** step)
            pw = pw - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * pw)
            np.testing.assert_allclose(np.asarray(p["w"]), pw, rtol=1e-5)

    def test_adafactor_reduces_loss(self, rng):
        tc = TrainConfig(learning_rate=0.1, weight_decay=0.0)
        w = {"w": jax.random.normal(rng, (8, 8)), "b": jnp.zeros((8,))}
        target = jax.random.normal(jax.random.PRNGKey(2), (8, 8))

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["b"] ** 2)
        state = adafactor_init(w)
        l0 = float(loss(w))
        for _ in range(50):
            g = jax.grad(loss)(w)
            w, state = adafactor_update(tc, g, state, w, jnp.float32(0.1))
        assert float(loss(w)) < 0.2 * l0

    def test_clip_by_global_norm(self, rng):
        tree = {"a": 3.0 * jax.random.normal(rng, (32,)),
                "b": 3.0 * jax.random.normal(jax.random.PRNGKey(1), (8, 8))}
        clipped, n = clip_by_global_norm(tree, 1.0)
        assert float(global_norm(clipped)) <= 1.0 + 1e-5
        assert float(n) > 1.0

    def test_warmup_schedule(self):
        tc = TrainConfig(learning_rate=1e-3, warmup_frac=0.1,
                         total_steps=100)
        assert float(warmup_schedule(tc, 0)) == pytest.approx(1e-4)
        assert float(warmup_schedule(tc, 4)) == pytest.approx(5e-4)
        assert float(warmup_schedule(tc, 50)) == pytest.approx(1e-3)
        assert float(warmup_schedule(tc, 0)) > 0.0   # step 0 must train


class TestLogprobHelpers:
    def test_masked_sum_equals_gather(self, rng):
        logits = jax.random.normal(rng, (4, 8, 64))
        tgt = jax.random.randint(rng, (4, 8), 0, 64)
        lp = token_logprob_from_logits(logits, tgt)
        ref = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                  tgt[..., None], axis=-1)[..., 0]
        np.testing.assert_allclose(np.asarray(lp), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)

    def test_entropy_variant(self, rng):
        logits = jax.random.normal(rng, (4, 8, 64))
        tgt = jax.random.randint(rng, (4, 8), 0, 64)
        lp, ent = token_logprob_and_entropy(logits, tgt)
        p = jax.nn.softmax(logits, -1)
        ref_ent = -(p * jnp.log(p)).sum(-1)
        np.testing.assert_allclose(np.asarray(ent), np.asarray(ref_ent),
                                   rtol=1e-4, atol=1e-5)


class TestTrainStep:
    def _batch(self, key, b=8, s=10):
        ks = jax.random.split(key, 3)
        return {
            "tokens": jax.random.randint(ks[0], (b, s), 0, 32),
            "mask": jnp.ones((b, s - 1)),
            "sampler_lp": -jnp.abs(jax.random.normal(ks[1], (b, s - 1))),
            "rewards": (jax.random.uniform(ks[2], (b,)) > 0.5).astype(
                jnp.float32),
        }

    def test_grad_accum_equivalence(self, rng):
        """accum=2 must produce (numerically close) identical updates to
        accum=1 on the same global batch."""
        params = init_params(TINY, rng)
        rl = RLConfig(loss_type="gepo", group_size=4, beta_kl=0.0)
        batch = self._batch(jax.random.PRNGKey(5))
        outs = {}
        for accum in (1, 2):
            tc = TrainConfig(learning_rate=1e-3, grad_accum=accum,
                             total_steps=10)
            state = init_state(TINY, tc, params)
            new_state, m = train_step(TINY, rl, tc, state, batch)
            outs[accum] = new_state.params
        flat1 = jax.tree_util.tree_leaves(outs[1])
        flat2 = jax.tree_util.tree_leaves(outs[2])
        for a, b in zip(flat1, flat2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)

    def test_loss_evaluated_exactly_grad_accum_times(self, rng,
                                                     monkeypatch):
        """Regression: the metrics-structure probe must not run a
        throwaway forward/backward — a step performs exactly
        ``grad_accum`` loss evaluations (jax.eval_shape costs none)."""
        import repro.training as training
        counter = {"n": 0}
        orig = training.rl_loss_fn

        def counted(*args, **kwargs):
            jax.debug.callback(
                lambda: counter.__setitem__("n", counter["n"] + 1))
            return orig(*args, **kwargs)

        monkeypatch.setattr(training, "rl_loss_fn", counted)
        params = init_params(TINY, rng)
        rl = RLConfig(loss_type="gepo", group_size=4, beta_kl=0.0)
        batch = self._batch(jax.random.PRNGKey(5), b=16)
        for accum in (1, 2, 4):
            counter["n"] = 0
            tc = TrainConfig(learning_rate=1e-3, grad_accum=accum,
                             total_steps=10)
            state = init_state(TINY, tc, params)
            train_step(TINY, rl, tc, state, batch)
            jax.effects_barrier()
            assert counter["n"] == accum, (accum, counter["n"])

    def test_grad_accum_max_metrics_not_averaged(self, rng):
        """iw_max must be the max over the whole step, not a
        mean-of-per-microbatch-maxes. Crafted 2-microbatch batch: the
        halves land in different microbatches with very different
        importance weights, so the buggy mean is measurably below the
        true max."""
        params = init_params(TINY, rng)
        rl = RLConfig(loss_type="gepo", group_size=4, beta_kl=0.0)
        batch = self._batch(jax.random.PRNGKey(5))
        # skew the first group's sampler logps so its per-seq maxima
        # differ sharply from the second microbatch's
        batch["sampler_lp"] = batch["sampler_lp"].at[:4].add(-2.0)
        metrics = {}
        for accum in (1, 2):
            tc = TrainConfig(learning_rate=1e-3, grad_accum=accum,
                             total_steps=10)
            state = init_state(TINY, tc, params)
            _, m = train_step(TINY, rl, tc, state, batch)
            metrics[accum] = m
        np.testing.assert_allclose(float(metrics[2]["iw_max"]),
                                   float(metrics[1]["iw_max"]),
                                   rtol=1e-5)
        # mean-type metrics still average to the full-batch value
        for key in ("loss", "kl", "iw_mean", "adv_mean"):
            np.testing.assert_allclose(float(metrics[2][key]),
                                       float(metrics[1][key]),
                                       rtol=1e-4, atol=1e-6)

    def test_sft_loss_decreases(self, rng):
        tc = TrainConfig(learning_rate=5e-3, total_steps=60)
        state = init_state(TINY, tc, init_params(TINY, rng))
        step = jit_sft_step(TINY, tc)
        toks = jax.random.randint(jax.random.PRNGKey(9), (16, 12), 3, 20)
        mask = jnp.ones((16, 11))
        first = None
        for i in range(60):
            state, loss = step(state, toks, mask)
            if first is None:
                first = float(loss)
        assert float(loss) < 0.5 * first
