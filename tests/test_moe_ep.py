"""Numerical equivalence of the shard_map expert-parallel MoE (§Perf
optimization) against the GSPMD baseline dispatch — run on an 8-device
debug mesh in a subprocess (device-count override must not leak)."""
import json
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.config import ModelConfig, ATTN, MOE
    from repro.models.moe import moe_ffn
    from repro.models.moe_ep import moe_ffn_ep
    from repro.models.params import init_params
    from repro.runtime_context import mesh_context

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(name="moe-eq", family="moe", num_layers=1,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, block_pattern=(ATTN,),
                      ffn_pattern=(MOE,), num_experts=4,
                      experts_per_token={k}, dtype="float32",
                      capacity_factor=8.0,       # no drops on either path
                      attn_impl="naive", remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)["blocks"]["layer_0"]["moe"]
    params = jax.tree_util.tree_map(lambda a: a[0], params)  # unstack
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))

    y_ref, aux_ref = moe_ffn(cfg, params, x)     # single-device baseline

    cfg_ep = dataclasses.replace(cfg, moe_ep="serve",
                                 ep_dp_axes=("data",))
    with mesh_context(mesh):
        def f(params, x):
            return moe_ffn_ep(cfg_ep, params, x)
        y_ep, aux_ep = jax.jit(f)(params, x)

    err = float(jnp.abs(y_ref - y_ep).max())
    lb_err = abs(float(aux_ref["moe_load_balance"])
                 - float(aux_ep["moe_load_balance"]))
    print(json.dumps({{"err": err, "lb_err": lb_err}}))
""")


def _run(k: int):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT.format(k=k)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_ep_matches_gspmd_top1():
    rec = _run(1)
    assert rec["err"] < 1e-4, rec
    assert rec["lb_err"] < 0.1, rec   # mean-of-shard-means


def test_ep_matches_gspmd_top2():
    rec = _run(2)
    assert rec["err"] < 1e-4, rec
