"""Speculative decoding: exact-replay acceptance, drafter, fused rescore.

The contracts under test, in ISSUE order: greedy spec decode is
bit-identical to the non-speculative engine; every reported logp is the
*target* model's logp of the emitted token (GEPO App. B.1 — never the
drafter's); rollback leaves the page pool balanced (append-only rewind,
no allocator traffic); and the verification path really consumes the
fused-layer kernels (``paged_prefill_layers`` launch-counted).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sentinel import (spec_verify_executable_bound,
                                     spec_verify_width_buckets)
from repro.config import (ATTN, LOCAL, MLP, ModelConfig, RLConfig,
                          ServeConfig)
from repro.data.tasks import EOS, PAD
from repro.models import init_params
from repro.sampling import NGramDrafter, build_engine, filter_logits
from repro.sampling.sample import NEG_INF
from repro.sampling.spec import accept_drafts, verify_width_buckets
from repro.serving.api import Request, SamplingParams

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=32,
                   block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)

GQA_LOCAL = ModelConfig(name="gqa-local", family="dense", num_layers=4,
                        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                        vocab_size=32, block_pattern=(ATTN, LOCAL),
                        ffn_pattern=(MLP,), sliding_window=8,
                        dtype="float32", attn_impl="naive", remat=False,
                        rope_theta=1e4)

GREEDY = dict(temperature=1.0, top_k=1, top_p=1.0)


def _run(cfg, params, rl, prompts, *, spec_k, key, max_new=12,
         prefix_cache=True, spec_rescore=True, spec=True, sync_every=4):
    serve = ServeConfig(engine="continuous", num_slots=3, page_size=4,
                        sync_every=sync_every, prefix_cache=prefix_cache,
                        max_total_tokens=max(len(p) for p in prompts)
                        + max_new,
                        spec_k=spec_k, spec_rescore=spec_rescore, seed=0)
    eng = build_engine(cfg, params, serve, rl=rl,
                       vocab_limit=cfg.vocab_size, key=key)
    sp = SamplingParams.from_rl(rl)
    if not spec:
        sp = SamplingParams(temperature=rl.temperature, top_k=rl.top_k,
                            top_p=rl.top_p, max_new_tokens=rl.max_new_tokens,
                            spec=False)
    res = eng.generate([Request(rid=i, prompt=p, params=sp)
                        for i, p in enumerate(prompts)])
    return eng, res


def _prompts(rng, n=6, width=7, vocab=30):
    return [rng.integers(4, vocab, size=width).astype(np.int32)
            for _ in range(n)]


class TestNGramDrafter:
    def test_continuation_of_most_recent_match(self):
        d = NGramDrafter(max_ngram=2, min_ngram=1)
        #        match A ----v        match B (more recent) ----v
        h = np.array([5, 6, 7, 8, 1, 5, 6, 9, 2, 5, 6], np.int32)
        np.testing.assert_array_equal(d.propose(h, 2), [9, 2])

    def test_longer_ngram_beats_shorter(self):
        d = NGramDrafter(max_ngram=3, min_ngram=1)
        h = np.array([1, 2, 3, 4, 9, 6, 2, 3, 7, 1, 2, 3], np.int32)
        # trigram [1,2,3] matches at the start -> continuation 4, 9
        np.testing.assert_array_equal(d.propose(h, 2), [4, 9])

    def test_no_match_is_empty(self):
        d = NGramDrafter()
        out = d.propose(np.array([1, 2, 3, 4, 5], np.int32), 4)
        assert out.size == 0 and out.dtype == np.int32

    def test_chains_past_history_end(self):
        # a length-2 cycle has only 2 continuation tokens in history;
        # chaining re-proposes over history + draft to fill all k slots
        d = NGramDrafter(max_ngram=1)
        h = np.array([7, 3, 7], np.int32)
        np.testing.assert_array_equal(d.propose(h, 5), [3, 7, 3, 7, 3])

    def test_k_zero_and_tiny_history(self):
        d = NGramDrafter()
        assert d.propose(np.array([3], np.int32), 4).size == 0
        assert d.propose(np.array([3, 3, 3], np.int32), 0).size == 0

    def test_validation(self):
        with pytest.raises(ValueError):
            NGramDrafter(max_ngram=2, min_ngram=3)


class TestAcceptDrafts:
    """Pure-function acceptance rule, greedy profile (top_k=1 makes the
    replayed draw the argmax — fully deterministic)."""

    V = 16

    def _logits(self, argmaxes):
        lg = np.zeros((1, len(argmaxes), self.V), np.float32)
        for i, t in enumerate(argmaxes):
            lg[0, i, t] = 5.0
        return jnp.asarray(lg)

    def _accept(self, argmaxes, drafts, *, gen_base=0, max_new=100):
        w = len(argmaxes)
        window = np.full((1, w), PAD, np.int32)
        window[0, 0] = 3                      # pending token (col 0)
        window[0, 1:1 + len(drafts)] = drafts
        return accept_drafts(
            self._logits(argmaxes), jnp.asarray(window),
            jnp.asarray([len(drafts)], np.int32), jnp.asarray([True]),
            jax.random.PRNGKey(0)[None], jnp.asarray([gen_base], np.int32),
            jnp.asarray([max_new], np.int32), temperature=1.0, top_k=1,
            top_p=1.0, vocab_limit=self.V)

    def test_full_acceptance_emits_k_plus_one(self):
        # rows say 5,6,7,8; drafts 5,6,7 all match -> emit 5,6,7,8
        toks, lps, n_emit, n_acc = self._accept([5, 6, 7, 8], [5, 6, 7])
        assert int(n_emit[0]) == 4 and int(n_acc[0]) == 3
        np.testing.assert_array_equal(np.asarray(toks[0]), [5, 6, 7, 8])

    def test_first_rejection_emits_replayed_draw(self):
        # draft 9 != replay 5: emit the replay, drop the rest
        toks, _, n_emit, n_acc = self._accept([5, 6, 7, 8], [9, 6, 7])
        assert int(n_emit[0]) == 1 and int(n_acc[0]) == 0
        assert int(toks[0, 0]) == 5
        np.testing.assert_array_equal(np.asarray(toks[0, 1:]), PAD)

    def test_mid_rejection(self):
        toks, _, n_emit, n_acc = self._accept([5, 6, 7, 8], [5, 9, 7])
        assert int(n_emit[0]) == 2 and int(n_acc[0]) == 1
        np.testing.assert_array_equal(np.asarray(toks[0, :2]), [5, 6])

    def test_eos_cuts_emission(self):
        toks, _, n_emit, n_acc = self._accept([5, EOS, 7, 8], [5, EOS, 7])
        assert int(n_emit[0]) == 2
        assert int(toks[0, 1]) == EOS
        np.testing.assert_array_equal(np.asarray(toks[0, 2:]), PAD)

    def test_budget_cuts_emission(self):
        # gen_base=2 (3 tokens committed incl. pending), max_new=4:
        # room for exactly one more emission
        toks, _, n_emit, _ = self._accept([5, 6, 7, 8], [5, 6, 7],
                                          gen_base=2, max_new=4)
        assert int(n_emit[0]) == 1 and int(toks[0, 0]) == 5

    def test_inactive_row_emits_nothing(self):
        lg = self._logits([5, 6])
        toks, lps, n_emit, n_acc = accept_drafts(
            lg, jnp.full((1, 2), PAD, jnp.int32),
            jnp.asarray([0], np.int32), jnp.asarray([False]),
            jax.random.PRNGKey(0)[None], jnp.asarray([0], np.int32),
            jnp.asarray([100], np.int32), temperature=1.0, top_k=1,
            top_p=1.0, vocab_limit=self.V)
        assert int(n_emit[0]) == 0 and float(lps[0].sum()) == 0.0

    def test_logps_are_target_model_logps(self):
        """The reported logp is log_softmax(raw row)[token] — the target
        model's convention — NOT the filtered/draft distribution's."""
        rng = np.random.default_rng(0)
        lg = jnp.asarray(rng.normal(size=(1, 3, self.V)).astype(np.float32))
        am = np.asarray(jnp.argmax(lg, axis=-1))[0]
        toks, lps, n_emit, _ = accept_drafts(
            lg, jnp.asarray([[3, am[0], am[1]]], jnp.int32),
            jnp.asarray([2], np.int32), jnp.asarray([True]),
            jax.random.PRNGKey(0)[None], jnp.asarray([0], np.int32),
            jnp.asarray([100], np.int32), temperature=1.0, top_k=1,
            top_p=1.0, vocab_limit=self.V)
        ref = jax.nn.log_softmax(lg, axis=-1)
        for j in range(int(n_emit[0])):
            np.testing.assert_allclose(
                float(lps[0, j]), float(ref[0, j, int(toks[0, j])]),
                rtol=1e-6)


class TestEngineParity:
    """spec_k=4 engine vs spec-off engine: same requests, same seed."""

    @pytest.mark.parametrize("cfg", [TINY, GQA_LOCAL],
                             ids=["tiny", "gqa-local"])
    def test_greedy_bit_exact(self, cfg):
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        rl = RLConfig(max_new_tokens=12, engine="continuous", **GREEDY)
        prompts = _prompts(np.random.default_rng(0))
        _, r0 = _run(cfg, params, rl, prompts, spec_k=0, key=key)
        eng, r4 = _run(cfg, params, rl, prompts, spec_k=4, key=key)
        for a, b in zip(r0, r4):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_allclose(a.logps, b.logps, rtol=2e-5,
                                       atol=1e-6)
            assert a.finish_reason == b.finish_reason
        st = eng.stats()
        # untrained greedy models loop -> the n-gram drafter locks on
        assert st["accept_rate"] > 0.3
        assert st["drafted_tokens_total"] > 0

    def test_stochastic_tokens_exact(self):
        """Exact replay reproduces the engine's counter-based draws, so
        even sampled (non-greedy) runs emit identical token streams."""
        key = jax.random.PRNGKey(1)
        params = init_params(TINY, key)
        rl = RLConfig(temperature=0.8, top_k=8, top_p=0.9,
                      max_new_tokens=10, engine="continuous")
        prompts = _prompts(np.random.default_rng(1))
        _, r0 = _run(TINY, params, rl, prompts, spec_k=0, key=key)
        _, r3 = _run(TINY, params, rl, prompts, spec_k=3, key=key)
        for a, b in zip(r0, r3):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_allclose(a.logps, b.logps, rtol=2e-5,
                                       atol=1e-6)

    def test_per_request_opt_out(self):
        """SamplingParams.spec=False rides through the spec engine with
        zero drafted tokens (all-opt-out rounds take the sequential
        fallback chunk) and stays bit-identical to the spec-off
        engine."""
        key = jax.random.PRNGKey(2)
        params = init_params(TINY, key)
        rl = RLConfig(max_new_tokens=8, engine="continuous", **GREEDY)
        prompts = _prompts(np.random.default_rng(2), n=4)
        _, r0 = _run(TINY, params, rl, prompts, spec_k=0, key=key)
        eng, r4 = _run(TINY, params, rl, prompts, spec_k=4, key=key,
                       spec=False)
        for a, b in zip(r0, r4):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        assert eng.stats()["drafted_tokens_total"] == 0

    def test_logps_are_target_model_end_to_end(self):
        """Teacher-forced recompute of the emitted sequences under the
        target params must reproduce the engine's reported logps — the
        GEPO importance-weight contract (a drafter logp leaking through
        would break the learner's ratio)."""
        from repro.sampling import rollout_from_results, token_logps
        key = jax.random.PRNGKey(3)
        params = init_params(TINY, key)
        rl = RLConfig(max_new_tokens=10, engine="continuous", **GREEDY)
        width = 7
        prompts = _prompts(np.random.default_rng(3), n=4, width=width)
        _, res = _run(TINY, params, rl, prompts, spec_k=4, key=key)
        roll = rollout_from_results(np.stack(prompts), res,
                                    rl.max_new_tokens)
        lp = token_logps(TINY, params, roll["tokens"])[:, width - 1:]
        mask = np.asarray(roll["comp_mask"])
        np.testing.assert_allclose(np.asarray(roll["sampler_lp"]) * mask,
                                   np.asarray(lp) * mask, rtol=1e-4,
                                   atol=1e-4)


class TestRollbackAndPool:
    def test_pool_balanced_after_spec_run(self):
        """Rejected drafts rewind by position only — no allocator
        traffic — so a finished spec run returns every page."""
        key = jax.random.PRNGKey(4)
        params = init_params(TINY, key)
        rl = RLConfig(max_new_tokens=12, engine="continuous", **GREEDY)
        prompts = _prompts(np.random.default_rng(4), n=8)
        eng, res = _run(TINY, params, rl, prompts, spec_k=4, key=key,
                        prefix_cache=False)
        assert len(res) == 8
        assert all(r.finish_reason in ("eos", "length") for r in res)
        assert eng.free_pages == eng.num_pages - 1   # all but scratch

    def test_pool_balanced_with_prefix_cache(self):
        key = jax.random.PRNGKey(5)
        params = init_params(TINY, key)
        rl = RLConfig(max_new_tokens=8, engine="continuous", **GREEDY)
        rng = np.random.default_rng(5)
        shared = rng.integers(4, 30, size=5).astype(np.int32)
        prompts = [np.concatenate([shared,
                                   rng.integers(4, 30, size=3)
                                   .astype(np.int32)]) for _ in range(6)]
        eng, res = _run(TINY, params, rl, prompts, spec_k=4, key=key)
        held = len({pg for ent in eng.prefix_cache._entries.values()
                    for pg in ent.pages})
        assert eng.free_pages + held == eng.num_pages - 1


class TestFusedRescore:
    def test_verify_path_uses_fused_layers_launch(self, monkeypatch):
        """The acceptance rescore must route through ONE
        ``paged_prefill_layers`` launch (the fused-layer kernels'
        consumer), and — same operands, row-independent math — agree
        bit-exactly with the in-forward attention outputs."""
        import repro.kernels.ops as ops
        from repro.sampling.continuous import _verify_chunk_jit
        calls = []
        real = ops.paged_prefill_layers

        def counted(q, kp, vp, *a, **kw):
            calls.append(int(q.shape[0]))          # layers folded per launch
            return real(q, kp, vp, *a, **kw)

        monkeypatch.setattr(ops, "paged_prefill_layers", counted)
        # the launch is only observable at trace time — drop executables
        # warmed by earlier tests so this engine traces fresh regardless
        # of suite order
        _verify_chunk_jit.clear_cache()
        key = jax.random.PRNGKey(6)
        params = init_params(TINY, key)
        rl = RLConfig(max_new_tokens=10, engine="continuous", **GREEDY)
        prompts = _prompts(np.random.default_rng(6))
        eng, _ = _run(TINY, params, rl, prompts, spec_k=4, key=key)
        st = eng.stats()
        assert st["spec_rounds"] > 0
        # traced at least once (per verify-width executable), all L
        # layers folded into each single launch
        assert calls and all(n == TINY.num_layers for n in calls)
        assert st["spec_rescore_max_diff"] == 0.0

    def test_rescore_off_skips_launch(self, monkeypatch):
        import repro.kernels.ops as ops
        from repro.sampling.continuous import _verify_chunk_jit
        calls = []
        real = ops.paged_prefill_layers
        monkeypatch.setattr(
            ops, "paged_prefill_layers",
            lambda *a, **kw: calls.append(1) or real(*a, **kw))
        _verify_chunk_jit.clear_cache()   # force fresh fused=False traces
        key = jax.random.PRNGKey(7)
        params = init_params(TINY, key)
        rl = RLConfig(max_new_tokens=6, engine="continuous", **GREEDY)
        eng, _ = _run(TINY, params, rl,
                      _prompts(np.random.default_rng(7), n=3),
                      spec_k=4, key=key, spec_rescore=False)
        assert eng.stats()["spec_rounds"] > 0 and not calls


class TestBucketsAndConfig:
    def test_width_buckets_match_sentinel(self):
        for k in range(0, 12):
            assert verify_width_buckets(k) == spec_verify_width_buckets(k)
        assert verify_width_buckets(4) == 3          # widths {2, 4, 5}
        assert verify_width_buckets(0) == 1          # floor width 2 only
        assert verify_width_buckets(7) == 3          # {2, 4, 8}

    def test_executable_bound(self):
        assert spec_verify_executable_bound(0, 8) == 0
        # verify widths × pow2 table widths {1,2,4,8}, plus one fallback
        # decode-chunk family over the same table widths
        assert spec_verify_executable_bound(4, 8) == \
            (spec_verify_width_buckets(4) + 1) * 4

    def test_serve_config_validation(self):
        with pytest.raises(ValueError):
            ServeConfig(engine="static", spec_k=4)
        with pytest.raises(ValueError):
            ServeConfig(engine="continuous", spec_k=-1)
        with pytest.raises(ValueError):
            ServeConfig(engine="continuous", spec_k=2, spec_ngram_min=0)


class TestFilterLogitsTopK:
    def test_lax_topk_matches_sort_reference(self):
        """Satellite: top-k threshold via lax.top_k must reproduce the
        full-sort reference exactly, ties included."""
        rng = np.random.default_rng(0)
        lg = rng.normal(size=(5, 64)).astype(np.float32)
        lg[0, :10] = 1.25                            # ties at the threshold
        lg[1] = 0.0                                  # fully degenerate
        x = jnp.asarray(lg)
        for k in (1, 3, 10, 63, 64, 0):
            got = filter_logits(x, top_k=k)
            v = x.shape[-1]
            if k and k < v:
                kth = jnp.sort(x, axis=-1)[..., v - k][..., None]
                want = jnp.where(x < kth, NEG_INF, x)
            else:
                want = x
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
