"""Serving front door: refcounted page allocator, shared-prefix cache
(bit-exactness + COW + eviction), SamplingParams/ServeConfig validation,
admission control reject paths, and the HTTP/websocket round-trip."""
import asyncio
import json

import jax
import numpy as np
import pytest

from repro.config import ATTN, MLP, ModelConfig, RLConfig, ServeConfig
from repro.models import init_params
from repro.sampling import (ContinuousEngine, PageAllocator, StaticEngine,
                            build_engine, pages_for)
from repro.sampling.prefix_cache import PrefixCache
from repro.serving import (EXPIRED, INFEASIBLE, OK, OVERLOADED, QUEUE_FULL,
                           AdmissionController)
from repro.serving.api import Engine, GenerationResult, Request, SamplingParams
from repro.serving.server import FrontDoor

TINY = ModelConfig(name="tiny-serve", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=32, block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)


def _prompt(rng, n):
    return rng.integers(4, 30, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
class TestPageAllocator:
    """Refcounted allocator ≡ the old free-list for the single-owner
    pattern, plus retain/release semantics the prefix cache needs."""

    def test_alloc_free_roundtrip_matches_free_list(self):
        a = PageAllocator(8)
        avail0 = a.available
        pages = a.alloc(3)
        assert len(pages) == 3 and a.available == avail0 - 3
        a.free(pages)                      # legacy alias for release
        assert a.available == avail0
        assert sorted(a.alloc(avail0)) == sorted(range(1, 8))

    def test_double_free_raises(self):
        a = PageAllocator(8)
        pages = a.alloc(2)
        a.release(pages)
        with pytest.raises(ValueError, match="double free|foreign"):
            a.release(pages)

    def test_retain_keeps_page_alive_across_release(self):
        a = PageAllocator(8)
        (pg,) = a.alloc(1)
        a.retain([pg])
        assert a.refcount(pg) == 2
        assert a.release([pg]) == []       # still cache-held
        assert a.refcount(pg) == 1
        assert a.release([pg]) == [pg]     # last reference frees it
        with pytest.raises(ValueError):
            a.retain([pg])                 # retain of a dead page

    def test_alloc_insufficient_returns_none(self):
        a = PageAllocator(4)               # 3 usable (page 0 is scratch)
        assert a.alloc(5) is None
        assert a.available == 3            # failed alloc took nothing


# ---------------------------------------------------------------------------
class TestSamplingParamsValidation:
    def test_defaults_valid(self):
        assert SamplingParams().profile == (0.6, 20, 0.95)

    @pytest.mark.parametrize("kw", [
        {"temperature": -0.1}, {"temperature": float("nan")},
        {"top_k": -1}, {"top_p": 0.0}, {"top_p": 1.5},
        {"max_new_tokens": 0},
        {"temperature": 0.0, "top_k": 5},          # greedy + filter conflict
        {"temperature": 0.0, "top_p": 0.5},
    ])
    def test_invalid_combinations_raise(self, kw):
        with pytest.raises(ValueError):
            SamplingParams(**kw)

    def test_pure_greedy_allowed(self):
        sp = SamplingParams(temperature=0.0, top_k=0, top_p=1.0)
        assert sp.profile == (0.0, 0, 1.0)

    def test_rl_roundtrip(self):
        rl = RLConfig(temperature=0.8, top_k=7, top_p=0.9, max_new_tokens=5)
        sp = SamplingParams.from_rl(rl)
        assert sp.rl().temperature == 0.8 and sp.rl().max_new_tokens == 5

    @pytest.mark.parametrize("kw", [
        {"prompt": np.zeros((0,), np.int32)},
        {"prompt": np.zeros((2, 2), np.int32)},
        {"prompt": [1, 2], "priority": -1},
        {"prompt": [1, 2], "arrival_s": 5.0, "deadline_s": 4.0},
    ])
    def test_request_validation(self, kw):
        with pytest.raises(ValueError):
            Request(rid=0, **kw)


class TestServeConfig:
    @pytest.mark.parametrize("kw", [
        {"engine": "batch"}, {"num_slots": 0}, {"page_size": 0},
        {"max_total_tokens": 1}, {"max_queue": 0},
        {"queue_overcommit": 0.5},
    ])
    def test_invalid_raises(self, kw):
        with pytest.raises(ValueError):
            ServeConfig(**kw)

    def test_resolved_pages_headroom(self):
        base = ServeConfig(num_slots=2, page_size=4, max_total_tokens=16)
        off = ServeConfig(num_slots=2, page_size=4, max_total_tokens=16,
                          prefix_cache=False)
        assert base.pages_per_slot == 4
        assert off.resolved_num_pages == 1 + 8       # scratch + exact budget
        assert base.resolved_num_pages == 1 + 8 + 4  # +50% cache headroom
        explicit = ServeConfig(num_pages=99)
        assert explicit.resolved_num_pages == 99


# ---------------------------------------------------------------------------
class TestPrefixCache:
    def _cache(self, num_pages=32, page_size=4, **kw):
        alloc = PageAllocator(num_pages)
        return PrefixCache(page_size, alloc, **kw), alloc

    def test_insert_lookup_full_pages_and_cow_tail(self):
        cache, alloc = self._cache()
        rng = np.random.default_rng(0)
        prompt = _prompt(rng, 10)                    # 2 full pages + 2 tail
        pages = alloc.alloc(pages_for(10, 4))
        assert cache.insert(prompt, pages)
        sharer = np.concatenate([prompt, _prompt(rng, 3)])
        m, shared, cow = cache.lookup(sharer)
        assert m == 10 and shared == pages[:2] and cow == pages[2]
        aligned = np.concatenate([prompt[:8], 31 - prompt[8:]])
        m, shared, cow = cache.lookup(aligned)
        assert m == 8 and shared == pages[:2] and cow == -1

    def test_hit_capped_below_prompt_len(self):
        """The final prompt token always prefills — its logits seed
        decoding — so a fully-cached prompt still hits only len-1."""
        cache, alloc = self._cache()
        prompt = _prompt(np.random.default_rng(1), 8)
        cache.insert(prompt, alloc.alloc(2))
        m, _, _ = cache.lookup(prompt)
        assert m == 7

    def test_short_prompt_not_cached(self):
        cache, alloc = self._cache(page_size=8)
        assert not cache.insert(np.arange(4, dtype=np.int32), alloc.alloc(1))
        assert len(cache) == 0

    def test_peek_has_no_side_effects(self):
        cache, alloc = self._cache()
        prompt = _prompt(np.random.default_rng(2), 12)
        cache.insert(prompt, alloc.alloc(3))
        before = dict(cache.stats)
        m, shared, _ = cache.peek(np.concatenate([prompt, prompt[:2]]))
        assert m == 12 and len(shared) == 3
        assert cache.stats == before

    def test_lru_eviction_at_entry_cap(self):
        cache, alloc = self._cache(num_pages=64, max_entries=2)
        rng = np.random.default_rng(3)
        prompts = [_prompt(rng, 8) for _ in range(3)]
        for p in prompts:
            cache.insert(p, alloc.alloc(2))
        assert len(cache) == 2 and cache.stats["evictions"] == 1
        assert cache.lookup(prompts[0])[0] == 0      # LRU victim is gone
        assert cache.lookup(prompts[2])[0] == 7

    def test_evict_until_frees_pool(self):
        cache, alloc = self._cache(num_pages=9)      # 8 usable
        rng = np.random.default_rng(4)
        for _ in range(2):
            pages = alloc.alloc(4)
            cache.insert(pages=pages, prompt=_prompt(rng, 16))
            alloc.release(pages)                     # only the cache holds on
        assert alloc.available == 0
        assert cache.evict_until(6) == 2
        assert alloc.available == 8 and len(cache) == 0


# ---------------------------------------------------------------------------
def _serve(**kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("sync_every", 4)
    kw.setdefault("max_total_tokens", 20)
    return ServeConfig(**kw)


def _engine(params, serve, rl, key):
    return build_engine(TINY, params, serve, rl=rl, vocab_limit=20, key=key)


@pytest.fixture(scope="module")
def tiny_params():
    return init_params(TINY, jax.random.PRNGKey(0))


class TestPrefixReuseEndToEnd:
    def test_prefix_hit_bit_exact_vs_cold_prefill(self, tiny_params, rng):
        """Requests served from cached prefix pages (incl. a COW tail)
        produce the same tokens and logps as a cold prefill."""
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=6,
                      engine="continuous")
        nrng = np.random.default_rng(7)
        prefix = _prompt(nrng, 10)                   # 2 full pages + 2 tail
        first = Request(rid=0, prompt=np.concatenate([prefix, [4, 5, 6]]),
                        params=SamplingParams.from_rl(rl))
        sharers = [Request(rid=r, prompt=np.concatenate(
                       [prefix, [10 + 3 * r, 7, 8]]),
                       params=SamplingParams.from_rl(rl))
                   for r in (1, 2)]
        results = {}
        for mode in (True, False):
            eng = _engine(tiny_params, _serve(prefix_cache=mode), rl, rng)
            eng.generate([first], key=rng)           # warm (or not) the cache
            results[mode] = eng.generate(sharers, key=rng)
            if mode:
                st = eng.stats()
                assert st["prefix_hits"] == 2
                assert st["prefix_tokens_reused"] == 20
                assert st["cow_copies"] == 2         # 10 % 4 != 0 → COW tail
        for warm, cold in zip(results[True], results[False]):
            np.testing.assert_array_equal(warm.tokens, cold.tokens)
            np.testing.assert_allclose(warm.logps, cold.logps,
                                       rtol=1e-5, atol=1e-5)
            assert warm.prefix_hit_tokens == 10
            assert cold.prefix_hit_tokens == 0

    def test_cache_evicted_under_pool_pressure(self, tiny_params, rng):
        """With an exact-budget pool (no headroom), cached prefixes must
        be evicted to admit new work — and everything still finishes
        with the pool balanced."""
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=4,
                      engine="continuous")
        serve = _serve(num_pages=1 + 2 * 5)          # scratch + 2 slots exact
        eng = _engine(tiny_params, serve, rl, rng)
        nrng = np.random.default_rng(8)
        reqs = [Request(rid=r, prompt=_prompt(nrng, 16),
                        params=SamplingParams.from_rl(rl))
                for r in range(6)]                   # all-distinct prompts
        out = eng.generate(reqs, key=rng)
        assert len(out) == 6
        assert all(r.finish_reason in ("eos", "length") for r in out)
        assert eng.prefix_cache.stats["evictions"] > 0
        held = len({pg for ent in eng.prefix_cache._entries.values()
                    for pg in ent.pages})
        assert eng.free_pages + held == eng.num_pages - 1


class TestAdmissionControl:
    def test_reject_taxonomy(self, tiny_params, rng):
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=8,
                      engine="continuous")
        serve = _serve(max_total_tokens=16, max_queue=3, queue_overcommit=1.0,
                       prefix_cache=False)
        eng = _engine(tiny_params, serve, rl, rng)
        adm = AdmissionController(serve, eng)
        sp = SamplingParams.from_rl(rl)
        ok = Request(rid=0, prompt=_prompt(np.random.default_rng(0), 8),
                     params=sp)
        assert adm.check(ok, now_s=0.0).reason == OK

        big = Request(rid=1, prompt=_prompt(np.random.default_rng(1), 12),
                      params=sp)                     # 12+8 > 16-token budget
        assert adm.check(big, now_s=0.0).reason == INFEASIBLE

        late = Request(rid=2, prompt=ok.prompt, params=sp, deadline_s=1.0)
        assert adm.check(late, now_s=2.0).reason == EXPIRED

        # queue 2 requests (8 pages promised) -> pool capacity 8 exceeded
        for r in (3, 4):
            eng.submit(Request(rid=r, prompt=ok.prompt, params=sp))
        assert adm.check(Request(rid=5, prompt=ok.prompt, params=sp),
                         now_s=0.0).reason == OVERLOADED
        eng.submit(Request(rid=6, prompt=ok.prompt, params=sp))
        assert adm.check(Request(rid=7, prompt=ok.prompt, params=sp),
                         now_s=0.0).reason == QUEUE_FULL
        assert adm.rejected_total == 4
        assert adm.rejected == {INFEASIBLE: 1, EXPIRED: 1, QUEUE_FULL: 1,
                                OVERLOADED: 1}
        eng.generate([], key=rng)                    # drain the queued three

    def test_shared_prefix_discounts_promised_pages(self, tiny_params, rng):
        """A request whose prefix is cached only charges admission for
        the pages it would newly allocate."""
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=4,
                      engine="continuous")
        serve = _serve(queue_overcommit=1.0)
        eng = _engine(tiny_params, serve, rl, rng)
        sp = SamplingParams.from_rl(rl)
        prompt = _prompt(np.random.default_rng(9), 16)
        eng.generate([Request(rid=0, prompt=prompt, params=sp)], key=rng)
        adm = AdmissionController(serve, eng)
        sharer = Request(rid=1, prompt=prompt.copy(), params=sp)
        cold = Request(rid=2, prompt=31 - prompt, params=sp)
        pages_cold = pages_for(16 + 4, 4)
        m, shared, _ = eng.prefix_cache.peek(sharer.prompt)
        assert len(shared) > 0
        assert adm.check(sharer, now_s=0.0).reason == OK
        assert adm.check(cold, now_s=0.0).reason == OK
        assert pages_cold - len(shared) < pages_cold  # the discount is real


class TestEngineProtocol:
    def test_both_engines_satisfy_protocol(self, tiny_params, rng):
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=4)
        cont = _engine(tiny_params, _serve(), rl, rng)
        stat = _engine(tiny_params, _serve(engine="static"), rl, rng)
        assert isinstance(cont, ContinuousEngine)
        assert isinstance(stat, StaticEngine)
        assert isinstance(cont, Engine) and isinstance(stat, Engine)
        sp = SamplingParams.from_rl(rl)
        reqs = [Request(rid=r, prompt=np.arange(4, 10, dtype=np.int32),
                        params=sp) for r in range(2)]
        for eng in (cont, stat):
            out = eng.generate(reqs, key=rng)
            assert [r.rid for r in out] == [0, 1]
            assert all(isinstance(r, GenerationResult) for r in out)


# ---------------------------------------------------------------------------
class TestFrontDoor:
    """HTTP + websocket round-trip against an in-process FrontDoor."""

    def _door(self, tiny_params):
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0, max_new_tokens=5,
                      engine="continuous")
        serve = _serve(port=0, max_total_tokens=16)
        return FrontDoor(TINY, tiny_params, serve, rl=rl, vocab_limit=20,
                         key=jax.random.PRNGKey(3))

    async def _http(self, port, method, path, payload=None):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        body = json.dumps(payload).encode() if payload is not None else b""
        writer.write((f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n"
                      ).encode() + body)
        await writer.drain()
        status = int((await reader.readline()).split()[1])
        n = 0
        while True:
            line = await reader.readline()
            if line in (b"\r\n", b""):
                break
            if line.lower().startswith(b"content-length:"):
                n = int(line.split(b":")[1])
        data = await reader.readexactly(n)
        writer.close()
        return status, json.loads(data)

    def test_http_generate_metrics_and_rejection(self, tiny_params):
        async def scenario():
            door = self._door(tiny_params)
            await door.start()
            try:
                status, out = await self._http(
                    door.port, "POST", "/generate",
                    {"tokens": [5, 6, 7, 8], "max_new_tokens": 5})
                assert status == 200
                assert len(out["tokens"]) == len(out["logps"]) >= 1
                assert out["finish_reason"] in ("eos", "length")

                status, err = await self._http(
                    door.port, "POST", "/generate",
                    {"tokens": list(range(4, 18)), "max_new_tokens": 5})
                assert status == 400                 # infeasible: 14+5 > 16
                assert err["error"] == INFEASIBLE

                status, health = await self._http(door.port, "GET", "/healthz")
                assert status == 200 and health["ok"]
                status, m = await self._http(door.port, "GET", "/metrics")
                assert status == 200
                assert m["slo"]["completed"] == 1
                assert m["rejected"][INFEASIBLE] == 1
                assert m["engine"]["completed"] == 1
            finally:
                await door.close()
        asyncio.run(scenario())

    def test_metrics_prometheus_exposition(self, tiny_params):
        """GET /metrics negotiates Prometheus text (Accept: text/plain or
        ?format=prometheus) while the JSON snapshot stays the default;
        the text carries the unified registry: serve SLO counters,
        engine page-pool gauges, and the compile-sentinel mirror."""
        from repro import obs

        async def scenario():
            door = self._door(tiny_params)
            await door.start()
            obs.configure(True, clear=True)
            try:
                status, _ = await self._http(
                    door.port, "POST", "/generate",
                    {"tokens": [5, 6, 7, 8], "max_new_tokens": 5})
                assert status == 200
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", door.port)
                writer.write(b"GET /metrics?format=prometheus HTTP/1.1\r\n"
                             b"Host: t\r\nAccept: text/plain\r\n\r\n")
                await writer.drain()
                assert b"200" in await reader.readline()
                ctype, n = b"", 0
                while True:
                    line = await reader.readline()
                    if line in (b"\r\n", b""):
                        break
                    if line.lower().startswith(b"content-type:"):
                        ctype = line
                    if line.lower().startswith(b"content-length:"):
                        n = int(line.split(b":")[1])
                text = (await reader.readexactly(n)).decode()
                writer.close()
                assert b"text/plain" in ctype
                assert "# TYPE serve_requests_completed_total counter" \
                    in text
                assert "serve_requests_completed_total 1" in text
                assert "serve_ttft_seconds_bucket" in text
                assert "engine_free_pages" in text        # page pool
                assert "xla_compiles_total" in text       # sentinel mirror
                # default (no Accept/format) still answers JSON
                status, m = await self._http(door.port, "GET", "/metrics")
                assert status == 200 and m["slo"]["completed"] == 1
            finally:
                obs.configure(False, clear=True)
                await door.close()
        asyncio.run(scenario())

    def test_websocket_stream(self, tiny_params):
        async def scenario():
            door = self._door(tiny_params)
            await door.start()
            try:
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", door.port)
                writer.write(b"GET /ws HTTP/1.1\r\nHost: t\r\n"
                             b"Upgrade: websocket\r\nConnection: Upgrade\r\n"
                             b"Sec-WebSocket-Key: dGVzdGtleTEyMzQ1Njc4\r\n"
                             b"\r\n")
                await writer.drain()
                assert b"101" in await reader.readline()
                while (await reader.readline()) not in (b"\r\n", b""):
                    pass
                payload = json.dumps({"id": "a", "tokens": [5, 6, 7],
                                      "max_new_tokens": 5}).encode()
                mask = b"\x01\x02\x03\x04"
                frame = bytes([0x81, 0x80 | len(payload)]) + mask + bytes(
                    b ^ mask[i % 4] for i, b in enumerate(payload))
                writer.write(frame)
                await writer.drain()
                events = []
                while True:                          # server frames: unmasked
                    hdr = await reader.readexactly(2)
                    ln = hdr[1] & 0x7F
                    if ln == 126:
                        ln = int.from_bytes(await reader.readexactly(2),
                                            "big")
                    events.append(json.loads(await reader.readexactly(ln)))
                    if "finish_reason" in events[-1]:
                        break
                assert all(e["id"] == "a" for e in events)
                assert events[-1]["finish_reason"] in ("eos", "length")
                assert [e["token"] for e in events[:-1]] == \
                    events[-1]["tokens"][:len(events) - 1]
                writer.close()
            finally:
                await door.close()
        asyncio.run(scenario())
