"""Unit tests for the algorithmic core: importance weights per Listing 1,
advantages, loss assembly, KL estimator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RLConfig
from repro.core import (ALL_METHODS, group_advantages, importance_weights,
                        kl_k3, policy_loss, seq_logprob)
from repro.core.importance import group_expectation_log_denominator


def _fake_batch(key, b=16, t=10, spread=0.3):
    ks = jax.random.split(key, 3)
    lp_l = -jnp.abs(jax.random.normal(ks[0], (b, t)))
    lp_s = lp_l - spread * jnp.abs(jax.random.normal(ks[1], (b, t)))
    mask = jnp.ones((b, t))
    return lp_l, lp_s, mask


class TestImportanceWeights:
    def test_gepo_matches_listing1(self, rng):
        """GEPO weight == p_seq / (Σq̂·q) with q̂ = q/Σq (eq. 2/3)."""
        g = 4
        lp_l, lp_s, mask = _fake_batch(rng, b=8)
        lw, level = importance_weights("gepo", lp_l, lp_s, mask,
                                       group_size=g)
        assert level == "seq"
        q = np.exp(np.asarray(seq_logprob(lp_s, mask)))
        p = np.exp(np.asarray(seq_logprob(lp_l, mask)))
        for gi in range(2):
            qs = q[gi * g:(gi + 1) * g]
            ps = p[gi * g:(gi + 1) * g]
            den = (qs / qs.sum() * qs).sum()
            np.testing.assert_allclose(
                np.exp(np.asarray(lw[gi * g:(gi + 1) * g])), ps / den,
                rtol=1e-5)

    def test_token_level_methods(self, rng):
        lp_l, lp_s, mask = _fake_batch(rng)
        for m in ("grpo", "dr_grpo", "bnpo"):
            lw, level = importance_weights(m, lp_l, lp_s, mask, group_size=4)
            assert level == "token" and lw.shape == lp_l.shape
            np.testing.assert_allclose(np.asarray(lw),
                                       np.asarray(lp_l - lp_s), rtol=1e-6)

    def test_gspo_seq_level(self, rng):
        lp_l, lp_s, mask = _fake_batch(rng)
        lw, level = importance_weights("gspo", lp_l, lp_s, mask,
                                       group_size=4)
        assert level == "seq"
        expect = seq_logprob(lp_l, mask) - seq_logprob(lp_s, mask)
        np.testing.assert_allclose(np.asarray(lw), np.asarray(expect),
                                   rtol=1e-6)

    def test_gepo_denominator_between_min_max(self, rng):
        """Ê_q[q] is a convex combination of the group's q values."""
        lp_l, lp_s, mask = _fake_batch(rng, b=8)
        q_seq = seq_logprob(lp_s, mask)
        log_den = group_expectation_log_denominator(q_seq, 4)
        q = np.asarray(q_seq).reshape(2, 4)
        den = np.asarray(log_den).reshape(2, 4)
        for gi in range(2):
            assert q[gi].min() - 1e-5 <= den[gi][0] <= q[gi].max() + 1e-5

    def test_gepo_no_grad_through_denominator(self, rng):
        lp_l, lp_s, mask = _fake_batch(rng, b=4)

        def f(lp_s_var):
            lw, _ = importance_weights("gepo", lp_l, lp_s_var, mask,
                                       group_size=4)
            return lw.sum()
        g = jax.grad(f)(lp_s)
        assert float(jnp.abs(g).max()) == 0.0

    def test_gepo_smooth_defensive_denominator(self, rng):
        """App. H: λ-smoothing pulls the weight toward 1."""
        lp_l, lp_s, mask = _fake_batch(rng, b=8, spread=1.5)
        lw0, _ = importance_weights("gepo", lp_l, lp_s, mask, group_size=4)
        lw1, _ = importance_weights("gepo", lp_l, lp_s, mask, group_size=4,
                                    gepo_smooth=1.0)
        # λ=1: denominator == p -> weight == 1
        np.testing.assert_allclose(np.asarray(lw1), 0.0, atol=1e-5)
        assert float(jnp.abs(lw1).mean()) <= float(jnp.abs(lw0).mean())


class TestAdvantages:
    def test_group_mean_baseline_zero_sum(self, rng):
        r = jax.random.uniform(rng, (32,))
        a = group_advantages(r, 8, normalize=False)
        np.testing.assert_allclose(np.asarray(a.reshape(4, 8).sum(-1)), 0.0,
                                   atol=1e-5)

    def test_normalization(self, rng):
        r = jax.random.uniform(rng, (32,))
        a = group_advantages(r, 8, normalize=True)
        std = np.asarray(a.reshape(4, 8).std(-1))
        np.testing.assert_allclose(std, 1.0, atol=0.05)

    def test_dr_grpo_skips_std(self, rng):
        r = jax.random.uniform(rng, (32,))
        a1 = group_advantages(r, 8, normalize=True, kind="dr_grpo")
        a2 = group_advantages(r, 8, normalize=False)
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2))

    def test_bnpo_beta_normalization(self):
        r = jnp.asarray([1., 0., 0., 0., 1., 1., 0., 1.])
        a = group_advantages(r, 4, kind="bnpo")
        rho = 0.5
        np.testing.assert_allclose(
            np.asarray(a), (np.asarray(r) - rho) / np.sqrt(rho * (1 - rho)),
            rtol=1e-5)


class TestPolicyLoss:
    @pytest.mark.parametrize("method", ALL_METHODS)
    def test_all_methods_finite_loss_and_grad(self, rng, method):
        lp_l, lp_s, mask = _fake_batch(rng)
        rl = RLConfig(loss_type=method, group_size=4)
        rewards = (jax.random.uniform(jax.random.PRNGKey(7), (16,))
                   > 0.5).astype(jnp.float32)
        adv = group_advantages(rewards, 4)
        loss, metrics = policy_loss(rl, lp_l, lp_s, mask, adv)
        assert jnp.isfinite(loss)
        g = jax.grad(lambda lp: policy_loss(rl, lp, lp_s, mask, adv)[0])(
            lp_l)
        assert bool(jnp.isfinite(g).all())
        for k in ("iw_var", "kl", "est_error", "clip_frac"):
            assert jnp.isfinite(metrics[k]), k

    def test_onpolicy_grpo_equals_reinforce_direction(self, rng):
        """With p == q the clipped surrogate gradient is the policy
        gradient −A·∇log p."""
        lp_l, _, mask = _fake_batch(rng)
        rl = RLConfig(loss_type="grpo", group_size=4, beta_kl=0.0,
                      adv_normalize=False)
        rewards = jax.random.uniform(jax.random.PRNGKey(3), (16,))
        adv = group_advantages(rewards, 4, normalize=False)
        g = jax.grad(lambda lp: policy_loss(rl, lp, jax.lax.stop_gradient(
            lp), mask, adv)[0])(lp_l)
        t = mask.sum(-1)
        expect = -(adv[:, None] / t[:, None]) * jnp.ones_like(lp_l) / 16
        np.testing.assert_allclose(np.asarray(g), np.asarray(expect),
                                   rtol=1e-4)

    def test_kl_estimator_nonnegative_and_zero_onpolicy(self, rng):
        lp_l, lp_s, mask = _fake_batch(rng)
        assert float(kl_k3(lp_l, lp_l, mask)) == 0.0
        assert float(kl_k3(lp_l, lp_s, mask)) >= 0.0

    def test_gepo_iw_variance_below_gspo_under_divergence(self, rng):
        """The paper's core claim at the estimator level: under large
        policy divergence the group-level weights have (much) smaller
        variance than sequence-level ones."""
        ks = jax.random.split(rng, 2)
        b, t = 64, 12
        lp_l = -jnp.abs(jax.random.normal(ks[0], (b, t)))
        lp_s = lp_l - 1.2 * jnp.abs(jax.random.normal(ks[1], (b, t)))
        mask = jnp.ones((b, t))
        rewards = (jax.random.uniform(ks[0], (b,)) > 0.5).astype(jnp.float32)
        adv = group_advantages(rewards, 8)
        var = {}
        for m in ("gspo", "gepo"):
            rl = RLConfig(loss_type=m, group_size=8)
            _, metrics = policy_loss(rl, lp_l, lp_s, mask, adv)
            var[m] = float(metrics["iw_var"])
        assert var["gepo"] < var["gspo"]
